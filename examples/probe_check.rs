use std::path::PathBuf;
use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{Corpus, CorpusSpec};
use eellm::inference::ModelState;
use eellm::runtime::artifacts::Manifest;
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn main() {
    let root = PathBuf::from("artifacts");
    let man = Manifest::load_config(&root, "ee-tiny").unwrap();
    let corpus = Corpus::build(&CorpusSpec { seed: 7, n_entities: 8, target_bytes: 120_000 });
    let mut ds = Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let steps = 60;
    let mut trainer = PipelineTrainer::new(man.clone(), TrainerOptions {
        seed: 42, lr: LrSchedule::cosine(3e-3, 5, steps), grad_clip: 1.0,
        loss_weights: LossWeightSchedule::Constant, total_steps: steps,
        bubble_fill: 0, bf_ratio: 2.0 }).unwrap();
    for i in 0..steps {
        let batches: Vec<TrainBatch> = (0..2).map(|_| ds.next_microbatch()).collect();
        let st = trainer.train_step(&batches, &[]).unwrap();
        if i % 10 == 0 { println!("step {i} losses {:?}", st.losses); }
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    let state = ModelState { man: man.clone(), stage_params: params };
    for prompt in ["abc: a b c d ", "count: 3 4 5 ", "the capital of "] {
        let report = eellm::inference::probe::probe_generation(state.clone(), prompt, 12).unwrap();
        println!("prompt {prompt:?} -> {:?}", report.generated);
        for p in &report.probes {
            println!("  pos {} exits {:?}", p.position, p.exits);
        }
    }
}
