//! End-to-end validation run (EXPERIMENTS.md §E2E): pipeline-parallel
//! pre-training of the ee-e2e early-exit transformer (~11M params, P=4,
//! exits at 1/4 and 1/2 depth — the paper's Section 5.1 layout scaled to
//! this CPU testbed) on the synthetic corpus, logging the per-exit loss
//! curve (Figure 6 analogue) and saving a checkpoint that the inference
//! benches (Figures 8/10, Tables 3/4) consume.
//!
//!     cargo run --release --example train_e2e -- \
//!         --config ee-e2e --steps 300 --microbatches 8
//!
//! Flags: --config --steps --microbatches --lr --seed --corpus-bytes
//!        --loss-weight-schedule --bubble-fill --out-dir

use std::path::PathBuf;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{Corpus, CorpusSpec};
use eellm::metrics::CurveWriter;
use eellm::runtime::artifacts::Manifest;
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};
use eellm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let config = args.get_or("config", "ee-e2e");
    let steps = args.usize_or("steps", 300);
    let microbatches = args.usize_or("microbatches", 8);
    let lr = args.f64_or("lr", 1e-3);
    let seed = args.usize_or("seed", 42) as u64;
    let corpus_bytes = args.usize_or("corpus-bytes", 4 << 20);
    let bubble_fill = args.usize_or("bubble-fill", 0);
    let out_dir = PathBuf::from(args.get_or("out-dir", "artifacts/runs"));
    std::fs::create_dir_all(&out_dir)?;

    let man = Manifest::load_config(&PathBuf::from("artifacts"), &config)?;
    println!(
        "[e2e] {} | ~{} params | P={} | exits {:?} | {} steps x {} mb x {} tok",
        man.name,
        man.approx_param_count,
        man.model.pipeline_stages,
        man.exit_order(),
        steps,
        microbatches,
        man.model.seq * man.model.microbatch,
    );

    let corpus = Corpus::build(&CorpusSpec {
        seed,
        n_entities: 24,
        target_bytes: corpus_bytes,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, seed);
    println!(
        "[e2e] corpus {} docs -> {} training examples",
        corpus.docs.len(),
        ds.n_examples()
    );

    let schedule = LossWeightSchedule::parse(
        &args.get_or("loss-weight-schedule", "constant"),
        steps,
    );
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed,
            lr: LrSchedule::cosine(lr, steps / 20 + 1, steps),
            grad_clip: 1.0,
            loss_weights: schedule,
            total_steps: steps,
            bubble_fill,
            bf_ratio: 2.0,
        },
    )?;

    let names = trainer.exit_names();
    let mut hdr = vec!["step".to_string(), "lr".to_string(), "seconds".to_string()];
    hdr.extend(names.iter().cloned());
    let curve_path = out_dir.join(format!("{config}_loss_curve.csv"));
    let mut curve = CurveWriter::new(
        &curve_path,
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    let val = ds.validation_batches(4);
    let t0 = std::time::Instant::now();
    let mut tokens_seen = 0usize;
    for step in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..microbatches).map(|_| ds.next_microbatch()).collect();
        let fills: Vec<TrainBatch> =
            (0..bubble_fill).map(|_| ds.next_microbatch()).collect();
        let stats = trainer.train_step(&batches, &fills)?;
        tokens_seen += microbatches * man.model.seq * man.model.microbatch;
        let mut row = vec![stats.step as f64, stats.lr, stats.wall_seconds];
        row.extend(stats.losses.iter());
        curve.push(row);
        if step % 10 == 0 || step + 1 == steps {
            let ls: Vec<String> = names
                .iter()
                .zip(&stats.losses)
                .map(|(n, l)| format!("{n}={l:.4}"))
                .collect();
            println!(
                "step {:>4}/{steps} | {} | {:.2}s/it | {:.0} tok/s",
                stats.step,
                ls.join(" "),
                stats.wall_seconds,
                tokens_seen as f64 / t0.elapsed().as_secs_f64()
            );
        }
        if (step + 1) % 50 == 0 {
            let v = trainer.validate(&val)?;
            let ls: Vec<String> = names
                .iter()
                .zip(&v)
                .map(|(n, l)| format!("{n}={l:.4}"))
                .collect();
            println!("  [val] {}", ls.join(" "));
            curve.flush()?;
        }
    }
    curve.flush()?;

    let ckpt = out_dir.join(format!("{config}.eckpt"));
    trainer.save_checkpoint(&ckpt)?;

    // Profile data for EXPERIMENTS.md §Perf.
    println!("\n[e2e] executable profile (per stage):");
    for (s, name, calls, ms) in trainer.profile()? {
        if calls > 0 {
            println!(
                "  stage {s} {name:<12} {calls:>6} calls  {:>10.1}ms total  {:>8.2}ms/call",
                ms,
                ms / calls as f64
            );
        }
    }
    trainer.shutdown();

    println!("\n[e2e] done in {:.1}s", t0.elapsed().as_secs_f64());
    println!("[e2e] loss curve: {}", curve_path.display());
    println!("[e2e] checkpoint: {}", ckpt.display());
    Ok(())
}
