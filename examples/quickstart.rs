//! Quickstart: the whole system in one file.
//!
//! Trains the tiny early-exit model with pipeline parallelism for a few
//! steps on the synthetic corpus, validates, then generates text with both
//! early-exit inference engines and shows the speed/quality knob.
//!
//!     make artifacts && cargo run --release --example quickstart

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{Corpus, CorpusSpec};
use eellm::inference::{
    ExitPolicy, ModelState, PipelinedEngine, SequentialEngine,
};
use eellm::runtime::artifacts::Manifest;
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");
    let man = Manifest::load_config(&artifacts, "ee-tiny")?;
    println!(
        "model: {} (~{} params), {} pipeline stages, exits at {:?}",
        man.name,
        man.approx_param_count,
        man.model.pipeline_stages,
        man.exit_order()
    );

    // --- data: deterministic synthetic corpus (facts, QA, patterns...).
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 12,
        target_bytes: 200_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 7);

    // --- pipeline-parallel training (one thread per stage; Eq. 2
    // auxiliary-loss backprop between them).
    let steps = 80;
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 8, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )?;
    let names = trainer.exit_names();
    for step in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..4).map(|_| ds.next_microbatch()).collect();
        let stats = trainer.train_step(&batches, &[])?;
        if step % 10 == 0 || step + 1 == steps {
            let ls: Vec<String> = names
                .iter()
                .zip(&stats.losses)
                .map(|(n, l)| format!("{n}={l:.3}"))
                .collect();
            println!("step {:>3} | {}", stats.step, ls.join(" "));
        }
    }
    let params = trainer.params()?;
    trainer.shutdown();
    let state = ModelState { man: man.clone(), stage_params: params };

    // --- inference: the speed/quality knob is the confidence threshold.
    let prompt = "question: what is the ";
    println!("\nprompt: {prompt:?}");
    for tau in [1.0f32, 0.5, 0.2] {
        let mut eng = SequentialEngine::new(state.clone(), ExitPolicy::confidence(tau))?;
        let out = eng.generate_text(prompt, 24)?;
        println!(
            "  recompute tau={tau:<4} -> {:?}  ({:.0}ms, {:.0}% early)",
            out.text,
            out.seconds * 1e3,
            100.0 * out.stats.early_fraction(man.model.n_layers)
        );
    }
    let mut eng = PipelinedEngine::new(state, ExitPolicy::confidence(0.2))?;
    let out = eng.generate_text(prompt, 24)?;
    println!(
        "  pipelined tau=0.2  -> {:?}  ({:.0}ms, {:.0}% early)",
        out.text,
        out.seconds * 1e3,
        100.0 * out.stats.early_fraction(man.model.n_layers)
    );
    eng.shutdown();
    Ok(())
}
