//! Interactive tour of the paper's pipeline schedules (Figure 3): renders
//! ASCII timelines for the standard 1F1B schedule, the early-exit variants
//! with and without the deferral optimisation, the bubble-filled schedule
//! (Figure 4), and the GPipe baseline — with iteration time, bubble
//! fraction and peak-memory numbers from the discrete-event simulator.
//!
//!     cargo run --release --example schedule_explorer -- --model 7B --pp 4

use eellm::schedule::costs::{CostModel, PAPER_MODELS};
use eellm::schedule::plan::{EeOptions, Plan};
use eellm::schedule::report::render_timeline;
use eellm::schedule::sim::Simulator;
use eellm::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]);
    let model = args.get_or("model", "7B");
    let pp = args.usize_or("pp", 4);
    let m = args.usize_or("microbatches", 6);
    let dims = PAPER_MODELS
        .iter()
        .find(|d| d.name == model)
        .unwrap_or(&PAPER_MODELS[1]);
    let cm = CostModel::a100(dims, pp, 1);
    let sim = Simulator::new(&cm);

    let mut mid_exits = vec![0usize; pp];
    for e in mid_exits.iter_mut().take(pp - 1).skip(1) {
        *e = 1;
    }

    let scenarios: Vec<(&str, Plan)> = vec![
        (
            "Figure 3(a): standard 1F1B, no early exits",
            Plan::one_f_one_b(pp, m, EeOptions::none(pp)),
        ),
        (
            "Figure 3(b): early exits on middle stages (eager exit forward)",
            Plan::one_f_one_b(
                pp,
                m,
                EeOptions::with_exits(mid_exits.clone(), false),
            ),
        ),
        (
            "Figure 3(c): + Optimization 1 (exit forward deferred to backward)",
            Plan::one_f_one_b(
                pp,
                m,
                EeOptions::with_exits(mid_exits.clone(), true),
            ),
        ),
        ("GPipe baseline (all forwards, then all backwards)", {
            Plan::gpipe(pp, m, EeOptions::none(pp))
        }),
        ("Figure 4: 1F1B with bubble filling (Appendix C.2)", {
            let mut p = Plan::one_f_one_b(pp, m, EeOptions::none(pp));
            let k = Plan::max_fill(pp, 2.0);
            p.add_bubble_fill(k, k, 2.0);
            p
        }),
    ];

    println!(
        "model {model}, pp={pp}, M={m} microbatches (digits = fwd mb, letters = bwd mb, f/b = fills)\n"
    );
    for (title, plan) in scenarios {
        let r = sim.run(&plan);
        println!("=== {title}");
        println!("{}", render_timeline(&r, 96));
        let alpha = cm.alpha;
        let peak = r.peak_memory_overall(alpha) / (1u64 << 30) as f64;
        println!(
            "peak memory {:.1} GiB (bottleneck stage {})\n",
            peak,
            r.bottleneck_stage(alpha)
        );
    }
}
