//! Generation demos reproducing the paper's qualitative tables.
//!
//!   --table3   sequences + latency at several thresholds (Table 3)
//!   --table4   per-exit prediction/confidence per token   (Table 4)
//!   (neither)  single generation with both engines
//!
//!     cargo run --release --example generate -- \
//!         --config ee-e2e --checkpoint artifacts/runs/ee-e2e.eckpt \
//!         --prompt "question: what is the capital of " --table3

use std::path::PathBuf;

use eellm::inference::{
    ExitPolicy, ModelState, PipelinedEngine, SequentialEngine,
};
use eellm::runtime::artifacts::Manifest;
use eellm::util::cli::Args;
use eellm::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["table3", "table4"]);
    let config = args.get_or("config", "ee-tiny");
    let prompt = args.get_or("prompt", "question: what is the capital of ");
    let max_new = args.usize_or("max-new-tokens", 32);
    let man = Manifest::load_config(&PathBuf::from("artifacts"), &config)?;
    let n_layers = man.model.n_layers;

    let state = match args.get("checkpoint") {
        Some(p) => ModelState::from_checkpoint(man, std::path::Path::new(p))?,
        None => {
            eprintln!("[warn] no --checkpoint; random weights");
            ModelState::init(man, 42)
        }
    };

    if args.flag("table4") {
        let report = eellm::inference::probe::probe_generation(
            state, &prompt, max_new,
        )?;
        println!("prompt:    {prompt:?}");
        println!("generated: {:?}", report.generated);
        report.to_table().emit("table4");
        println!(
            "cross-exit agreement on confident (>=0.8) tokens: {:.1}%",
            100.0 * report.agreement_at(0.8)
        );
        return Ok(());
    }

    if args.flag("table3") {
        let mut t = Table::new(
            "Table 3 analogue: generations vs confidence threshold",
            &["threshold", "time", "early%", "generated"],
        );
        let mut full_text = String::new();
        for tau in [1.0f32, 0.8, 0.4, 0.2] {
            let mut eng = SequentialEngine::new(state.clone(), ExitPolicy::confidence(tau))?;
            let out = eng.generate_text(&prompt, max_new)?;
            if tau == 1.0 {
                full_text = out.text.clone();
            }
            let marker = if out.text == full_text { "" } else { " *" };
            t.row(vec![
                format!("{tau}"),
                format!("{:.0}ms", out.seconds * 1e3),
                format!(
                    "{:.0}%",
                    100.0 * out.stats.early_fraction(n_layers)
                ),
                format!("{:?}{marker}", out.text),
            ]);
        }
        println!("prompt: {prompt:?} (* = differs from full-model output)");
        t.emit("table3");
        return Ok(());
    }

    // Full spec grammar via --policy; --threshold stays as confidence
    // sugar (shared resolution rule).
    let policy = ExitPolicy::from_args(&args, 0.5)?;
    let mut seq = SequentialEngine::new(state.clone(), policy.clone())?;
    let a = seq.generate_text(&prompt, max_new)?;
    println!(
        "recompute: {:?} ({:.0}ms, exits {:?})",
        a.text,
        a.seconds * 1e3,
        a.stats.counts
    );
    let mut pipe = PipelinedEngine::new(state, policy)?;
    let b = pipe.generate_text(&prompt, max_new)?;
    println!(
        "pipelined: {:?} ({:.0}ms, exits {:?})",
        b.text,
        b.seconds * 1e3,
        b.stats.counts
    );
    pipe.shutdown();
    Ok(())
}
