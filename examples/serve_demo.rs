//! Multi-request serving demo: a pool of early-exit engines multiplexing
//! a mixed request set with per-request thresholds.
//!
//!     cargo run --release --example serve_demo -- \
//!         --config ee-tiny --checkpoint artifacts/runs/ee-e2e.eckpt \
//!         --workers 2 --policy spf --engine recompute

use std::path::PathBuf;

use eellm::inference::ModelState;
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    EngineKind, EnginePool, Policy, PoolConfig, ServeRequest,
};
use eellm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let config = args.get_or("config", "ee-tiny");
    let workers = args.usize_or("workers", 2);
    let policy = Policy::parse(&args.get_or("policy", "spf"))?;
    let kind = EngineKind::parse(&args.get_or("engine", "recompute"))?;
    let man = Manifest::load_config(&PathBuf::from("artifacts"), &config)?;
    let n_layers = man.model.n_layers;
    let state = match args.get("checkpoint") {
        Some(p) => ModelState::from_checkpoint(man, std::path::Path::new(p))?,
        None => {
            eprintln!("[warn] no --checkpoint; random weights");
            ModelState::init(man, 42)
        }
    };

    let prompts = [
        "question: what is the capital of ",
        "3+4=",
        "copy: the color of melka is red. |",
        "count: 1 2 3 4 ",
        "question: what is the food of ",
        "abc: a b c ",
    ];
    let reqs: Vec<ServeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            // Alternate aggressive and conservative per-request
            // thresholds to show both paths through the pool.
            let tau = if i % 2 == 0 { 0.4 } else { 1.0 };
            ServeRequest::new(i as u64, *p, 24).with_threshold(tau)
        })
        .collect();

    let mut pool = EnginePool::new(
        state,
        PoolConfig { workers, engine: kind, threshold: 0.8, policy },
    );
    let (responses, metrics) = pool.run_batch(reqs)?;
    pool.shutdown()?;

    for r in &responses {
        println!(
            "req {} (worker {}): {:?} [{} tok, queue {:.0}ms, total {:.0}ms]",
            r.id,
            r.worker,
            r.output.text,
            r.output.tokens.len(),
            r.queue_seconds * 1e3,
            r.total_seconds * 1e3,
        );
    }
    println!(
        "{} requests | {:.1} tok/s | p50 {:.0}ms p95 {:.0}ms | early {:.0}% \
         | exits {:?}",
        metrics.requests,
        metrics.throughput_tps(),
        metrics.p50_latency_seconds * 1e3,
        metrics.p95_latency_seconds * 1e3,
        100.0 * metrics.early_fraction(n_layers),
        metrics.exits.counts,
    );
    Ok(())
}
