//! Multi-request serving demo: a pool of early-exit engines continuously
//! batching a mixed request set, streaming tokens as they are emitted,
//! with per-request exit policies, priorities, and deadlines.
//!
//!     cargo run --release --example serve_demo -- \
//!         --config ee-tiny --checkpoint artifacts/runs/ee-e2e.eckpt \
//!         --workers 2 --concurrent 3 --sched priority --engine recompute
//!
//! The event trace printed while the batch runs shows requests
//! interleaving on each worker (continuous batching) rather than running
//! back-to-back.

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

use eellm::data::tokenizer::ByteTokenizer;
use eellm::inference::{ExitPolicy, ModelState};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    EngineKind, EnginePool, Policy, PoolConfig, ServeEvent, ServeRequest,
};
use eellm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let config = args.get_or("config", "ee-tiny");
    let workers = args.usize_or("workers", 2);
    let concurrent = args.usize_or("concurrent", 3);
    // Same migration guard as serve-bench: `--policy` used to be the
    // scheduling policy and now takes an exit-policy spec.
    if let Some(p) = args.get("policy") {
        if Policy::parse(p).is_ok() {
            anyhow::bail!(
                "--policy now takes an exit-policy spec (e.g. \
                 confidence:0.8); the queue scheduling policy moved to \
                 --sched {p}"
            );
        }
    }
    let sched = Policy::parse(&args.get_or("sched", "priority"))?;
    let kind = EngineKind::parse(&args.get_or("engine", "recompute"))?;
    let man = Manifest::load_config(&PathBuf::from("artifacts"), &config)?;
    let n_layers = man.model.n_layers;
    let state = match args.get("checkpoint") {
        Some(p) => ModelState::from_checkpoint(man, std::path::Path::new(p))?,
        None => {
            eprintln!("[warn] no --checkpoint; random weights");
            ModelState::init(man, 42)
        }
    };

    let prompts = [
        "question: what is the capital of ",
        "3+4=",
        "copy: the color of melka is red. |",
        "count: 1 2 3 4 ",
        "question: what is the food of ",
        "abc: a b c ",
    ];
    let reqs: Vec<ServeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            // Mix per-request exit policies to show the pluggable
            // surface: the paper's confidence rule (aggressive and
            // baseline, via the `with_threshold` sugar) alongside
            // entropy- and margin-based exits. The last request gets a
            // high priority and a tight deadline so it jumps the queue
            // under --sched priority.
            let mut r = ServeRequest::new(i as u64, *p, 24);
            r = match i % 4 {
                0 => r.with_threshold(0.4),
                1 => r.with_threshold(1.0),
                2 => r.with_policy(ExitPolicy::Entropy { max_nats: 1.0 }),
                _ => r.with_policy(ExitPolicy::TopTwoMargin { delta: 0.3 }),
            };
            if i + 1 == prompts.len() {
                r = r
                    .with_priority(10)
                    .with_deadline(Duration::from_millis(100));
            }
            r
        })
        .collect();

    let mut pool = EnginePool::new(
        state,
        PoolConfig {
            workers,
            engine: kind,
            policy: ExitPolicy::from_args(&args, 0.8)?,
            sched,
            max_concurrent: concurrent,
            prefix_cache_positions: args.usize_or("prefix-cache", 0),
            // The demo serves the default hot path: fused lane decode
            // over device-resident lane groups whenever the manifest
            // ships decode_lanes executables.
            lane_fusion: true,
            lane_residency: true,
        },
    );

    // Stream: print each request's first token the moment it lands
    // (the TTFT event), and the interleaved text as it grows.
    let tok = ByteTokenizer;
    let mut streams: HashMap<u64, String> = HashMap::new();
    let out = pool.run_batch_streamed(reqs, |ev| match ev {
        ServeEvent::Token { id, worker, token, .. } => {
            let text = streams.entry(*id).or_default();
            if text.is_empty() {
                println!("[stream] req {id} first token on worker {worker}");
            }
            text.push_str(&tok.decode(&[*token]));
        }
        ServeEvent::Done { id } => {
            println!(
                "[stream] req {id} done: {:?}",
                streams.get(id).map(String::as_str).unwrap_or("")
            );
        }
        ServeEvent::Failed { id } => println!("[stream] req {id} FAILED"),
    })?;
    pool.shutdown()?;

    for f in &out.failures {
        eprintln!("{f}");
    }
    for r in &out.responses {
        println!(
            "req {} (worker {}): {:?} [{} tok, queue {:.0}ms, TTFT {:.0}ms, \
             total {:.0}ms]",
            r.id,
            r.worker,
            r.output.text,
            r.output.tokens.len(),
            r.queue_seconds * 1e3,
            r.ttft_seconds * 1e3,
            r.total_seconds * 1e3,
        );
    }
    let m = &out.metrics;
    println!(
        "{} requests | {:.1} tok/s | p50 {:.0}ms p95 {:.0}ms | TTFT p50 \
         {:.0}ms p95 {:.0}ms | tok gap p50 {:.1}ms | early {:.0}% | exits \
         {:?} | deadline misses {}",
        m.requests,
        m.throughput_tps(),
        m.p50_latency_seconds * 1e3,
        m.p95_latency_seconds * 1e3,
        m.p50_ttft_seconds * 1e3,
        m.p95_ttft_seconds * 1e3,
        m.p50_token_gap_seconds * 1e3,
        100.0 * m.early_fraction(n_layers),
        m.exits.counts,
        m.deadline_misses,
    );
    if m.prefix.lookups() > 0 {
        println!(
            "prefix cache (--prefix-cache): hit rate {:.0}%, prefill \
             positions saved {}",
            100.0 * m.prefix_hit_rate(),
            m.prefill_positions_saved(),
        );
    }
    Ok(())
}
