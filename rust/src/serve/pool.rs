//! The engine worker pool: N workers, each owning a full inference engine
//! built *inside* its thread from a [`ModelState`] clone — the `xla`
//! runtime types are `Rc`-based and `!Send`, so only host-resident state
//! crosses thread boundaries (the same topology the training workers and
//! the pipelined engine's stage threads use).
//!
//! Workers are **continuous-batching** loops over resumable
//! [`DecodeSession`]s: each worker holds up to
//! [`PoolConfig::max_concurrent`] live sessions, round-robins one decode
//! step across them, and admits newly queued requests *between steps* —
//! mid-flight, not at batch close. Every emitted token is streamed to the
//! pool's event channel as it happens, so callers observe
//! [`ServeEvent::Token`] events long before a request completes
//! (time-to-first-token instead of whole-batch latency).
//!
//! All workers pull from one [`Scheduler`] queue and report per-request
//! completions (or failures) over the same mpsc channel the token stream
//! uses.
//!
//! With [`PoolConfig::prefix_cache_positions`] set, the pool keeps **one**
//! tiered snapshot store ([`TieredStore`]) of KV snapshots shared by
//! every worker (the store is `Sync`; a prefix prefilled by worker 0
//! serves admissions on worker 3): admissions restore the longest cached
//! prefix of their prompt and prefill only the suffix (shared
//! system-prompt traffic), with hit-rate and prefill-positions-saved
//! surfaced in [`ServeMetrics`]. Within
//! [`PoolConfig::device_tier_positions`], the store pins its hottest
//! entries device-resident; per-tier activity lands in
//! [`ServeMetrics::tier`].
//!
//! **Conversational serving**
//! ([`crate::serve::ServeRequest::with_conversation`]): when a
//! conversation-tagged turn completes, its end-of-turn KV state —
//! prompt ⧺ generated tokens — is snapshotted into the same store
//! *before* the session closes, keyed under the conversation's full
//! token history. The next turn's prompt textually extends that
//! history, so its admission restores everything and prefills only its
//! own new text (O(new turn), not O(history)). A pool-wide registry
//! tracks per-conversation activity and releases a conversation's
//! stored history once it idles past [`PoolConfig::convo_idle_ttl`]
//! (swept at batch start); turn/restore/snapshot/expiry counters land
//! in [`ServeMetrics::convo`], and store + device-tier + park-store
//! occupancy under one [`ServeMetrics::snapshot_memory`] gauge block.
//!
//! Exit decisions are [`ExitPolicy`] values end-to-end: the pool default
//! is [`PoolConfig::policy`], each request may override it
//! ([`crate::serve::ServeRequest::with_policy`]), and workers re-apply
//! the engine-resident policy before touching a session that wants a
//! different one.
//!
//! **Lane-fused batched decode** ([`PoolConfig::lane_fusion`], on by
//! default): instead of stepping live sessions one batch-1 forward pass
//! at a time, each round is planned by [`plan_round`] — sessions are
//! grouped by exit policy (each distinct policy applied once per round,
//! not once per adjacent policy change), and same-policy sessions with
//! no recompute deficit form greedy lane groups (largest manifest
//! `decode_lanes` size that fits) advanced through one batched XLA call
//! per stage ([`DecodeSession::step_fused`]); the remainder and
//! deficit-carrying sessions step solo. Fusion is output-invisible —
//! `tests/batched_decode_equivalence.rs` pins token-for-token and
//! exit-layer-for-exit-layer equality against unfused and serial
//! decoding — and its activity (fused vs solo steps, lane occupancy,
//! stages skipped) lands in [`ServeMetrics::lanes`].
//!
//! With [`PoolConfig::lane_residency`] (on by default, sequential
//! engine), fused lane groups are **device-resident**: the engine keeps
//! each group's lane-stacked KV caches on device across rounds, so a
//! warm round costs zero host cache traffic. The planner cooperates via
//! *stickiness* — each worker feeds last round's warm fused groups back
//! into [`plan_round`], which keeps a warm membership intact while
//! every member stays eligible (re-planning an identical group is a
//! free warm hit; any membership change costs a dissolve + re-gather).
//! Gather/scatter/warm-hit traffic lands in [`ServeMetrics::lanes`];
//! `tests/resident_lanes_equivalence.rs` pins output-invisibility and
//! the zero-steady-state-traffic property.
//!
//! **Interleaved pipelined serving**: on backends that interleave
//! windows ([`DecodeBackend::interleaves_windows`] — the pipelined
//! engine), a round submits every live session's width-1 window down
//! the stage chain before collecting any token
//! ([`DecodeSession::step_interleaved`]), so one session's deep-stage KV
//! back-fill overlaps another session's shallow-stage forward — the
//! pipeline bubbles a single session leaves are filled by its
//! neighbours. Exit policies ride per-session (captured by the chain at
//! admission), so mixed-policy sessions share rounds without
//! engine-resident policy swaps, and per-round in-flight occupancy
//! lands in [`ServeMetrics::interleave`].
//!
//! **SLO control plane** ([`PoolConfig::control`]): deadline-driven
//! preemption parks the lowest-value live session — a host-resident
//! [`ParkedSession`] snapshot in a strictly bounded pool-wide store —
//! when a queued deadlined request is about to blow its deadline, and
//! resumes it (on any worker) once a slot frees up; admission control
//! sheds or degrades requests at enqueue ([`ShedPolicy`]), with typed
//! [`ServeEvent::Shed`] events and [`BatchOutcome::sheds`] outcomes
//! instead of silent drops; weighted per-tenant fairness
//! ([`ControlConfig::tenant_weights`]) keeps bursty tenants at their
//! configured shares. Preempt/park/resume counters, shed/degrade
//! counts, p99 TTFT, deadline-miss rate, and per-tenant token shares
//! land in [`ServeMetrics::slo`] and [`ServeMetrics::tenants`];
//! `tests/slo_serving_equivalence.rs` pins park/resume
//! output-invisibility and the fault-injection containment
//! properties.
//!
//! **Self-healing serving** ([`ControlConfig::heal`]): a deterministic
//! chaos schedule ([`crate::serve::FaultPlan`], `serve-bench --chaos`)
//! can fire injected faults at every serving seam — fused lane
//! dispatch, interleaved submit/collect, stage threads, snapshot/
//! restore, prefix-cache restore, park/resume, solo decode. Live
//! sessions capture decode-time micro-checkpoints
//! ([`DecodeSession::checkpoint`]) into a bounded pool-wide store at a
//! fixed token cadence; a failed request opens a *recovery episode*
//! instead of failing: a backoff-delayed [`RecoveryTicket`] re-admits
//! it (on any worker) from its newest checkpoint — or from scratch —
//! with the already-streamed token prefix suppressed at re-emission,
//! so a recovered stream is token- and exit-layer-identical to a
//! fault-free run. A panicked or chain-poisoned engine is rebuilt in
//! place by the worker's supervisor (its sessions ride tickets onto
//! healthy engines); a worker flapping through
//! [`HealConfig::quarantine_after`] consecutive rebuilds quarantines,
//! shrinking pool capacity into the shed/degrade path. Injection,
//! observation, retry, recovery, checkpoint, restart, and quarantine
//! counters land in [`ServeMetrics::faults`];
//! `tests/chaos_recovery_equivalence.rs` pins the recovered-stream
//! equality and bounded-retry properties on both engines.

use std::collections::{BTreeMap, VecDeque};
use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::inference::{
    DecodeBackend, DecodeSession, ExitPolicy, ModelState, ParkedSession,
    PipelinedEngine, PrefixCacheStats, SequentialEngine, StepEvent,
    TierStats, TieredStore,
};

use super::faults::{
    classify_failure, injected_error, recovery_backoff, FaultInjector,
    FaultPlan, FaultSite,
};
use super::metrics::{
    ConvoCounters, ConvoStats, FaultCounters, FaultStats,
    InterleaveStats, LaneCounters, LaneStats, ServeMetrics, SloCounters,
    SloStats, SnapshotMemory,
};
use super::request::{ServeRequest, ServeResponse};
use super::scheduler::{
    Admission, Policy, SchedConfig, Scheduler, ShedPolicy, ShedReason,
};

/// Which engine each pool worker wraps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// [`SequentialEngine`] — KV recomputation ("recompute" on the CLI).
    Sequential,
    /// [`PipelinedEngine`] — thread-per-stage KV back-fill.
    Pipelined,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "recompute" | "sequential" => Ok(EngineKind::Sequential),
            "pipelined" => Ok(EngineKind::Pipelined),
            other => {
                bail!("unknown engine kind {other:?} (recompute|pipelined)")
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct PoolConfig {
    pub workers: usize,
    pub engine: EngineKind,
    /// Default exit policy; requests may override per-request
    /// ([`crate::serve::ServeRequest::with_policy`]).
    pub policy: ExitPolicy,
    /// Queue scheduling policy (FIFO / SPF / priority+deadline).
    pub sched: Policy,
    /// Live decode sessions each worker interleaves (continuous
    /// batching). Clamped to at least 1 and to what the engine supports
    /// ([`DecodeBackend::max_live_sessions`]). Both engines serve many
    /// sessions at once: the sequential engine's sessions own their KV
    /// caches, and the pipelined engine keys per-stage cache slots by
    /// session id.
    pub max_concurrent: usize,
    /// Pool-wide shared-prefix KV-cache budget in cached positions
    /// (0 disables). When set, the pool keeps one [`TieredStore`] of
    /// post-prefill and end-of-turn snapshots shared across all
    /// workers: admissions on any worker restore the longest cached
    /// prefix of their prompt and prefill only the suffix. Both engines
    /// participate ([`DecodeBackend::supports_cache_snapshots`]):
    /// sequential sessions snapshot their own caches, and the pipelined
    /// engine drains per-stage session slots over its snapshot
    /// protocol.
    pub prefix_cache_positions: usize,
    /// Device-resident tier budget of the snapshot store, in cached
    /// positions: the store's hottest entries (repeat-hit system
    /// prompts, active conversations) are pinned device-resident within
    /// this budget ([`TieredStore`]), immune to host-tier LRU pressure.
    /// 0 keeps the store host-only; no effect while
    /// `prefix_cache_positions` is 0.
    pub device_tier_positions: usize,
    /// Conversations ([`crate::serve::ServeRequest::with_conversation`])
    /// idle longer than this are expired: their registry entry and
    /// stored end-of-turn snapshot are released. The TTL is swept at
    /// batch start, so expiry takes effect between batches.
    pub convo_idle_ttl: Duration,
    /// Fuse same-policy live sessions into batched decode lane groups
    /// (manifest `decode_lanes` executables) instead of stepping each
    /// with its own batch-1 pass. On engines or manifests without lane
    /// executables this is a no-op; turning it off forces the solo path
    /// everywhere (the lanes-off baseline benches compare against).
    pub lane_fusion: bool,
    /// Keep fused lane groups device-resident across rounds (sequential
    /// engine): caches gathered once at group formation, stepped on
    /// device, scattered back only on lane departure — plus round
    /// stickiness in [`plan_round`], which keeps a warm group's
    /// membership intact while every member stays eligible. Off
    /// (serve-bench `--no-resident`), every fused step pays the full
    /// per-stage gather/scatter round-trip (the measurable baseline).
    /// No effect when `lane_fusion` is off or on interleaving engines.
    pub lane_residency: bool,
    /// SLO control plane: deadline-driven preemption, admission
    /// control / load shedding, per-tenant fairness. The default
    /// disables all of it.
    pub control: ControlConfig,
}

/// SLO control-plane configuration. [`ControlConfig::default`] turns
/// every feature off, so the pool behaves exactly as a control-plane-
/// free build.
#[derive(Debug, Clone)]
pub struct ControlConfig {
    /// Deadline-driven preemption: when a worker's live set is full
    /// and a queued deadlined request is within
    /// [`ControlConfig::preempt_horizon`] of its deadline, park the
    /// lowest-value live session (snapshot its KV caches to host) and
    /// admit the urgent request into the freed slot. Parked sessions
    /// resume — on whichever worker frees a slot first — and complete
    /// with their original token stream (park/resume is
    /// output-invisible).
    pub preempt: bool,
    /// Urgency horizon: a queued deadlined request counts as urgent
    /// once its remaining slack is at most this.
    pub preempt_horizon: Duration,
    /// Pool-wide bound on concurrently parked sessions; 0 disables
    /// preemption outright. The bound is strict — a park slot is
    /// reserved before the victim is snapshotted, and a parked
    /// snapshot is never dropped.
    pub park_capacity: usize,
    /// Admission control: queue-depth and predicted-TTFT bounds
    /// applied at enqueue ([`Scheduler::submit`]); `None` admits
    /// everything.
    pub shed: Option<ShedPolicy>,
    /// Weighted per-tenant fairness
    /// ([`crate::serve::ServeRequest::tenant`] indexes this table);
    /// empty disables fairness accounting.
    pub tenant_weights: Vec<f64>,
    /// Inject a control-plane fault (fault-injection tests): the
    /// selected seam fails with a typed error instead of running.
    pub fault: Option<ControlFault>,
    /// Self-healing serving: decode-time micro-checkpoints, bounded
    /// recovery retries, engine supervision, and the deterministic
    /// chaos schedule driving fault-injection benches. The default
    /// turns all of it off.
    pub heal: HealConfig,
}

impl Default for ControlConfig {
    fn default() -> ControlConfig {
        ControlConfig {
            preempt: false,
            preempt_horizon: Duration::from_millis(25),
            park_capacity: 2,
            shed: None,
            tenant_weights: Vec::new(),
            fault: None,
            heal: HealConfig::default(),
        }
    }
}

/// Self-healing configuration ([`ControlConfig::heal`]). The default
/// disables checkpointing, recovery, and chaos injection, so the pool
/// behaves exactly as a healing-free build: failures stay terminal
/// typed `Failed` outcomes.
#[derive(Debug, Clone, PartialEq)]
pub struct HealConfig {
    /// Capture a live session's KV micro-checkpoint every this many
    /// generated tokens (0 disables checkpointing; recovery then
    /// re-admits from scratch). Checkpoints ride the same
    /// [`ParkedSession`] host-snapshot path preemption parks use, and
    /// are non-destructive — the session keeps decoding.
    pub checkpoint_interval: usize,
    /// Bound on concurrently stored checkpoints (newest per request);
    /// a new request's capture is refused — not evicting others —
    /// once the store is full.
    pub checkpoint_capacity: usize,
    /// Re-admission attempts a failed request may consume before its
    /// recovery episode fails for good. 0 disables recovery entirely.
    pub max_retries: u32,
    /// Backoff before the first re-admission attempt, doubled per
    /// consumed retry ([`recovery_backoff`]).
    pub backoff: Duration,
    /// Quarantine a worker (it stops serving; capacity shrinks into
    /// the shed/degrade path) after this many consecutive engine
    /// rebuilds without a clean round in between.
    pub quarantine_after: u32,
    /// Deterministic chaos schedule (`serve-bench --chaos`): each
    /// worker derives independent per-site fault streams from the
    /// plan's pinned seed ([`FaultPlan::injector`]).
    pub chaos: Option<FaultPlan>,
}

impl Default for HealConfig {
    fn default() -> HealConfig {
        HealConfig {
            checkpoint_interval: 0,
            checkpoint_capacity: 8,
            max_retries: 0,
            backoff: Duration::from_millis(1),
            quarantine_after: 3,
            chaos: None,
        }
    }
}

impl HealConfig {
    /// Whether failed requests open recovery episodes instead of
    /// failing terminally.
    pub fn enabled(&self) -> bool {
        self.max_retries > 0
    }
}

/// Which control-plane seam [`ControlConfig::fault`] poisons.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlFault {
    /// The KV-cache snapshot fails while parking a preemption victim:
    /// the victim fails with a typed error, the urgent request still
    /// gets the freed slot, and every other session keeps serving.
    ParkSnapshot,
    /// The KV-cache restore fails while resuming a parked session: the
    /// resumed request fails with a typed error and the worker keeps
    /// serving.
    ResumeRestore,
}

/// The engine surface the pool needs: an exit-policy knob plus the
/// [`DecodeBackend`] that decode sessions step over.
trait PoolEngine {
    fn apply_policy(&mut self, policy: &ExitPolicy);
    fn backend(&mut self) -> &mut dyn DecodeBackend;
    /// Tear down engine-owned resources (threads), if any.
    fn finish(self: Box<Self>) {}
    /// Whether the engine can still serve rounds. A pipelined engine
    /// with a poisoned stage chain reports false; the supervisor then
    /// rebuilds it instead of letting every future round fail fast.
    fn healthy(&self) -> bool {
        true
    }
    /// Chaos hook: kill one engine-internal worker (a pipelined stage
    /// thread), returning whether the engine supports the fault.
    fn poison_stage(&mut self, _stage: usize) -> bool {
        false
    }
}

impl PoolEngine for SequentialEngine {
    fn apply_policy(&mut self, policy: &ExitPolicy) {
        self.policy = policy.clone();
    }

    fn backend(&mut self) -> &mut dyn DecodeBackend {
        self
    }
}

impl PoolEngine for PipelinedEngine {
    fn apply_policy(&mut self, policy: &ExitPolicy) {
        self.set_policy(policy.clone());
    }

    fn backend(&mut self) -> &mut dyn DecodeBackend {
        self
    }

    fn finish(self: Box<Self>) {
        (*self).shutdown();
    }

    fn healthy(&self) -> bool {
        !self.chain_down()
    }

    fn poison_stage(&mut self, stage: usize) -> bool {
        self.inject_stage_failure(stage).is_ok()
    }
}

enum WorkerEvent {
    /// Engine built and compiled; the worker is about to start serving.
    Ready { worker: usize },
    /// One token emitted for a live request (streamed mid-generation).
    Token { id: u64, worker: usize, token: i32, exit_layer: usize },
    Done(ServeResponse),
    /// One request failed; the worker keeps serving. `retries` echoes
    /// how many recovery re-admissions the request consumed before
    /// the terminal failure (0 without healing).
    Failed { id: u64, worker: usize, error: String, retries: u32 },
    /// The worker itself died (engine construction failed or it panicked).
    Fatal { worker: usize, error: String },
}

/// Streamed serving events, delivered to `run_batch_streamed` callbacks
/// in emission order (interleaved across requests and workers).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeEvent {
    /// Request `id` emitted one token at `exit_layer` on `worker`.
    Token { id: u64, worker: usize, token: i32, exit_layer: usize },
    /// Request `id` completed; its full [`ServeResponse`] is in the batch
    /// results.
    Done { id: u64 },
    /// Request `id` failed; the error is in the batch failures.
    Failed { id: u64 },
    /// Request `id` was rejected by admission control; its typed reason
    /// is in the batch sheds ([`BatchOutcome::sheds`]).
    Shed { id: u64 },
}

/// One request shed by admission control — a first-class outcome with a
/// typed reason, not a failure: the caller can retry, degrade, or route
/// elsewhere.
#[derive(Debug, Clone)]
pub struct Shed {
    pub id: u64,
    pub tenant: usize,
    pub reason: ShedReason,
}

/// One failed request of a batch.
#[derive(Debug, Clone)]
pub struct RequestFailure {
    pub id: u64,
    /// Worker that observed the failure; `None` when the request never
    /// reached one (e.g. rejected by a closed queue).
    pub worker: Option<usize>,
    pub error: String,
    /// Recovery re-admissions consumed before the episode gave up
    /// (0 without healing — the failure was terminal on first touch).
    pub retries: u32,
}

impl std::fmt::Display for RequestFailure {
    /// One-line report shared by every CLI/demo surface.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {} failed", self.id)?;
        if let Some(w) = self.worker {
            write!(f, " on worker {w}")?;
        }
        write!(f, ": {}", self.error)
    }
}

/// Per-request outcomes of one batch: one poisoned prompt no longer wipes
/// out the whole batch's results — it lands in `failures` while every
/// other response survives in `responses`.
#[derive(Debug)]
pub struct BatchOutcome {
    /// Successful responses, sorted by request id.
    pub responses: Vec<ServeResponse>,
    /// Failed requests, sorted by request id.
    pub failures: Vec<RequestFailure>,
    /// Requests rejected by admission control, sorted by request id.
    pub sheds: Vec<Shed>,
    /// Aggregate metrics over the successful responses.
    pub metrics: ServeMetrics,
}

/// One request's terminal outcome, for callers that want a single
/// id-ordered stream instead of the three sorted vectors of
/// [`BatchOutcome`].
#[derive(Debug, Clone)]
pub enum Outcome {
    Done(ServeResponse),
    Failed(RequestFailure),
    Shed(Shed),
}

impl Outcome {
    pub fn id(&self) -> u64 {
        match self {
            Outcome::Done(r) => r.id,
            Outcome::Failed(f) => f.id,
            Outcome::Shed(s) => s.id,
        }
    }
}

impl BatchOutcome {
    /// All per-request outcomes merged into one id-sorted stream.
    pub fn outcomes(&self) -> Vec<Outcome> {
        let mut all: Vec<Outcome> = Vec::with_capacity(
            self.responses.len() + self.failures.len() + self.sheds.len(),
        );
        all.extend(self.responses.iter().cloned().map(Outcome::Done));
        all.extend(self.failures.iter().cloned().map(Outcome::Failed));
        all.extend(self.sheds.iter().cloned().map(Outcome::Shed));
        all.sort_by_key(|o| o.id());
        all
    }
}

/// A pool of engine workers multiplexing a shared request queue.
///
/// Every submitted request produces exactly one `Done`/`Failed`
/// completion event (token events stream in between), and
/// [`EnginePool::run_batch`] consumes exactly one completion per request
/// it submitted — so batches never see a previous batch's responses.
/// Direct [`EnginePool::submit`] is for fire-and-forget use only and must
/// not be mixed with `run_batch` on the same pool.
pub struct EnginePool {
    cfg: PoolConfig,
    sched: Arc<Scheduler>,
    events: Receiver<WorkerEvent>,
    /// Events received while waiting for something else (e.g. a `Done`
    /// arriving during the readiness wait); consumed before `recv`.
    stash: VecDeque<WorkerEvent>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// The pool-wide tiered snapshot store shared by every worker (one
    /// element; empty when the cache is disabled). The pool keeps the
    /// handle so batch metrics can read its counters.
    prefix_stores: Vec<Arc<TieredStore>>,
    /// Pool-wide conversation plane: the id registry plus the
    /// turn/restore/expiry counters, shared by every worker.
    convo: Arc<ConvoPlane>,
    /// Pool-wide lane-fusion counters, shared by every worker.
    lane_counters: Arc<LaneCounters>,
    /// Pool-wide SLO control-plane counters (preempt/park/resume),
    /// shared by every worker.
    slo_counters: Arc<SloCounters>,
    /// Bounded pool-wide store of preempted (parked) sessions — a
    /// session parked by one worker may resume on any other.
    park: Arc<ParkStore>,
    /// Pool-wide self-healing plane: micro-checkpoints plus the
    /// recovery tickets of open episodes, shared by every worker.
    heal: Arc<HealPlane>,
    /// Pool-wide fault/recovery counters, shared by every worker.
    fault_counters: Arc<FaultCounters>,
    /// Workers that have not reported `Fatal`.
    alive: usize,
    /// Every live worker has reported `Ready`.
    ready: bool,
}

impl EnginePool {
    /// Spawn `cfg.workers` engine workers over clones of `state`. Engine
    /// construction (compiling the stage executables) happens inside each
    /// worker thread; construction failures surface on the next
    /// [`EnginePool::run_batch`].
    pub fn new(state: ModelState, cfg: PoolConfig) -> EnginePool {
        assert!(cfg.workers > 0, "pool needs at least one worker");
        let sched = Arc::new(Scheduler::new_with(SchedConfig {
            policy: cfg.sched,
            shed: cfg.control.shed.clone(),
            tenant_weights: cfg.control.tenant_weights.clone(),
        }));
        let (tx, events) = channel::<WorkerEvent>();
        // One store for the whole pool: the store is `Sync` (internal
        // lock), so sharing it lets a prefix prefilled on one worker
        // serve admissions on every other, and the position budget
        // bounds the pool rather than budget x workers.
        let prefix_stores: Vec<Arc<TieredStore>> =
            if cfg.prefix_cache_positions > 0 {
                vec![Arc::new(TieredStore::new(
                    cfg.prefix_cache_positions,
                    cfg.device_tier_positions,
                ))]
            } else {
                Vec::new()
            };
        let lane_counters = Arc::new(LaneCounters::default());
        let slo_counters = Arc::new(SloCounters::default());
        let park = Arc::new(ParkStore::new(cfg.control.park_capacity));
        let heal_plane = Arc::new(HealPlane::new(
            cfg.control.heal.checkpoint_capacity,
        ));
        let fault_counters = Arc::new(FaultCounters::default());
        let convo = Arc::new(ConvoPlane::new(cfg.convo_idle_ttl));
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let sched = Arc::clone(&sched);
            let tx = tx.clone();
            let state = state.clone();
            let cfg = cfg.clone();
            let store = prefix_stores.first().cloned();
            let counters = Arc::clone(&lane_counters);
            let slo = Arc::clone(&slo_counters);
            let park = Arc::clone(&park);
            let heal = Arc::clone(&heal_plane);
            let faults = Arc::clone(&fault_counters);
            let convo = Arc::clone(&convo);
            let handle = std::thread::Builder::new()
                .name(format!("serve-{w}"))
                .spawn(move || {
                    worker_main(
                        w, state, cfg, sched, tx, store, counters, slo,
                        park, heal, faults, convo,
                    )
                })
                .expect("spawn serve worker");
            workers.push(handle);
        }
        // Workers hold the only event senders, so `events.recv` errors
        // out instead of hanging if every worker dies.
        drop(tx);
        let alive = workers.len();
        EnginePool {
            cfg,
            sched,
            events,
            stash: VecDeque::new(),
            workers,
            prefix_stores,
            convo,
            lane_counters,
            slo_counters,
            park,
            heal: heal_plane,
            fault_counters,
            alive,
            ready: false,
        }
    }

    /// Lifetime self-healing counters of the pool — injections,
    /// observed faults, retries, recoveries, checkpoints, restarts,
    /// quarantines (per-batch deltas are in [`ServeMetrics::faults`]).
    pub fn fault_stats(&self) -> FaultStats {
        self.fault_counters.stats()
    }

    /// Lifetime SLO control-plane counters (per-batch deltas are in
    /// [`ServeMetrics::slo`]; shed/degrade counts are folded in at
    /// metrics-assembly time, so read batch metrics for those).
    pub fn slo_stats(&self) -> SloStats {
        self.slo_counters.stats()
    }

    /// Sessions currently parked (preempted, awaiting resume).
    pub fn parked_sessions(&self) -> usize {
        self.park.len()
    }

    /// Lifetime lane-fusion counters of the pool (per-batch deltas are
    /// in [`ServeMetrics::lanes`]).
    pub fn lane_stats(&self) -> LaneStats {
        self.lane_counters.stats()
    }

    /// Lifetime interleaved-round counters of the pool (per-batch deltas
    /// are in [`ServeMetrics::interleave`]).
    pub fn interleave_stats(&self) -> InterleaveStats {
        self.lane_counters.interleave_stats()
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// The pool's shared tiered snapshot store as a one-element slice
    /// (empty when the cache is disabled). Handles stay valid across
    /// [`EnginePool::shutdown`], so tests can assert pin/budget
    /// invariants after the workers exit.
    pub fn prefix_stores(&self) -> &[Arc<TieredStore>] {
        &self.prefix_stores
    }

    /// Lifetime host-tier prefix KV-cache counters of the shared store.
    pub fn prefix_stats(&self) -> PrefixCacheStats {
        let mut agg = PrefixCacheStats::default();
        for st in &self.prefix_stores {
            agg.merge(&st.stats());
        }
        agg
    }

    /// Lifetime device-tier counters of the shared store (per-batch
    /// deltas are in [`ServeMetrics::tier`]).
    pub fn tier_stats(&self) -> TierStats {
        let mut agg = TierStats::default();
        for st in &self.prefix_stores {
            agg.merge(&st.tier_stats());
        }
        agg
    }

    /// Lifetime conversation counters of the pool (per-batch deltas are
    /// in [`ServeMetrics::convo`]).
    pub fn convo_stats(&self) -> ConvoStats {
        self.convo.counters.stats()
    }

    /// Conversations currently registered (served at least one turn and
    /// not yet expired).
    pub fn active_conversations(&self) -> usize {
        self.convo.active()
    }

    /// Snapshot-memory occupancy right now: the prefix/conversation
    /// store's host tier, its device-resident tier, and the preemption
    /// park store, under one gauge block (what
    /// [`ServeMetrics::snapshot_memory`] reports at batch close).
    pub fn snapshot_memory(&self) -> SnapshotMemory {
        let mut m = SnapshotMemory::default();
        for st in &self.prefix_stores {
            m.cached_entries += st.len();
            m.cached_positions += st.used_positions();
            m.cached_bytes += st.used_bytes();
            m.device_entries += st.device_len();
            m.device_positions += st.device_used_positions();
            m.device_bytes += st.device_bytes();
        }
        let (parked_entries, parked_bytes) = self.park.usage();
        m.parked_entries = parked_entries;
        m.parked_bytes = parked_bytes;
        let (checkpoint_entries, checkpoint_bytes) = self.heal.usage();
        m.checkpoint_entries = checkpoint_entries;
        m.checkpoint_bytes = checkpoint_bytes;
        m
    }

    /// Enqueue one request (non-blocking). Returns `false` when the pool
    /// has been shut down (the queue is closed) — the request was
    /// rejected, not queued.
    ///
    /// The response events stay in the pool's channel, and since workers
    /// now stream one `Token` event per generated token, an undrained
    /// channel grows by ~`max_new` events per request (not one): use
    /// `run_batch`/`run_batch_streamed` unless the pool is short-lived
    /// and results are truly never read.
    #[must_use]
    pub fn submit(&self, req: ServeRequest) -> bool {
        self.sched.push(req)
    }

    /// Next event, preferring ones stashed during the readiness wait.
    fn next_event(&mut self) -> Result<WorkerEvent> {
        if let Some(e) = self.stash.pop_front() {
            return Ok(e);
        }
        self.events
            .recv()
            .ok()
            .context("all pool workers exited unexpectedly")
    }

    /// Block until every live worker has built its engine (or died
    /// trying), so batch wall-clocks measure serving, not compilation.
    fn wait_ready(&mut self) -> Result<()> {
        if self.ready {
            return Ok(());
        }
        let mut pending = self.workers.len();
        let mut last_error = String::new();
        while pending > 0 {
            match self.next_event()? {
                WorkerEvent::Ready { .. } => pending -= 1,
                WorkerEvent::Fatal { worker, error } => {
                    pending -= 1;
                    self.alive -= 1;
                    eprintln!("[serve] worker {worker} died: {error}");
                    last_error = error;
                }
                other => self.stash.push_back(other),
            }
        }
        if self.alive == 0 {
            bail!("every pool worker died; last error: {last_error}");
        }
        self.ready = true;
        Ok(())
    }

    /// Submit a whole request set and wait for every completion,
    /// returning per-request outcomes plus aggregate metrics over the
    /// successes.
    pub fn run_batch(
        &mut self,
        reqs: Vec<ServeRequest>,
    ) -> Result<BatchOutcome> {
        self.run_batch_streamed(reqs, |_| {})
    }

    /// [`EnginePool::run_batch`] with a streaming observer: `on_event` is
    /// called for every token/completion/failure in emission order, while
    /// the batch is still running — this is the serving layer's streaming
    /// response surface.
    ///
    /// Errors only on pool-level failures (every worker dead);
    /// per-request errors land in [`BatchOutcome::failures`].
    pub fn run_batch_streamed(
        &mut self,
        reqs: Vec<ServeRequest>,
        mut on_event: impl FnMut(&ServeEvent),
    ) -> Result<BatchOutcome> {
        self.wait_ready()?;
        if self.alive == 0 {
            bail!("no live pool workers");
        }
        // Conversations idle since the previous batch expire now,
        // releasing their stored end-of-turn snapshots — the TTL is
        // swept at batch boundaries, where no worker is mid-turn on an
        // expiring id.
        self.convo
            .expire_idle(self.prefix_stores.first().map(|s| s.as_ref()));
        let n = reqs.len();
        let t0 = Instant::now();
        // Store counters are monotonic across batches; remember where
        // they start so this batch's metrics report only its own
        // activity.
        let prefix_base: Vec<PrefixCacheStats> =
            self.prefix_stores.iter().map(|s| s.stats()).collect();
        let tier_base: Vec<TierStats> =
            self.prefix_stores.iter().map(|s| s.tier_stats()).collect();
        let convo_base = self.convo.counters.stats();
        let lane_base = self.lane_counters.stats();
        let interleave_base = self.lane_counters.interleave_stats();
        let slo_base = self.slo_counters.stats();
        let fault_base = self.fault_counters.stats();
        let shed_base = self.sched.shed_count();
        let degraded_base = self.sched.degraded_count();
        let mut failures: Vec<RequestFailure> = Vec::new();
        let mut sheds: Vec<Shed> = Vec::new();
        for r in reqs {
            let id = r.id;
            let tenant = r.tenant;
            // Staggered arrivals: hold this submission until the
            // request's offset from batch start elapses — workers keep
            // draining already-queued work in parallel, so one batch
            // can model a deadlined request arriving mid-flight.
            if let Some(off) = r.start_after {
                let elapsed = t0.elapsed();
                if off > elapsed {
                    std::thread::sleep(off - elapsed);
                }
            }
            match self.sched.submit(r) {
                Admission::Queued | Admission::Degraded { .. } => {}
                Admission::Shed(reason) => {
                    // Shedding is a first-class outcome, not a failure:
                    // the observer sees it immediately, and the typed
                    // reason lands in `BatchOutcome::sheds`.
                    on_event(&ServeEvent::Shed { id });
                    sheds.push(Shed { id, tenant, reason });
                }
                Admission::Closed => {
                    // The observer must see every failure, including
                    // ones that never reached a worker.
                    on_event(&ServeEvent::Failed { id });
                    failures.push(RequestFailure {
                        id,
                        worker: None,
                        error: "request rejected: pool queue is closed"
                            .into(),
                        retries: 0,
                    });
                }
            }
        }
        let mut responses = Vec::with_capacity(n);
        while responses.len() + failures.len() + sheds.len() < n {
            match self.next_event()? {
                WorkerEvent::Token { id, worker, token, exit_layer } => {
                    on_event(&ServeEvent::Token {
                        id,
                        worker,
                        token,
                        exit_layer,
                    });
                }
                WorkerEvent::Done(r) => {
                    on_event(&ServeEvent::Done { id: r.id });
                    responses.push(r);
                }
                WorkerEvent::Failed { id, worker, error, retries } => {
                    on_event(&ServeEvent::Failed { id });
                    failures.push(RequestFailure {
                        id,
                        worker: Some(worker),
                        error,
                        retries,
                    });
                }
                WorkerEvent::Fatal { worker, error } => {
                    self.alive -= 1;
                    if self.alive == 0 {
                        bail!(
                            "every pool worker died with requests \
                             outstanding; last error (worker {worker}): \
                             {error}"
                        );
                    }
                    eprintln!("[serve] worker {worker} died: {error}");
                }
                WorkerEvent::Ready { .. } => {}
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        responses.sort_by_key(|r| r.id);
        failures.sort_by_key(|f| f.id);
        sheds.sort_by_key(|s| s.id);
        let mut metrics = ServeMetrics::from_responses(&responses, wall);
        for (store, base) in self.prefix_stores.iter().zip(&prefix_base) {
            metrics.prefix.merge(&store.stats().since(base));
        }
        for (store, base) in self.prefix_stores.iter().zip(&tier_base) {
            metrics.tier.merge(&store.tier_stats().since(base));
        }
        metrics.convo = self.convo.counters.stats().since(&convo_base);
        metrics.snapshot_memory = self.snapshot_memory();
        metrics.lanes = self.lane_counters.stats().since(&lane_base);
        metrics.interleave = self
            .lane_counters
            .interleave_stats()
            .since(&interleave_base);
        metrics.slo = self.slo_counters.stats().since(&slo_base);
        metrics.faults =
            self.fault_counters.stats().since(&fault_base);
        metrics.slo.shed =
            self.sched.shed_count().saturating_sub(shed_base);
        metrics.slo.degraded =
            self.sched.degraded_count().saturating_sub(degraded_base);
        Ok(BatchOutcome { responses, failures, sheds, metrics })
    }

    /// Close the queue, drain, and join every worker.
    pub fn shutdown(mut self) -> Result<()> {
        self.sched.close();
        for (i, h) in std::mem::take(&mut self.workers)
            .into_iter()
            .enumerate()
        {
            if h.join().is_err() {
                bail!("serve worker {i} panicked");
            }
        }
        Ok(())
    }
}

impl Drop for EnginePool {
    /// Error paths that skip [`EnginePool::shutdown`] must still release
    /// the workers: closing the queue makes every `Scheduler::pop` return
    /// `None`, so the (detached) threads drain and exit instead of
    /// blocking forever on the condvar.
    fn drop(&mut self) {
        self.sched.close();
    }
}

/// One live request on a worker: its resumable session plus stream-timing
/// state.
struct Live {
    id: u64,
    /// Exit policy this request decodes under (request override or the
    /// pool default).
    policy: ExitPolicy,
    session: DecodeSession,
    queue_seconds: f64,
    /// The request's relative deadline, echoed into the response for
    /// deadline-miss accounting.
    deadline: Option<Duration>,
    /// Scheduling priority, kept live so preemption can rank sessions
    /// by value.
    priority: i32,
    /// Tenant id, echoed into the response for per-tenant shares.
    tenant: usize,
    /// Conversation id: on completion the session's end-of-turn KV
    /// state is snapshotted into the pool store under this id's
    /// registry entry.
    conversation: Option<u64>,
    /// When the worker admitted (and prefilled) the request.
    admitted: Instant,
    /// Last token emission (admission before the first token).
    last_event: Instant,
    /// Per-token emission gaps; `[0]` spans admission to first token.
    token_seconds: Vec<f64>,
    /// Prompt and budget, kept host-side so a recovery ticket can
    /// re-admit the request from scratch after an engine loss.
    prompt: String,
    max_new: usize,
    /// Tokens already streamed to the client (drives replay
    /// suppression after a recovery).
    emitted: usize,
    /// Replayed tokens still to swallow: a recovery restored a state
    /// older than what the client saw, and the re-decoded prefix must
    /// not be emitted twice ([`stream_token`]).
    suppress: usize,
    /// Recovery re-admissions this request has consumed.
    retries: u32,
    /// Generated-token count at the last stored micro-checkpoint.
    last_checkpoint: usize,
}

/// A parked (preempted) session: everything needed to rebuild the
/// request's `Live` entry on whichever worker resumes it. Holds only
/// host-resident state ([`ParkedSession`]), so entries cross worker
/// threads freely.
struct ParkedEntry {
    id: u64,
    tenant: usize,
    priority: i32,
    /// Relative deadline (for the eventual response).
    deadline: Option<Duration>,
    /// Absolute deadline (for resume ordering).
    due: Option<Instant>,
    policy: ExitPolicy,
    /// Conversation id, carried across park/resume so the resumed turn
    /// still snapshots at completion.
    conversation: Option<u64>,
    queue_seconds: f64,
    admitted: Instant,
    token_seconds: Vec<f64>,
    /// Prompt, budget, stream position, and consumed retries, carried
    /// so a failed resume can still open a recovery episode.
    prompt: String,
    max_new: usize,
    emitted: usize,
    retries: u32,
    parked: ParkedSession,
}

/// `a` outranks `b` for resume order: higher priority first, then
/// deadlined before deadline-less, then earlier deadline, then lower
/// id.
fn higher_value(a: &ParkedEntry, b: &ParkedEntry) -> bool {
    if a.priority != b.priority {
        return a.priority > b.priority;
    }
    match (a.due, b.due) {
        (Some(_), None) => true,
        (None, Some(_)) => false,
        (Some(x), Some(y)) if x != y => x < y,
        _ => a.id < b.id,
    }
}

/// The pool-wide bounded store of preempted sessions. A slot is
/// reserved *before* a victim is parked (inside the scheduler's urgent
/// pop, so the room check cannot race another worker's preemption) and
/// the insert itself is infallible — a parked snapshot is never
/// dropped, and parked + reserved never exceeds capacity.
struct ParkStore {
    inner: Mutex<ParkState>,
}

#[derive(Default)]
struct ParkState {
    entries: Vec<ParkedEntry>,
    reserved: usize,
    capacity: usize,
    peak: usize,
}

impl ParkStore {
    fn new(capacity: usize) -> ParkStore {
        ParkStore {
            inner: Mutex::new(ParkState {
                capacity,
                ..ParkState::default()
            }),
        }
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Occupancy gauge: parked entries and the host bytes their
    /// snapshots hold.
    fn usage(&self) -> (usize, usize) {
        let st = self.inner.lock().unwrap();
        (
            st.entries.len(),
            st.entries.iter().map(|e| e.parked.snapshot_bytes()).sum(),
        )
    }

    /// Most sessions parked at once over the store's lifetime.
    #[cfg(test)]
    fn peak(&self) -> usize {
        self.inner.lock().unwrap().peak
    }

    /// Claim one slot ahead of parking; `false` when the store (parked
    /// + outstanding reservations) is at capacity.
    fn try_reserve(&self) -> bool {
        let mut st = self.inner.lock().unwrap();
        if st.entries.len() + st.reserved < st.capacity {
            st.reserved += 1;
            true
        } else {
            false
        }
    }

    /// Release a reservation that will not be used (the park failed
    /// before producing a snapshot).
    fn cancel_reservation(&self) {
        let mut st = self.inner.lock().unwrap();
        st.reserved = st.reserved.saturating_sub(1);
    }

    /// Park into a previously reserved slot (infallible — the
    /// reservation made room). Returns the store's occupancy after the
    /// insert.
    fn park_reserved(&self, e: ParkedEntry) -> usize {
        let mut st = self.inner.lock().unwrap();
        st.reserved = st.reserved.saturating_sub(1);
        st.entries.push(e);
        st.peak = st.peak.max(st.entries.len());
        st.entries.len()
    }

    /// Remove and return the highest-value parked session
    /// ([`higher_value`]).
    fn take_best(&self) -> Option<ParkedEntry> {
        let mut st = self.inner.lock().unwrap();
        let mut best: Option<usize> = None;
        for (i, e) in st.entries.iter().enumerate() {
            best = Some(match best {
                None => i,
                Some(b) if higher_value(e, &st.entries[b]) => i,
                Some(b) => b,
            });
        }
        best.map(|i| st.entries.remove(i))
    }
}

/// Everything needed to re-admit a failed request on a healthy
/// engine: the original request's identity and accounting, plus how
/// many tokens its client has already seen (`emitted` — the replayed
/// prefix is suppressed at re-emission) and how many re-admission
/// attempts its episode has consumed (`retries`). Host-resident only,
/// so tickets cross worker threads freely; the matching KV
/// micro-checkpoint, if one was captured, lives in the [`HealPlane`]
/// checkpoint store.
struct RecoveryTicket {
    id: u64,
    tenant: usize,
    priority: i32,
    deadline: Option<Duration>,
    policy: ExitPolicy,
    conversation: Option<u64>,
    queue_seconds: f64,
    admitted: Instant,
    token_seconds: Vec<f64>,
    prompt: String,
    max_new: usize,
    /// Tokens already streamed to the client before the fault.
    emitted: usize,
    /// Re-admission attempts consumed so far.
    retries: u32,
    /// Exponential-backoff gate: the ticket is not due before this.
    not_before: Instant,
}

/// What [`HealPlane::take_due`] found.
enum TicketPoll {
    /// The earliest-due ticket, removed from the plane.
    Due(RecoveryTicket),
    /// Tickets pending, none due yet: the earliest is this far away.
    Waiting(Duration),
    Empty,
}

/// The pool-wide self-healing plane: decode-time micro-checkpoints
/// (bounded, newest per request) plus the recovery tickets of open
/// episodes. Shared by every worker — a session checkpointed on one
/// worker re-admits on whichever worker frees a slot first, the same
/// topology as the park store.
struct HealPlane {
    inner: Mutex<HealState>,
}

#[derive(Default)]
struct HealState {
    checkpoints: BTreeMap<u64, ParkedSession>,
    capacity: usize,
    pending: Vec<RecoveryTicket>,
}

impl HealPlane {
    fn new(capacity: usize) -> HealPlane {
        HealPlane {
            inner: Mutex::new(HealState {
                capacity,
                ..HealState::default()
            }),
        }
    }

    /// Poison-tolerant lock: the plane only ever runs collection ops
    /// under the lock, so a worker that panicked while holding it left
    /// consistent state — recovery must not lose the healing layer to
    /// the very fault it exists to absorb.
    fn lock(&self) -> std::sync::MutexGuard<'_, HealState> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Store (or refresh) request `id`'s newest micro-checkpoint.
    /// Refreshing an existing entry is always allowed; a new id is
    /// refused once `capacity` checkpoints are held (strict bound,
    /// no eviction of other requests' restore points). Returns
    /// whether the checkpoint was kept.
    fn store_checkpoint(&self, id: u64, snap: ParkedSession) -> bool {
        let mut st = self.lock();
        if !st.checkpoints.contains_key(&id)
            && st.checkpoints.len() >= st.capacity
        {
            return false;
        }
        st.checkpoints.insert(id, snap);
        true
    }

    /// A copy of `id`'s latest checkpoint: recovery attempts may run
    /// more than once, so the stored entry survives until the request
    /// reaches a terminal outcome.
    fn checkpoint(&self, id: u64) -> Option<ParkedSession> {
        self.lock().checkpoints.get(&id).cloned()
    }

    /// The request reached a terminal outcome: release its checkpoint.
    fn drop_checkpoint(&self, id: u64) {
        self.lock().checkpoints.remove(&id);
    }

    fn submit(&self, t: RecoveryTicket) {
        self.lock().pending.push(t);
    }

    fn has_pending(&self) -> bool {
        !self.lock().pending.is_empty()
    }

    /// Remove and return the earliest-due ticket at `now`, or report
    /// how long until one becomes due.
    fn take_due(&self, now: Instant) -> TicketPoll {
        let mut st = self.lock();
        if st.pending.is_empty() {
            return TicketPoll::Empty;
        }
        let mut best = 0;
        for i in 1..st.pending.len() {
            if st.pending[i].not_before < st.pending[best].not_before {
                best = i;
            }
        }
        let due = st.pending[best].not_before;
        if due <= now {
            TicketPoll::Due(st.pending.swap_remove(best))
        } else {
            TicketPoll::Waiting(due - now)
        }
    }

    /// Remove every pending ticket (quarantine: the caller fails each
    /// with a terminal event, so no episode is left open).
    fn drain_pending(&self) -> Vec<RecoveryTicket> {
        std::mem::take(&mut self.lock().pending)
    }

    /// Occupancy gauge: checkpoints held and the host bytes their
    /// snapshots pin.
    fn usage(&self) -> (usize, usize) {
        let st = self.lock();
        (
            st.checkpoints.len(),
            st.checkpoints.values().map(|p| p.snapshot_bytes()).sum(),
        )
    }
}

/// One registered conversation: its activity clock plus the store key
/// of its latest end-of-turn snapshot.
struct ConvoEntry {
    /// Last turn activity (admission or completion).
    last_active: Instant,
    /// Store key (prompt ⧺ generated tokens) of the latest end-of-turn
    /// snapshot, kept so expiry — and replacement by the next turn's
    /// snapshot — can release it.
    last_key: Option<Vec<i32>>,
}

/// The pool-wide conversation plane: a registry of active conversation
/// ids plus the counters batch metrics are cut from. Workers touch it
/// at admission (restore accounting) and turn completion (end-of-turn
/// snapshot bookkeeping); the pool sweeps the idle TTL at batch start.
struct ConvoPlane {
    registry: Mutex<BTreeMap<u64, ConvoEntry>>,
    counters: ConvoCounters,
    /// Conversations idle past this expire
    /// ([`PoolConfig::convo_idle_ttl`]).
    ttl: Duration,
}

impl ConvoPlane {
    fn new(ttl: Duration) -> ConvoPlane {
        ConvoPlane {
            registry: Mutex::new(BTreeMap::new()),
            counters: ConvoCounters::default(),
            ttl,
        }
    }

    /// Whether `id` already completed a turn (so this admission is a
    /// follow-up), refreshing its activity clock when so.
    fn touch(&self, id: u64) -> bool {
        let mut reg = self.registry.lock().unwrap();
        match reg.get_mut(&id) {
            Some(e) => {
                e.last_active = Instant::now();
                true
            }
            None => false,
        }
    }

    /// Record a completed turn: register (or refresh) the conversation
    /// and remember its latest snapshot key. The previous turn's
    /// snapshot — a strict prefix of the new one, useless once the
    /// deeper entry exists — is released from the store.
    fn complete_turn(
        &self,
        id: u64,
        key: Option<Vec<i32>>,
        store: Option<&TieredStore>,
    ) {
        let now = Instant::now();
        let prev = {
            let mut reg = self.registry.lock().unwrap();
            let e = reg.entry(id).or_insert_with(|| ConvoEntry {
                last_active: now,
                last_key: None,
            });
            e.last_active = now;
            match key {
                Some(k) if e.last_key.as_ref() != Some(&k) => {
                    e.last_key.replace(k)
                }
                // No new snapshot stored (or the key did not change):
                // the previous one stays the conversation's restore
                // point.
                _ => None,
            }
        };
        if let (Some(prev), Some(st)) = (prev, store) {
            st.remove(&prev);
        }
    }

    /// Expire conversations idle past the TTL, releasing their stored
    /// end-of-turn snapshots.
    fn expire_idle(&self, store: Option<&TieredStore>) {
        let now = Instant::now();
        let expired: Vec<Vec<i32>> = {
            let mut reg = self.registry.lock().unwrap();
            let dead: Vec<u64> = reg
                .iter()
                .filter(|(_, e)| {
                    now.duration_since(e.last_active) > self.ttl
                })
                .map(|(&id, _)| id)
                .collect();
            let mut keys = Vec::new();
            for id in &dead {
                if let Some(e) = reg.remove(id) {
                    keys.extend(e.last_key);
                }
            }
            if !dead.is_empty() {
                self.counters.record_expired(dead.len() as u64);
            }
            keys
        };
        if let Some(st) = store {
            for k in expired {
                st.remove(&k);
            }
        }
    }

    /// Conversations currently registered.
    fn active(&self) -> usize {
        self.registry.lock().unwrap().len()
    }
}

/// The value signals preemption reads from one live session.
#[derive(Debug, Clone, Copy)]
struct VictimInfo {
    /// Scheduling priority (higher = more valuable).
    priority: i32,
    /// Absolute deadline, when the request has one.
    due: Option<Instant>,
}

/// Reconstruct each live session's absolute deadline (submission time
/// ≈ admission minus queue wait, plus the relative deadline).
fn victim_infos(live: &[Live]) -> Vec<VictimInfo> {
    live.iter()
        .map(|l| VictimInfo {
            priority: l.priority,
            due: l.deadline.map(|d| {
                let queued =
                    Duration::from_secs_f64(l.queue_seconds.max(0.0));
                l.admitted.checked_sub(queued).unwrap_or(l.admitted) + d
            }),
        })
        .collect()
}

/// Pick the live session an urgent request may displace: the
/// lowest-value *eligible* one, or `None`. A session is eligible only
/// when it is strictly lower-value than the urgent request — lower
/// priority, or equal priority with no deadline at stake, or equal
/// priority with more than `horizon` of slack left (the urgent
/// request, by construction of the urgent pop, has less). Among
/// eligible sessions the lowest value loses its slot: lowest priority
/// first, then deadline-less before deadlined, then the latest
/// deadline (most slack to spare).
fn preemption_victim(
    live: &[VictimInfo],
    urgent_priority: i32,
    now: Instant,
    horizon: Duration,
) -> Option<usize> {
    let eligible = |v: &VictimInfo| {
        v.priority < urgent_priority
            || (v.priority == urgent_priority
                && match v.due {
                    None => true,
                    Some(due) => {
                        due.saturating_duration_since(now) > horizon
                    }
                })
    };
    let mut best: Option<(usize, VictimInfo)> = None;
    for (i, v) in live.iter().enumerate() {
        if !eligible(v) {
            continue;
        }
        let lower = match &best {
            None => true,
            Some((_, b)) => {
                if v.priority != b.priority {
                    v.priority < b.priority
                } else {
                    match (v.due, b.due) {
                        (None, Some(_)) => true,
                        (Some(x), Some(y)) => x > y,
                        _ => false,
                    }
                }
            }
        };
        if lower {
            best = Some((i, *v));
        }
    }
    best.map(|(i, _)| i)
}

/// Per-worker bundle of the self-healing layer: the shared heal plane
/// and fault counters, plus this worker's deterministic chaos
/// schedule (an independent per-site [`FaultInjector`] stream per
/// worker) and its supervision flap counter.
struct HealRuntime {
    cfg: HealConfig,
    plane: Arc<HealPlane>,
    counters: Arc<FaultCounters>,
    chaos: Option<FaultInjector>,
    /// Engine rebuilds without a clean round in between; quarantine
    /// trips when this exceeds [`HealConfig::quarantine_after`].
    consecutive_failures: u32,
}

impl HealRuntime {
    /// Whether failures open recovery episodes.
    fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Roll the chaos schedule at `site`; a firing draw is counted as
    /// injected.
    fn fire(&mut self, site: FaultSite) -> bool {
        match self.chaos.as_mut() {
            Some(inj) if inj.fire(site) => {
                self.counters.record_injected(site);
                true
            }
            _ => false,
        }
    }

    /// Which stage a fired [`FaultSite::StagePanic`] kills.
    fn pick_stage(&mut self, n_stages: usize) -> usize {
        self.chaos
            .as_mut()
            .map(|inj| inj.pick(FaultSite::StagePanic, n_stages))
            .unwrap_or(0)
    }
}

/// The continuous-batching worker loop: admit queued requests into free
/// session slots (blocking only when fully idle), then give every live
/// session one decode step, streaming each token as it is emitted.
/// With preemption on, a full live set additionally yields its
/// lowest-value session to any queued deadlined request inside its
/// urgency horizon; parked sessions resume into free slots whenever the
/// queue is momentarily drained, and due recovery tickets re-admit
/// after them.
#[allow(clippy::too_many_arguments)]
fn worker_main(
    worker: usize,
    state: ModelState,
    cfg: PoolConfig,
    sched: Arc<Scheduler>,
    events: Sender<WorkerEvent>,
    store: Option<Arc<TieredStore>>,
    counters: Arc<LaneCounters>,
    slo: Arc<SloCounters>,
    park: Arc<ParkStore>,
    heal_plane: Arc<HealPlane>,
    faults: Arc<FaultCounters>,
    convo: Arc<ConvoPlane>,
) {
    let mut heal = HealRuntime {
        cfg: cfg.control.heal.clone(),
        plane: heal_plane,
        counters: faults,
        chaos: cfg
            .control
            .heal
            .chaos
            .as_ref()
            .map(|p| p.injector(worker)),
        consecutive_failures: 0,
    };
    let mut engine: Box<dyn PoolEngine> =
        match build_engine(state.clone(), &cfg) {
            Ok(e) => e,
            Err(e) => {
                events
                    .send(WorkerEvent::Fatal {
                        worker,
                        error: format!("{e:#}"),
                    })
                    .ok();
                return;
            }
        };
    events.send(WorkerEvent::Ready { worker }).ok();
    let max_live =
        cfg.max_concurrent.max(1).min(engine.backend().max_live_sessions());
    // Interleaving backends (the pipelined engine) take whole rounds
    // down the stage chain at once instead of fused lane groups or
    // round-robined solo steps.
    let interleaving = engine.backend().interleaves_windows();
    let mut live: Vec<Live> = Vec::new();
    // Engines read one resident policy; track it and re-apply before
    // touching a session that wants a different one.
    let mut current_policy = cfg.policy.clone();
    // Fused groups that stepped successfully last round, by request id —
    // fed back to `plan_round` as stickiness so device-resident lane
    // groups stay warm across rounds instead of being greedily
    // re-packed. The engine's traffic counter is monotonic; workers
    // fold per-round deltas into the shared pool stats.
    let mut warm: Vec<Vec<u64>> = Vec::new();
    let mut traffic_base = engine.backend().lane_traffic();
    // Preemption needs host snapshots; without them (or with a zero
    // park budget) the control plane degrades to plain scheduling.
    let preempt_on = cfg.control.preempt
        && cfg.control.park_capacity > 0
        && engine.backend().supports_cache_snapshots();
    'serve: loop {
        // Admission: fill free slots. Block only when idle; poll with
        // `try_pop` while sessions are live, so queued requests join
        // mid-flight between decode steps instead of at batch close.
        // Parked sessions resume into slots the queue leaves free.
        while live.len() < max_live {
            let popped = if live.is_empty() {
                if park.is_empty() && !heal.plane.has_pending() {
                    match sched.pop() {
                        // Fully idle: block until work or close.
                        Some(x) => Some(x),
                        // Queue closed and drained: resume leftovers a
                        // late parker (or a recovery ticket) may have
                        // added before exiting.
                        None if park.is_empty()
                            && !heal.plane.has_pending() =>
                        {
                            break 'serve
                        }
                        None => None,
                    }
                } else {
                    // Idle with parked or recovering work: never block
                    // on the queue (every worker blocking would strand
                    // the parked session or ticket forever).
                    sched.try_pop()
                }
            } else if cfg.lane_fusion
                && !interleaving
                && cfg.sched != Policy::Priority
            {
                // Mid-flight: never stall live sessions. Lane-aware
                // admission — prefer requests whose effective policy
                // joins a live session's lane group over ones that
                // would open a fresh policy class, and within fresh
                // classes prefer predicted-shallow (exit-capable)
                // traffic, which packs into fused lanes. Skipped
                // under `Policy::Priority`, where urgency order wins.
                sched.try_pop_preferring(|r| {
                    let p = r.policy.as_ref().unwrap_or(&cfg.policy);
                    let joins_live =
                        live.iter().any(|l| l.policy == *p);
                    match (joins_live, p.may_exit()) {
                        (true, _) => 0,
                        (false, true) => 1,
                        (false, false) => 2,
                    }
                })
            } else {
                sched.try_pop()
            };
            let Some((req, queue_seconds)) = popped else {
                // Queue momentarily empty: pull parked work into the
                // free slot first, then due recovery tickets.
                match resume_parked(
                    worker,
                    engine.as_mut(),
                    &cfg,
                    &park,
                    &events,
                    &slo,
                    &counters,
                    &mut heal,
                    &mut current_policy,
                    &mut live,
                ) {
                    ResumeOutcome::Resumed => continue,
                    ResumeOutcome::Panicked { failed_id } => {
                        retire(worker, &events, failed_id, &live);
                        return;
                    }
                    ResumeOutcome::EngineSuspect => {
                        if !supervise(
                            worker,
                            &mut engine,
                            &state,
                            &cfg,
                            &events,
                            &mut heal,
                            &mut current_policy,
                            &mut live,
                            None,
                            "worker panicked during resume restore",
                        ) {
                            return;
                        }
                        warm.clear();
                        traffic_base = engine.backend().lane_traffic();
                        continue;
                    }
                    ResumeOutcome::Empty => {}
                }
                match recover_pending(
                    worker,
                    engine.as_mut(),
                    &events,
                    &mut heal,
                    &mut current_policy,
                    &counters,
                    &mut live,
                ) {
                    RecoverOutcome::Recovered => continue,
                    RecoverOutcome::EngineSuspect => {
                        if !supervise(
                            worker,
                            &mut engine,
                            &state,
                            &cfg,
                            &events,
                            &mut heal,
                            &mut current_policy,
                            &mut live,
                            None,
                            "worker panicked during recovery restore",
                        ) {
                            return;
                        }
                        warm.clear();
                        traffic_base = engine.backend().lane_traffic();
                        continue;
                    }
                    RecoverOutcome::Waiting(d) if live.is_empty() => {
                        // Nothing to serve until a ticket matures:
                        // sleep in short slices so queue work (or a
                        // close) is still noticed promptly.
                        std::thread::sleep(
                            d.min(Duration::from_millis(5)),
                        );
                        continue;
                    }
                    RecoverOutcome::Waiting(_) => break,
                    RecoverOutcome::Empty if live.is_empty() => continue,
                    RecoverOutcome::Empty => break,
                }
            };
            match admit_request(
                worker,
                engine.as_mut(),
                &cfg,
                store.as_deref(),
                &convo,
                &counters,
                &events,
                &mut heal,
                &mut current_policy,
                &mut live,
                req,
                queue_seconds,
            ) {
                AdmitOutcome::Continue => {}
                AdmitOutcome::EngineSuspect { panicked_id } => {
                    if !heal.enabled() {
                        retire(worker, &events, panicked_id, &live);
                        return;
                    }
                    if !supervise(
                        worker,
                        &mut engine,
                        &state,
                        &cfg,
                        &events,
                        &mut heal,
                        &mut current_policy,
                        &mut live,
                        None,
                        "worker panicked during admission",
                    ) {
                        return;
                    }
                    warm.clear();
                    traffic_base = engine.backend().lane_traffic();
                }
            }
        }
        // Deadline-driven preemption: the live set is full, so a queued
        // deadlined request inside its urgency horizon may displace the
        // lowest-value live session. The park-store slot is reserved
        // inside the scheduler's urgent pop, so the room check cannot
        // race another worker's preemption, and a popped urgent request
        // is guaranteed a victim (the live set is this thread's own).
        if preempt_on && live.len() >= max_live && !live.is_empty() {
            let infos = victim_infos(&live);
            let now = Instant::now();
            let horizon = cfg.control.preempt_horizon;
            let urgent = sched.pop_urgent_when(horizon, |r| {
                preemption_victim(&infos, r.priority, now, horizon)
                    .is_some()
                    && park.try_reserve()
            });
            if let Some((req, queue_seconds)) = urgent {
                match preemption_victim(&infos, req.priority, now, horizon)
                {
                    None => {
                        // Unreachable (the predicate above just held
                        // over the same inputs) — but never strand the
                        // request or the reservation.
                        park.cancel_reservation();
                        let id = req.id;
                        if !sched.push(req) {
                            events
                                .send(WorkerEvent::Failed {
                                    id,
                                    worker,
                                    error: "preemption aborted and the \
                                            queue is closed"
                                        .into(),
                                    retries: 0,
                                })
                                .ok();
                        }
                    }
                    Some(vi) => {
                        let victim = live.remove(vi);
                        let Live {
                            id: vid,
                            policy: vpolicy,
                            session,
                            queue_seconds: vqueue,
                            deadline: vdeadline,
                            priority: vprio,
                            tenant: vtenant,
                            conversation: vconvo,
                            admitted: vadmitted,
                            last_event: _,
                            token_seconds: vtokens,
                            prompt: vprompt,
                            max_new: vmax_new,
                            emitted: vemitted,
                            suppress: _,
                            retries: vretries,
                            last_checkpoint: _,
                        } = victim;
                        let park_fault = cfg.control.fault
                            == Some(ControlFault::ParkSnapshot)
                            || heal.fire(FaultSite::Park);
                        let parked = if park_fault {
                            // Injected fault: release the victim's
                            // backend state exactly as a real failed
                            // snapshot would have.
                            let mut s = session;
                            s.close(engine.backend());
                            Ok(Err(injected_error(FaultSite::Park)))
                        } else {
                            std::panic::catch_unwind(AssertUnwindSafe(
                                || session.park(engine.backend()),
                            ))
                        };
                        match parked {
                            Ok(Ok(p)) => {
                                slo.record_preemption();
                                let occupancy =
                                    park.park_reserved(ParkedEntry {
                                        id: vid,
                                        tenant: vtenant,
                                        priority: vprio,
                                        deadline: vdeadline,
                                        due: infos[vi].due,
                                        policy: vpolicy,
                                        conversation: vconvo,
                                        queue_seconds: vqueue,
                                        admitted: vadmitted,
                                        token_seconds: vtokens,
                                        prompt: vprompt,
                                        max_new: vmax_new,
                                        emitted: vemitted,
                                        retries: vretries,
                                        parked: p,
                                    });
                                slo.observe_parked(occupancy as u64);
                            }
                            Ok(Err(e)) => {
                                // Typed per-request failure (or, with
                                // healing on, a recovery episode): the
                                // victim fails or recovers alone; the
                                // urgent request still gets the slot
                                // and every other session keeps
                                // serving.
                                park.cancel_reservation();
                                slo.record_park_failure();
                                fail_or_ticket(
                                    worker,
                                    &events,
                                    &mut heal,
                                    RecoveryTicket {
                                        id: vid,
                                        tenant: vtenant,
                                        priority: vprio,
                                        deadline: vdeadline,
                                        policy: vpolicy,
                                        conversation: vconvo,
                                        queue_seconds: vqueue,
                                        admitted: vadmitted,
                                        token_seconds: vtokens,
                                        prompt: vprompt,
                                        max_new: vmax_new,
                                        emitted: vemitted,
                                        retries: vretries,
                                        not_before: Instant::now(),
                                    },
                                    &format!("park failed: {e:#}"),
                                );
                            }
                            Err(_) => {
                                park.cancel_reservation();
                                slo.record_park_failure();
                                if heal.enabled() {
                                    // Both casualties ride tickets;
                                    // the suspect engine is rebuilt
                                    // before serving on.
                                    fail_or_ticket(
                                        worker,
                                        &events,
                                        &mut heal,
                                        RecoveryTicket {
                                            id: vid,
                                            tenant: vtenant,
                                            priority: vprio,
                                            deadline: vdeadline,
                                            policy: vpolicy,
                                            conversation: vconvo,
                                            queue_seconds: vqueue,
                                            admitted: vadmitted,
                                            token_seconds: vtokens,
                                            prompt: vprompt,
                                            max_new: vmax_new,
                                            emitted: vemitted,
                                            retries: vretries,
                                            not_before: Instant::now(),
                                        },
                                        "park failed: worker panicked \
                                         during snapshot",
                                    );
                                    fail_or_ticket(
                                        worker,
                                        &events,
                                        &mut heal,
                                        RecoveryTicket {
                                            id: req.id,
                                            tenant: req.tenant,
                                            priority: req.priority,
                                            deadline: req.deadline,
                                            policy: req
                                                .policy
                                                .clone()
                                                .unwrap_or_else(|| {
                                                    cfg.policy.clone()
                                                }),
                                            conversation: req
                                                .conversation,
                                            queue_seconds,
                                            admitted: Instant::now(),
                                            token_seconds: Vec::new(),
                                            prompt: req.prompt.clone(),
                                            max_new: req.max_new,
                                            emitted: 0,
                                            retries: 0,
                                            not_before: Instant::now(),
                                        },
                                        "admission aborted: worker \
                                         panicked during park",
                                    );
                                    if !supervise(
                                        worker,
                                        &mut engine,
                                        &state,
                                        &cfg,
                                        &events,
                                        &mut heal,
                                        &mut current_policy,
                                        &mut live,
                                        None,
                                        "worker panicked during park",
                                    ) {
                                        return;
                                    }
                                    warm.clear();
                                    traffic_base =
                                        engine.backend().lane_traffic();
                                    continue 'serve;
                                }
                                events
                                    .send(WorkerEvent::Failed {
                                        id: req.id,
                                        worker,
                                        error: "admission aborted: \
                                                worker panicked during \
                                                park"
                                            .into(),
                                        retries: 0,
                                    })
                                    .ok();
                                retire(worker, &events, vid, &live);
                                return;
                            }
                        }
                        match admit_request(
                            worker,
                            engine.as_mut(),
                            &cfg,
                            store.as_deref(),
                            &convo,
                            &counters,
                            &events,
                            &mut heal,
                            &mut current_policy,
                            &mut live,
                            req,
                            queue_seconds,
                        ) {
                            AdmitOutcome::Continue => {}
                            AdmitOutcome::EngineSuspect {
                                panicked_id,
                            } => {
                                if !heal.enabled() {
                                    retire(
                                        worker, &events, panicked_id,
                                        &live,
                                    );
                                    return;
                                }
                                if !supervise(
                                    worker,
                                    &mut engine,
                                    &state,
                                    &cfg,
                                    &events,
                                    &mut heal,
                                    &mut current_policy,
                                    &mut live,
                                    None,
                                    "worker panicked during admission",
                                ) {
                                    return;
                                }
                                warm.clear();
                                traffic_base =
                                    engine.backend().lane_traffic();
                                continue 'serve;
                            }
                        }
                    }
                }
            }
        }
        if live.is_empty() {
            // Every admission this round failed; go back to waiting.
            continue;
        }
        // One decode step per live session per round, planned as
        // policy-ordered fused lane groups plus solo steps. Removals
        // are deferred to the round's end so the plan's indices stay
        // valid throughout.
        let classes = policy_classes(&live);
        let (lanes, fusable) = {
            let be = engine.backend();
            let lanes: Vec<usize> = if cfg.lane_fusion && !interleaving {
                be.decode_lanes().to_vec()
            } else {
                Vec::new()
            };
            let fusable: Vec<bool> = if lanes.is_empty() && !interleaving {
                vec![false; live.len()]
            } else {
                live.iter().map(|l| l.session.fusable(&*be)).collect()
            };
            (lanes, fusable)
        };
        let plan = if interleaving {
            // One interleaved group of every eligible session — the
            // chain handles mixed policies (each session's policy was
            // captured stage-side at admission), so no policy-class
            // split. The rest step solo: an ineligible session here is
            // out of budget or KV capacity, so its solo step only emits
            // `Finished` without touching the backend.
            let group: Vec<usize> =
                (0..live.len()).filter(|&i| fusable[i]).collect();
            let mut plan: Vec<Vec<usize>> = Vec::new();
            if !group.is_empty() {
                plan.push(group);
            }
            plan.extend(
                (0..live.len()).filter(|&i| !fusable[i]).map(|i| vec![i]),
            );
            plan
        } else {
            // Map last round's warm groups from request ids to current
            // live indices; a group with any departed member just
            // drops out (plan_round re-validates the rest).
            let sticky: Vec<Vec<usize>> = warm
                .iter()
                .filter_map(|g| {
                    g.iter()
                        .map(|id| live.iter().position(|l| l.id == *id))
                        .collect::<Option<Vec<usize>>>()
                })
                .collect();
            plan_round(&classes, &fusable, &lanes, &sticky)
        };
        // Sessions finished (Ok) or failed (Err(msg)) this round, by
        // live index.
        let mut retired: Vec<(usize, Option<String>)> = Vec::new();
        // Fused groups that step successfully this round (request ids).
        let mut next_warm: Vec<Vec<u64>> = Vec::new();
        // A worklist rather than a plain loop: a failed fused group is
        // re-queued as solo steps (see below).
        let mut queue: VecDeque<Vec<usize>> = plan.into_iter().collect();
        while let Some(group) = queue.pop_front() {
            let group = &group;
            let gpolicy = live[group[0]].policy.clone();
            // Interleaving backends read each session's policy from the
            // chain slot captured at admission; the engine-resident
            // policy only matters for future admissions, so rounds never
            // swap it.
            if !interleaving && gpolicy != current_policy {
                engine.apply_policy(&gpolicy);
                current_policy = gpolicy;
                counters.record_policy_apply();
            }
            if interleaving && fusable[group[0]] {
                // Interleaved stage-chain round: submit every member's
                // window, then collect every token — members overlap on
                // the chain, and the occupancy histogram records how
                // many were in flight together.
                // Chaos seam: a stage-thread "panic" poisons a pinned
                // stage of the chain before the round runs, so the
                // failure surfaces through the same typed path a real
                // stage death would take. Submit/collect-window faults
                // are synthesized as round errors before the backend is
                // touched, keeping every member's cache state intact.
                if heal.fire(FaultSite::StagePanic) {
                    let stage =
                        heal.pick_stage(engine.backend().n_stages());
                    engine.poison_stage(stage);
                }
                let injected = if heal.fire(FaultSite::SubmitWindow) {
                    Some(injected_error(FaultSite::SubmitWindow))
                } else if heal.fire(FaultSite::CollectWindow) {
                    Some(injected_error(FaultSite::CollectWindow))
                } else {
                    None
                };
                let mut members: Vec<(usize, &mut Live)> = live
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| group.contains(i))
                    .collect();
                let stepped = match injected {
                    Some(e) => Ok(Err(e)),
                    None => {
                        let mut sess: Vec<&mut DecodeSession> = members
                            .iter_mut()
                            .map(|(_, l)| &mut l.session)
                            .collect();
                        let be = engine.backend();
                        std::panic::catch_unwind(AssertUnwindSafe(|| {
                            DecodeSession::step_interleaved(be, &mut sess)
                        }))
                    }
                };
                match stepped {
                    Err(_) => {
                        // As in the solo panic arm: deliver the round's
                        // deferred outcomes, then fail the group and
                        // every other live session — or, when healing
                        // is on, ticket every casualty and rebuild the
                        // suspect engine in place.
                        drop(members);
                        let i = group[0];
                        let below =
                            retired.iter().filter(|(j, _)| *j < i).count();
                        settle_round(
                            worker,
                            &events,
                            engine.backend(),
                            &sched,
                            store.as_deref(),
                            &convo,
                            &mut heal,
                            &mut live,
                            retired,
                        );
                        let failed = live.remove(i - below);
                        if heal.enabled() {
                            if !supervise(
                                worker,
                                &mut engine,
                                &state,
                                &cfg,
                                &events,
                                &mut heal,
                                &mut current_policy,
                                &mut live,
                                Some(failed),
                                "worker panicked during decode",
                            ) {
                                return;
                            }
                            warm.clear();
                            traffic_base =
                                engine.backend().lane_traffic();
                            continue 'serve;
                        }
                        retire(worker, &events, failed.id, &live);
                        return;
                    }
                    Ok(Err(e)) => {
                        // A failed interleaved round leaves the chain's
                        // per-session state indeterminate — some members
                        // may have absorbed their token while others'
                        // windows never ran — so fail every member
                        // rather than retry against unknown caches. The
                        // worker itself keeps serving: a poisoned chain
                        // fails future rounds fast, and healthy chains
                        // (e.g. a malformed single window) carry on.
                        let msg =
                            format!("interleaved round failed: {e:#}");
                        drop(members);
                        for &i in group {
                            retired.push((i, Some(msg.clone())));
                        }
                    }
                    Ok(Ok(evs)) => {
                        counters.record_interleaved(group.len());
                        let now = Instant::now();
                        for ((i, l), ev) in members.iter_mut().zip(evs) {
                            let StepEvent::Token {
                                token,
                                exit_layer,
                                done,
                            } = ev
                            else {
                                // Fusable sessions always decode.
                                retired.push((*i, None));
                                continue;
                            };
                            stream_token(
                                worker,
                                &events,
                                &heal.counters,
                                l,
                                now,
                                token,
                                exit_layer,
                            );
                            if done.is_some() {
                                retired.push((*i, None));
                            }
                        }
                    }
                }
            } else if group.len() == 1 {
                let i = group[0];
                // Chaos seam: a solo decode fault is synthesized before
                // the backend runs, so the session's cache state stays
                // exactly as its last emitted token left it — the
                // micro-checkpoint (or a from-scratch re-run) replays
                // the suppressed tail bit-identically.
                let stepped = if heal.fire(FaultSite::Decode) {
                    Ok(Err(injected_error(FaultSite::Decode)))
                } else {
                    let l = &mut live[i];
                    let be = engine.backend();
                    std::panic::catch_unwind(AssertUnwindSafe(|| {
                        l.session.step(be)
                    }))
                };
                match stepped {
                    Err(_) => {
                        // The engine may be in a corrupt state: fail
                        // the stepped request and every other live one,
                        // then retire the worker — unless healing is
                        // on, in which case every casualty rides a
                        // recovery ticket and the engine is rebuilt.
                        // Outcomes that predate the panic still count —
                        // deliver the round's deferred
                        // completions/failures first.
                        let below =
                            retired.iter().filter(|(j, _)| *j < i).count();
                        settle_round(
                            worker,
                            &events,
                            engine.backend(),
                            &sched,
                            store.as_deref(),
                            &convo,
                            &mut heal,
                            &mut live,
                            retired,
                        );
                        let failed = live.remove(i - below);
                        if heal.enabled() {
                            if !supervise(
                                worker,
                                &mut engine,
                                &state,
                                &cfg,
                                &events,
                                &mut heal,
                                &mut current_policy,
                                &mut live,
                                Some(failed),
                                "worker panicked during decode",
                            ) {
                                return;
                            }
                            warm.clear();
                            traffic_base =
                                engine.backend().lane_traffic();
                            continue 'serve;
                        }
                        retire(worker, &events, failed.id, &live);
                        return;
                    }
                    Ok(Err(e)) => {
                        retired.push((i, Some(format!("{e:#}"))));
                    }
                    Ok(Ok(StepEvent::Token { token, exit_layer, done })) => {
                        counters.record_solo();
                        let now = Instant::now();
                        stream_token(
                            worker,
                            &events,
                            &heal.counters,
                            &mut live[i],
                            now,
                            token,
                            exit_layer,
                        );
                        if done.is_some() {
                            retired.push((i, None));
                        }
                    }
                    Ok(Ok(StepEvent::Finished(_))) => {
                        retired.push((i, None));
                    }
                }
            } else {
                // Fused lane group: every member advances one token in
                // a single batched pass per stage. Chaos seam: a fused
                // dispatch fault fails the batched pass before it runs;
                // the per-lane solo fallback below is itself the
                // recovery, so the episode opens and closes in place.
                let fused_fault = heal.fire(FaultSite::FusedDispatch);
                let mut members: Vec<(usize, &mut Live)> = live
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| group.contains(i))
                    .collect();
                let stepped = if fused_fault {
                    Ok(Err(injected_error(FaultSite::FusedDispatch)))
                } else {
                    let mut sess: Vec<&mut DecodeSession> = members
                        .iter_mut()
                        .map(|(_, l)| &mut l.session)
                        .collect();
                    let be = engine.backend();
                    std::panic::catch_unwind(AssertUnwindSafe(|| {
                        DecodeSession::step_fused(be, &mut sess)
                    }))
                };
                match stepped {
                    Err(_) => {
                        // As in the solo panic arm: deliver the round's
                        // deferred outcomes, then fail the group and
                        // every other live session — or ticket them all
                        // and rebuild when healing is on.
                        drop(members);
                        let i = group[0];
                        let below =
                            retired.iter().filter(|(j, _)| *j < i).count();
                        settle_round(
                            worker,
                            &events,
                            engine.backend(),
                            &sched,
                            store.as_deref(),
                            &convo,
                            &mut heal,
                            &mut live,
                            retired,
                        );
                        let failed = live.remove(i - below);
                        if heal.enabled() {
                            if !supervise(
                                worker,
                                &mut engine,
                                &state,
                                &cfg,
                                &events,
                                &mut heal,
                                &mut current_policy,
                                &mut live,
                                Some(failed),
                                "worker panicked during decode",
                            ) {
                                return;
                            }
                            warm.clear();
                            traffic_base =
                                engine.backend().lane_traffic();
                            continue 'serve;
                        }
                        retire(worker, &events, failed.id, &live);
                        return;
                    }
                    Ok(Err(e)) => {
                        // The fused pass failed before touching any
                        // lane's session state (`run_lanes` defers its
                        // cache scatters until the whole pass has
                        // succeeded; stats accounting is deferred the
                        // same way): retry every member on the solo
                        // path this round, so a poisoned session fails
                        // alone instead of wiping the group — the
                        // PR-2 isolation property, kept under fusion.
                        // The solo fallback IS the recovery for a
                        // failed dispatch: the episode closes here
                        // without a ticket or retry-budget draw.
                        drop(members);
                        if heal.enabled() {
                            heal.counters
                                .record_observed(FaultSite::FusedDispatch);
                            heal.counters.record_recovery();
                        }
                        eprintln!(
                            "[serve] worker {worker}: fused lane group \
                             of {} failed; retrying solo: {e:#}",
                            group.len()
                        );
                        for &i in group.iter().rev() {
                            queue.push_front(vec![i]);
                        }
                    }
                    Ok(Ok(fused)) => {
                        counters
                            .record_fused(group.len(), fused.stages_skipped);
                        next_warm.push(
                            members.iter().map(|(_, l)| l.id).collect(),
                        );
                        let now = Instant::now();
                        for ((i, l), ev) in
                            members.iter_mut().zip(fused.events)
                        {
                            let StepEvent::Token {
                                token,
                                exit_layer,
                                done,
                            } = ev
                            else {
                                // Fusable sessions always decode.
                                retired.push((*i, None));
                                continue;
                            };
                            stream_token(
                                worker,
                                &events,
                                &heal.counters,
                                l,
                                now,
                                token,
                                exit_layer,
                            );
                            if done.is_some() {
                                retired.push((*i, None));
                            }
                        }
                    }
                }
            }
        }
        // Retire finished/failed sessions; their slots free up for the
        // next admission pass.
        settle_round(
            worker,
            &events,
            engine.backend(),
            &sched,
            store.as_deref(),
            &convo,
            &mut heal,
            &mut live,
            retired,
        );
        if heal.enabled() && !engine.healthy() {
            // A poisoned stage chain fails every future round; rebuild
            // now, while the round's casualties are already ticketed,
            // instead of limping into guaranteed failures.
            if !supervise(
                worker,
                &mut engine,
                &state,
                &cfg,
                &events,
                &mut heal,
                &mut current_policy,
                &mut live,
                None,
                "stage chain poisoned",
            ) {
                return;
            }
            warm.clear();
            traffic_base = engine.backend().lane_traffic();
            continue;
        }
        // A fully-served round on a healthy engine resets the flap
        // counter — quarantine is for consecutive failures only.
        heal.consecutive_failures = 0;
        checkpoint_live(worker, engine.as_mut(), &mut heal, &mut live);
        warm = next_warm;
        // Attribute the round's lane-cache traffic (including departure
        // scatters from the retirements above) to the pool counters.
        let t = engine.backend().lane_traffic();
        counters.record_traffic(&t.since(&traffic_base));
        traffic_base = t;
    }
    let t = engine.backend().lane_traffic();
    counters.record_traffic(&t.since(&traffic_base));
    engine.finish();
}

/// What [`admit_request`] did with the popped request.
enum AdmitOutcome {
    /// Admitted, failed typed, or ticketed for recovery — either way
    /// the worker keeps serving.
    Continue,
    /// The engine panicked during prefill. With healing off the caller
    /// must retire, failing `panicked_id` along with the live set; with
    /// healing on the request already rides a recovery ticket and the
    /// caller should supervise (rebuild) the engine.
    EngineSuspect { panicked_id: u64 },
}

/// Admit one popped request into a free live slot: apply its policy,
/// prefill (through the shared snapshot store when configured), and
/// push the live session. Conversation-tagged requests are counted as
/// opening or follow-up turns here (restore hit/miss, positions saved).
#[allow(clippy::too_many_arguments)]
fn admit_request(
    worker: usize,
    engine: &mut dyn PoolEngine,
    cfg: &PoolConfig,
    store: Option<&TieredStore>,
    convo: &ConvoPlane,
    counters: &LaneCounters,
    events: &Sender<WorkerEvent>,
    heal: &mut HealRuntime,
    current_policy: &mut ExitPolicy,
    live: &mut Vec<Live>,
    req: ServeRequest,
    queue_seconds: f64,
) -> AdmitOutcome {
    let policy = req.policy.clone().unwrap_or_else(|| cfg.policy.clone());
    if policy != *current_policy {
        engine.apply_policy(&policy);
        *current_policy = policy.clone();
        counters.record_policy_apply();
    }
    let admitted = Instant::now();
    // Chaos seam: a prefix-cache restore fault fails the prefill before
    // the store is consulted, so the snapshot store's state is exactly
    // what the fault-free run would have seen.
    let prefix_fault = store.is_some() && heal.fire(FaultSite::PrefixRestore);
    // Every popped request must produce exactly one completion
    // event, even if the engine panics — otherwise `run_batch`
    // waits forever on the lost request.
    let started = std::panic::catch_unwind(AssertUnwindSafe(|| {
        let be = engine.backend();
        let mut s = DecodeSession::new_text(be, &req.prompt, req.max_new)?;
        if prefix_fault {
            return Err(injected_error(FaultSite::PrefixRestore));
        }
        let cached = match store {
            Some(st) => s.prefill_with_cache(be, st)?,
            None => {
                s.prefill(be)?;
                Default::default()
            }
        };
        if let Some(cid) = req.conversation {
            // A registered id makes this a follow-up turn: its restore
            // either hit the conversation's stored history or missed
            // (evicted, expired between batches, or a cold store).
            if convo.touch(cid) {
                convo.counters.record_restore(
                    cached.cached_tokens > 0,
                    cached.saved_positions as u64,
                );
            } else {
                convo.counters.record_first_turn();
            }
        } else if let Some(st) = store {
            // Extend the store with this prompt's full
            // prefix unless a resident entry already covers
            // it in full (then the hit refreshed its LRU
            // slot and a re-insert would only duplicate it).
            // `would_admit` skips the host-copy snapshot
            // when the store could only reject it, and a
            // failed snapshot merely logs — the request
            // already prefilled fine without the cache.
            // Conversation turns skip this: their end-of-turn
            // snapshot covers the prompt and more.
            if !s.is_done()
                && cached.cached_tokens < s.prompt_len()
                && st.would_admit(s.prompt_len().saturating_sub(1))
            {
                match s.prefix_snapshot(be) {
                    Ok(snap) => {
                        st.insert(snap);
                    }
                    Err(e) => eprintln!(
                        "[serve] worker {worker}: prefix \
                         snapshot failed (serving continues \
                         uncached): {e:#}"
                    ),
                }
            }
        }
        Ok::<_, anyhow::Error>(s)
    }));
    match started {
        Ok(Ok(session)) => {
            live.push(Live {
                id: req.id,
                policy,
                session,
                queue_seconds,
                deadline: req.deadline,
                priority: req.priority,
                tenant: req.tenant,
                conversation: req.conversation,
                admitted,
                last_event: admitted,
                token_seconds: Vec::new(),
                prompt: req.prompt,
                max_new: req.max_new,
                emitted: 0,
                suppress: 0,
                retries: 0,
                last_checkpoint: 0,
            });
            AdmitOutcome::Continue
        }
        Ok(Err(e)) => {
            fail_or_ticket(
                worker,
                events,
                heal,
                RecoveryTicket {
                    id: req.id,
                    tenant: req.tenant,
                    priority: req.priority,
                    deadline: req.deadline,
                    policy,
                    conversation: req.conversation,
                    queue_seconds,
                    admitted,
                    token_seconds: Vec::new(),
                    prompt: req.prompt,
                    max_new: req.max_new,
                    emitted: 0,
                    retries: 0,
                    not_before: admitted,
                },
                &format!("{e:#}"),
            );
            AdmitOutcome::Continue
        }
        Err(_) => {
            if heal.enabled() {
                fail_or_ticket(
                    worker,
                    events,
                    heal,
                    RecoveryTicket {
                        id: req.id,
                        tenant: req.tenant,
                        priority: req.priority,
                        deadline: req.deadline,
                        policy,
                        conversation: req.conversation,
                        queue_seconds,
                        admitted,
                        token_seconds: Vec::new(),
                        prompt: req.prompt,
                        max_new: req.max_new,
                        emitted: 0,
                        retries: 0,
                        not_before: admitted,
                    },
                    "worker panicked during prefill",
                );
            }
            AdmitOutcome::EngineSuspect { panicked_id: req.id }
        }
    }
}

/// What [`resume_parked`] did with the park store's best entry.
enum ResumeOutcome {
    /// An entry was taken: either resumed into a live slot, its failure
    /// reported, or a recovery ticket filed. Re-check admission either
    /// way.
    Resumed,
    /// Nothing parked.
    Empty,
    /// The engine panicked during restore with healing off; the caller
    /// must retire, failing `failed_id` along with the live set.
    Panicked { failed_id: u64 },
    /// The engine panicked during restore with healing on; the entry
    /// already rides a recovery ticket and the caller should supervise
    /// (rebuild) the engine.
    EngineSuspect,
}

/// Take the highest-value parked session and rebuild it as a live
/// session on this worker. The entry's policy is applied *before* the
/// restore — interleaving backends capture a session's policy at
/// open/restore, so applying it afterwards would decode the wrong
/// policy.
#[allow(clippy::too_many_arguments)]
fn resume_parked(
    worker: usize,
    engine: &mut dyn PoolEngine,
    cfg: &PoolConfig,
    park: &ParkStore,
    events: &Sender<WorkerEvent>,
    slo: &SloCounters,
    counters: &LaneCounters,
    heal: &mut HealRuntime,
    current_policy: &mut ExitPolicy,
    live: &mut Vec<Live>,
) -> ResumeOutcome {
    let Some(e) = park.take_best() else {
        return ResumeOutcome::Empty;
    };
    let ParkedEntry {
        id,
        tenant,
        priority,
        deadline,
        due: _,
        policy,
        conversation,
        queue_seconds,
        admitted,
        token_seconds,
        prompt,
        max_new,
        emitted,
        retries,
        parked,
    } = e;
    if policy != *current_policy {
        engine.apply_policy(&policy);
        *current_policy = policy.clone();
        counters.record_policy_apply();
    }
    let inject = cfg.control.fault == Some(ControlFault::ResumeRestore)
        || heal.fire(FaultSite::Resume);
    let restored = if inject {
        Ok(Err(injected_error(FaultSite::Resume)))
    } else {
        std::panic::catch_unwind(AssertUnwindSafe(|| {
            parked.resume(engine.backend())
        }))
    };
    match restored {
        Ok(Ok(session)) => {
            slo.record_resume();
            let generated = session.generated().len();
            live.push(Live {
                id,
                policy,
                session,
                queue_seconds,
                deadline,
                priority,
                tenant,
                conversation,
                admitted,
                last_event: Instant::now(),
                token_seconds,
                prompt,
                max_new,
                // A parked session resumes exactly where it left off,
                // so nothing re-decodes; the suppress window is only
                // non-zero if a recovery preceded the park.
                suppress: emitted.saturating_sub(generated),
                emitted,
                retries,
                last_checkpoint: generated,
            });
            ResumeOutcome::Resumed
        }
        Ok(Err(err)) => {
            // Typed per-request failure (or a recovery episode when
            // healing is on): the resumed request fails or recovers
            // alone; the worker and every other session keep serving.
            slo.record_resume_failure();
            fail_or_ticket(
                worker,
                events,
                heal,
                RecoveryTicket {
                    id,
                    tenant,
                    priority,
                    deadline,
                    policy,
                    conversation,
                    queue_seconds,
                    admitted,
                    token_seconds,
                    prompt,
                    max_new,
                    emitted,
                    retries,
                    not_before: Instant::now(),
                },
                &format!("resume failed: {err:#}"),
            );
            ResumeOutcome::Resumed
        }
        Err(_) => {
            slo.record_resume_failure();
            if heal.enabled() {
                fail_or_ticket(
                    worker,
                    events,
                    heal,
                    RecoveryTicket {
                        id,
                        tenant,
                        priority,
                        deadline,
                        policy,
                        conversation,
                        queue_seconds,
                        admitted,
                        token_seconds,
                        prompt,
                        max_new,
                        emitted,
                        retries,
                        not_before: Instant::now(),
                    },
                    "resume failed: worker panicked during restore",
                );
                return ResumeOutcome::EngineSuspect;
            }
            ResumeOutcome::Panicked { failed_id: id }
        }
    }
}

/// Deliver a round's deferred outcomes — `(live index, Some(error))`
/// failures and `(live index, None)` completions — removing each from
/// the live set, highest index first so the recorded indices stay
/// valid. A completed conversation turn snapshots its end-of-turn KV
/// state *before* the close releases the session's caches. Each retired
/// session is then closed, releasing its backend-side decode state
/// (per-stage KV slots on interleaving engines). Completions feed their
/// service time back to the scheduler's predicted-TTFT estimator
/// (admission control).
#[allow(clippy::too_many_arguments)]
fn settle_round(
    worker: usize,
    events: &Sender<WorkerEvent>,
    backend: &mut dyn DecodeBackend,
    sched: &Scheduler,
    store: Option<&TieredStore>,
    convo: &ConvoPlane,
    heal: &mut HealRuntime,
    live: &mut Vec<Live>,
    mut retired: Vec<(usize, Option<String>)>,
) {
    retired.sort_by(|a, b| b.0.cmp(&a.0));
    for (i, err) in retired {
        let mut l = live.remove(i);
        if err.is_none() {
            if let Some(cid) = l.conversation {
                let key = end_of_turn_snapshot(
                    worker, backend, store, convo, &l.session,
                );
                convo.counters.record_turn();
                convo.complete_turn(cid, key, store);
            }
        }
        l.session.close(backend);
        match err {
            Some(error) => {
                fail_or_ticket(worker, events, heal, live_ticket(l), &error);
            }
            None => {
                // A finished request's micro-checkpoint can never be
                // needed again; release its bytes eagerly.
                heal.plane.drop_checkpoint(l.id);
                let service = complete(worker, events, l);
                sched.note_done(service);
            }
        }
    }
}

/// Capture a completed conversation turn's end-of-turn KV snapshot
/// (prompt ⧺ generated tokens) into the store, returning the stored
/// key. Budget refusals and capture errors only count and log — the
/// turn itself already completed; its conversation merely restarts
/// cold next turn.
fn end_of_turn_snapshot(
    worker: usize,
    backend: &mut dyn DecodeBackend,
    store: Option<&TieredStore>,
    convo: &ConvoPlane,
    session: &DecodeSession,
) -> Option<Vec<i32>> {
    let st = store?;
    let positions = (session.prompt_len() + session.generated().len())
        .saturating_sub(1);
    if !st.would_admit(positions) {
        convo.counters.record_snapshot(false);
        return None;
    }
    match session.finish_snapshot(backend) {
        Ok(snap) => {
            let key = snap.tokens.clone();
            let stored = st.insert(snap);
            convo.counters.record_snapshot(stored);
            stored.then_some(key)
        }
        Err(e) => {
            convo.counters.record_snapshot_failure();
            eprintln!(
                "[serve] worker {worker}: end-of-turn snapshot failed \
                 (conversation restarts cold): {e:#}"
            );
            None
        }
    }
}

/// Dense policy-class ids over the live set: sessions with equal exit
/// policies share an id; ids are assigned in first-appearance order.
fn policy_classes(live: &[Live]) -> Vec<usize> {
    let mut classes: Vec<&ExitPolicy> = Vec::new();
    live.iter()
        .map(|l| {
            match classes.iter().position(|p| **p == l.policy) {
                Some(i) => i,
                None => {
                    classes.push(&l.policy);
                    classes.len() - 1
                }
            }
        })
        .collect()
}

/// Plan one continuous-batching round over the live sessions.
///
/// Inputs are parallel per-session slices: `classes[i]` is session
/// `i`'s policy class ([`policy_classes`]), `fusable[i]` whether it may
/// join a fused lane group ([`DecodeSession::fusable`]); `lanes` is the
/// backend's fused group-size ladder (sorted ascending; sizes < 2 are
/// ignored, empty disables fusion). `sticky` holds last round's warm
/// fused groups (live indices, lane order preserved): with
/// device-resident lane groups, re-planning an identical membership is
/// a free warm hit while any membership change costs a full dissolve +
/// re-gather, so the planner keeps a sticky group intact whenever every
/// member is still eligible, rather than greedily re-packing.
///
/// Returns step groups covering every session exactly once. Invariants
/// (property-tested below):
///
/// - groups are contiguous per policy class, classes in
///   first-appearance order — each distinct policy is applied once per
///   round instead of once per adjacent policy change;
/// - a group of size > 1 is a fused lane group: its size is one of
///   `lanes`, all members share a class and are fusable;
/// - a sticky group whose members are all fusable, same-class, and
///   unclaimed by an earlier sticky group survives verbatim (emitted
///   before its class's greedy groups); otherwise it dissolves and its
///   members re-pack greedily (largest ladder size that fits);
/// - non-fusable sessions (recompute deficit, capacity edge) always
///   step solo.
pub fn plan_round(
    classes: &[usize],
    fusable: &[bool],
    lanes: &[usize],
    sticky: &[Vec<usize>],
) -> Vec<Vec<usize>> {
    assert_eq!(classes.len(), fusable.len());
    let n = classes.len();
    let lanes: Vec<usize> =
        lanes.iter().copied().filter(|&b| b >= 2).collect();
    // Warm groups that survive re-validation: still a ladder size, every
    // member present, fusable, policy-pure, and not claimed twice
    // (overlapping sticky inputs keep first-come membership).
    let mut claimed = vec![false; n];
    let mut kept: Vec<Vec<usize>> = Vec::new();
    for g in sticky {
        let ok = lanes.contains(&g.len())
            && g.iter().all(|&i| i < n && fusable[i] && !claimed[i])
            && g.iter().all(|&i| classes[i] == classes[g[0]]);
        if ok {
            for &i in g {
                claimed[i] = true;
            }
            kept.push(g.clone());
        }
    }
    let mut order: Vec<usize> = Vec::new();
    let mut by_class: Vec<Vec<usize>> = Vec::new();
    for (i, &c) in classes.iter().enumerate() {
        if c >= by_class.len() {
            by_class.resize(c + 1, Vec::new());
        }
        if by_class[c].is_empty() {
            order.push(c);
        }
        by_class[c].push(i);
    }
    let mut groups = Vec::new();
    for c in order {
        // Warm groups first (in their class's slot, so each distinct
        // policy is still applied exactly once per round)...
        for g in kept.iter().filter(|g| classes[g[0]] == c) {
            groups.push(g.clone());
        }
        // ...then greedy packing over the class's unclaimed remainder.
        let members = &by_class[c];
        let eligible: Vec<usize> = members
            .iter()
            .copied()
            .filter(|&i| fusable[i] && !claimed[i])
            .collect();
        let mut k = 0;
        while k < eligible.len() {
            match lanes
                .iter()
                .copied()
                .filter(|&b| b <= eligible.len() - k)
                .max()
            {
                Some(b) => {
                    groups.push(eligible[k..k + b].to_vec());
                    k += b;
                }
                None => break,
            }
        }
        for &i in &eligible[k..] {
            groups.push(vec![i]);
        }
        for &i in members.iter().filter(|&&i| !fusable[i]) {
            groups.push(vec![i]);
        }
    }
    groups
}

/// Emit the `Done` event for a finished live session, returning its
/// service time (admission to completion — parked time included for
/// preempted sessions; that is what the client observed) for the
/// scheduler's service estimator.
fn complete(worker: usize, events: &Sender<WorkerEvent>, l: Live) -> f64 {
    let output = l.session.output();
    let service_seconds = l.admitted.elapsed().as_secs_f64();
    let ttft_seconds = l.queue_seconds
        + l.token_seconds.first().copied().unwrap_or(service_seconds);
    events
        .send(WorkerEvent::Done(ServeResponse {
            id: l.id,
            worker,
            output,
            queue_seconds: l.queue_seconds,
            ttft_seconds,
            token_seconds: l.token_seconds,
            total_seconds: l.queue_seconds + service_seconds,
            deadline: l.deadline,
            tenant: l.tenant,
            retries: l.retries,
        }))
        .ok();
    service_seconds
}

/// The engine panicked: fail the panicking request and every other live
/// session (their engine is gone), then report the worker dead.
fn retire(
    worker: usize,
    events: &Sender<WorkerEvent>,
    panicked_id: u64,
    live: &[Live],
) {
    events
        .send(WorkerEvent::Failed {
            id: panicked_id,
            worker,
            error: "worker panicked during decode".into(),
            retries: 0,
        })
        .ok();
    for l in live {
        events
            .send(WorkerEvent::Failed {
                id: l.id,
                worker,
                error: "worker retired mid-generation (engine panicked \
                        on another request)"
                    .into(),
                retries: l.retries,
            })
            .ok();
    }
    events
        .send(WorkerEvent::Fatal {
            worker,
            error: "panicked during decode; worker retired".into(),
        })
        .ok();
}

fn build_engine(
    state: ModelState,
    cfg: &PoolConfig,
) -> Result<Box<dyn PoolEngine>> {
    Ok(match cfg.engine {
        EngineKind::Sequential => {
            let mut e = SequentialEngine::new(state, cfg.policy.clone())
                .context("building sequential engine")?;
            e.lane_residency = cfg.lane_residency;
            Box::new(e)
        }
        EngineKind::Pipelined => Box::new(
            PipelinedEngine::new(state, cfg.policy.clone())
                .context("building pipelined engine")?,
        ),
    })
}

/// Emit one decoded token to the client stream — or swallow it when the
/// session is replaying a recovered tail. The suppress window covers
/// exactly the tokens the client already saw before the fault, so a
/// recovered stream is token- and exit-layer-identical to a fault-free
/// run; swallowed replays are counted as re-decoded work.
fn stream_token(
    worker: usize,
    events: &Sender<WorkerEvent>,
    faults: &FaultCounters,
    l: &mut Live,
    now: Instant,
    token: i32,
    exit_layer: usize,
) {
    if l.suppress > 0 {
        l.suppress -= 1;
        l.last_event = now;
        faults.record_redecoded(1);
        return;
    }
    l.token_seconds
        .push(now.duration_since(l.last_event).as_secs_f64());
    l.last_event = now;
    l.emitted += 1;
    events
        .send(WorkerEvent::Token { id: l.id, worker, token, exit_layer })
        .ok();
}

/// Turn a (failed) live session into a recovery ticket, carrying the
/// request identity, accumulated timing, and stream position. The
/// session itself is dropped — callers close it (best-effort) first.
fn live_ticket(l: Live) -> RecoveryTicket {
    RecoveryTicket {
        id: l.id,
        tenant: l.tenant,
        priority: l.priority,
        deadline: l.deadline,
        policy: l.policy,
        conversation: l.conversation,
        queue_seconds: l.queue_seconds,
        admitted: l.admitted,
        token_seconds: l.token_seconds,
        prompt: l.prompt,
        max_new: l.max_new,
        emitted: l.emitted,
        retries: l.retries,
        not_before: l.last_event,
    }
}

/// Route a failed request: with healing off, fail it typed exactly as
/// before this layer existed; with healing on, open a recovery episode
/// — count the fault against its seam, and either file the ticket
/// (backoff applied) or give up typed once its retry budget is spent.
/// Every episode opened here closes with exactly one recovery or one
/// recovery failure, so `recoveries == observed - recovery_failures`
/// holds by construction.
fn fail_or_ticket(
    worker: usize,
    events: &Sender<WorkerEvent>,
    heal: &mut HealRuntime,
    mut t: RecoveryTicket,
    error: &str,
) {
    if !heal.enabled() {
        events
            .send(WorkerEvent::Failed {
                id: t.id,
                worker,
                error: error.to_string(),
                retries: t.retries,
            })
            .ok();
        return;
    }
    heal.counters.record_observed(classify_failure(error));
    if t.retries >= heal.cfg.max_retries {
        heal.counters.record_recovery_failure();
        heal.plane.drop_checkpoint(t.id);
        events
            .send(WorkerEvent::Failed {
                id: t.id,
                worker,
                error: format!(
                    "giving up after {} recovery attempts: {error}",
                    t.retries
                ),
                retries: t.retries,
            })
            .ok();
        return;
    }
    t.not_before =
        Instant::now() + recovery_backoff(heal.cfg.backoff, t.retries + 1);
    heal.plane.submit(t);
}

/// A recovery attempt itself failed: consume one retry and re-file (or
/// give up typed). Unlike [`fail_or_ticket`] this does *not* count a
/// new observed fault — the episode is already open; attempts inside it
/// only consume budget.
fn retry_ticket(
    worker: usize,
    events: &Sender<WorkerEvent>,
    heal: &mut HealRuntime,
    mut t: RecoveryTicket,
    error: &str,
) {
    t.retries += 1;
    if t.retries >= heal.cfg.max_retries {
        heal.counters.record_recovery_failure();
        heal.plane.drop_checkpoint(t.id);
        events
            .send(WorkerEvent::Failed {
                id: t.id,
                worker,
                error: format!(
                    "giving up after {} recovery attempts: {error}",
                    t.retries
                ),
                retries: t.retries,
            })
            .ok();
        return;
    }
    t.not_before =
        Instant::now() + recovery_backoff(heal.cfg.backoff, t.retries + 1);
    heal.plane.submit(t);
}

/// What [`recover_pending`] did with the heal plane's ticket queue.
enum RecoverOutcome {
    /// A due ticket was taken: restored into a live slot, re-filed
    /// after a typed failure, or failed for good. Re-check admission.
    Recovered,
    /// Tickets exist but none is due yet; the earliest matures in the
    /// given duration.
    Waiting(Duration),
    /// No pending tickets.
    Empty,
    /// The engine panicked during the restore; the ticket was re-filed
    /// and the caller should supervise (rebuild) the engine.
    EngineSuspect,
}

/// Re-admit one due recovery ticket: restore the request's session from
/// its micro-checkpoint when one is stored (only the tail since the
/// checkpoint re-decodes), or re-run it from scratch. Tokens the client
/// already saw are suppressed on replay ([`stream_token`]), so the
/// recovered stream is identical to a fault-free run.
fn recover_pending(
    worker: usize,
    engine: &mut dyn PoolEngine,
    events: &Sender<WorkerEvent>,
    heal: &mut HealRuntime,
    current_policy: &mut ExitPolicy,
    counters: &LaneCounters,
    live: &mut Vec<Live>,
) -> RecoverOutcome {
    let t = match heal.plane.take_due(Instant::now()) {
        TicketPoll::Due(t) => t,
        TicketPoll::Waiting(d) => return RecoverOutcome::Waiting(d),
        TicketPoll::Empty => return RecoverOutcome::Empty,
    };
    heal.counters.record_retry();
    // Apply the ticket's policy *before* the restore — interleaving
    // backends capture a session's policy at open/restore.
    if t.policy != *current_policy {
        engine.apply_policy(&t.policy);
        *current_policy = t.policy.clone();
        counters.record_policy_apply();
    }
    let checkpoint = heal.plane.checkpoint(t.id);
    // Chaos seam: a restore fault fails the attempt before the backend
    // is touched (the checkpoint stays stored for the next attempt).
    let fault = heal.fire(FaultSite::Restore);
    let attempt = std::panic::catch_unwind(AssertUnwindSafe(|| {
        if fault {
            return Err(injected_error(FaultSite::Restore));
        }
        let be = engine.backend();
        match checkpoint {
            Some(p) => p.resume(be),
            None => {
                let mut s =
                    DecodeSession::new_text(be, &t.prompt, t.max_new)?;
                s.prefill(be)?;
                Ok(s)
            }
        }
    }));
    match attempt {
        Ok(Ok(session)) => {
            heal.counters.record_recovery();
            let generated = session.generated().len();
            live.push(Live {
                id: t.id,
                policy: t.policy,
                session,
                queue_seconds: t.queue_seconds,
                deadline: t.deadline,
                priority: t.priority,
                tenant: t.tenant,
                conversation: t.conversation,
                admitted: t.admitted,
                last_event: Instant::now(),
                token_seconds: t.token_seconds,
                prompt: t.prompt,
                max_new: t.max_new,
                suppress: t.emitted.saturating_sub(generated),
                emitted: t.emitted,
                retries: t.retries + 1,
                last_checkpoint: generated,
            });
            RecoverOutcome::Recovered
        }
        Ok(Err(e)) => {
            retry_ticket(worker, events, heal, t, &format!("{e:#}"));
            RecoverOutcome::Recovered
        }
        Err(_) => {
            retry_ticket(
                worker,
                events,
                heal,
                t,
                "worker panicked during recovery restore",
            );
            RecoverOutcome::EngineSuspect
        }
    }
}

/// The engine is suspect (panicked worker, poisoned stage chain): fail
/// or ticket every stranded live session, then rebuild the engine in
/// place so checkpointed work re-admits onto healthy state. Returns
/// `false` when the worker flapped past its quarantine budget or the
/// rebuild itself failed — the worker is then quarantined and must stop
/// serving; the shrunken capacity feeds the shed/degrade path exactly
/// like a retired worker always has.
#[allow(clippy::too_many_arguments)]
fn supervise(
    worker: usize,
    engine: &mut Box<dyn PoolEngine>,
    state: &ModelState,
    cfg: &PoolConfig,
    events: &Sender<WorkerEvent>,
    heal: &mut HealRuntime,
    current_policy: &mut ExitPolicy,
    live: &mut Vec<Live>,
    casualty: Option<Live>,
    error: &str,
) -> bool {
    // Every stranded session rides a ticket (or fails typed once its
    // retry budget is spent). The suspect engine's state is going away
    // with the rebuild, so closing sessions is best-effort only.
    for l in casualty.into_iter().chain(live.drain(..)) {
        let mut l = l;
        let be = engine.backend();
        let _ = std::panic::catch_unwind(AssertUnwindSafe(|| {
            l.session.close(be);
        }));
        fail_or_ticket(worker, events, heal, live_ticket(l), error);
    }
    heal.consecutive_failures += 1;
    let flaps = heal.consecutive_failures;
    if flaps > heal.cfg.quarantine_after {
        let msg = format!(
            "{flaps} consecutive engine failures (last: {error})"
        );
        quarantine(worker, events, heal, &msg);
        return false;
    }
    match build_engine(state.clone(), cfg) {
        Ok(fresh) => {
            heal.counters.record_restart();
            let old = std::mem::replace(engine, fresh);
            // The old engine's teardown may itself panic or block on a
            // dead stage chain; never let it take the fresh engine (or
            // this worker) down with it.
            let _ = std::panic::catch_unwind(AssertUnwindSafe(move || {
                let mut old = old;
                old.finish();
            }));
            *current_policy = cfg.policy.clone();
            true
        }
        Err(e) => {
            let msg = format!("engine rebuild failed: {e:#}");
            quarantine(worker, events, heal, &msg);
            false
        }
    }
}

/// Quarantine a flapping worker: abandon every pending recovery ticket
/// as a typed failure (exactly one terminal event per request — nothing
/// strands, even if this was the last worker), then report the worker
/// dead so capacity accounting sees the shrunken pool. Tickets another
/// live worker has already taken are unaffected.
fn quarantine(
    worker: usize,
    events: &Sender<WorkerEvent>,
    heal: &mut HealRuntime,
    reason: &str,
) {
    heal.counters.record_quarantine();
    for t in heal.plane.drain_pending() {
        heal.counters.record_recovery_failure();
        heal.plane.drop_checkpoint(t.id);
        events
            .send(WorkerEvent::Failed {
                id: t.id,
                worker,
                error: format!(
                    "recovery abandoned (worker quarantined: {reason})"
                ),
                retries: t.retries,
            })
            .ok();
    }
    events
        .send(WorkerEvent::Fatal {
            worker,
            error: format!("quarantined: {reason}"),
        })
        .ok();
}

/// Sweep the live set for sessions due a micro-checkpoint: every
/// `checkpoint_interval` generated tokens, capture a non-consuming
/// snapshot into the heal plane's bounded store. A failed or refused
/// capture only counts and logs — the session keeps serving; its
/// recovery would simply re-run from scratch (or an older checkpoint).
fn checkpoint_live(
    worker: usize,
    engine: &mut dyn PoolEngine,
    heal: &mut HealRuntime,
    live: &mut Vec<Live>,
) {
    let interval = heal.cfg.checkpoint_interval;
    if interval == 0
        || !heal.enabled()
        || !engine.backend().supports_cache_snapshots()
    {
        return;
    }
    for l in live.iter_mut() {
        let generated = l.session.generated().len();
        if generated < l.last_checkpoint + interval || l.session.is_done() {
            continue;
        }
        l.last_checkpoint = generated;
        let fault = heal.fire(FaultSite::Snapshot);
        let snap = if fault {
            Err(injected_error(FaultSite::Snapshot))
        } else {
            let be = engine.backend();
            std::panic::catch_unwind(AssertUnwindSafe(|| {
                l.session.checkpoint(be)
            }))
            .unwrap_or_else(|_| {
                Err(anyhow::anyhow!(
                    "worker panicked during checkpoint snapshot"
                ))
            })
        };
        match snap {
            Ok(p) => {
                let stored = heal.plane.store_checkpoint(l.id, p);
                heal.counters.record_checkpoint(stored);
            }
            Err(e) => {
                heal.counters.record_checkpoint(false);
                eprintln!(
                    "[serve] worker {worker}: micro-checkpoint failed \
                     (request {} recovers from scratch): {e:#}",
                    l.id
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    /// How many `apply_policy` calls executing `plan` in order costs,
    /// starting from a resident policy unequal to every class — the
    /// quantity the policy-churn fix is about.
    fn policy_swaps(plan: &[Vec<usize>], classes: &[usize]) -> usize {
        let mut swaps = 0;
        let mut current = usize::MAX;
        for g in plan {
            if classes[g[0]] != current {
                swaps += 1;
                current = classes[g[0]];
            }
        }
        swaps
    }

    #[test]
    fn lane_plan_greedy_group_formation() {
        // 5 fusable same-policy sessions over lanes [2, 4]: one 4-lane
        // group, remainder solo.
        let classes = [0usize; 5];
        let fusable = [true; 5];
        let plan = plan_round(&classes, &fusable, &[2, 4], &[]);
        assert_eq!(plan, vec![vec![0, 1, 2, 3], vec![4]]);
        // Lanes off: everyone solo.
        let plan = plan_round(&classes, &fusable, &[], &[]);
        assert_eq!(plan.len(), 5);
        assert!(plan.iter().all(|g| g.len() == 1));
        // Deficit-carrying sessions (non-fusable) step solo even when a
        // lane would fit.
        let plan = plan_round(
            &classes,
            &[true, false, true, false, true],
            &[2, 4],
            &[],
        );
        assert_eq!(plan, vec![vec![0, 2], vec![4], vec![1], vec![3]]);
    }

    /// Warm-group stickiness: a warm fused group whose members are all
    /// still eligible survives verbatim — even when greedy packing
    /// would have cut a different (larger) grouping — and an ineligible
    /// member dissolves the group back to greedy packing.
    #[test]
    fn lane_plan_keeps_warm_groups_intact() {
        let classes = [0usize; 5];
        let fusable = [true; 5];
        // Greedy alone would form [0,1,2,3]; the warm pair [1,3] (in
        // its lane order) must survive instead, with the rest packed
        // around it.
        let plan =
            plan_round(&classes, &fusable, &[2, 4], &[vec![1, 3]]);
        assert_eq!(plan, vec![vec![1, 3], vec![0, 2], vec![4]]);
        // A warm member that went non-fusable (deficit) dissolves the
        // group: plain greedy packing takes over.
        let plan = plan_round(
            &classes,
            &[true, true, true, false, true],
            &[2, 4],
            &[vec![1, 3]],
        );
        assert_eq!(plan, vec![vec![0, 1, 2, 4], vec![3]]);
        // A warm group whose size fell off the ladder (member departed
        // before the round; caller passes the survivors) re-packs too.
        let plan =
            plan_round(&classes, &fusable, &[2, 4], &[vec![1, 3, 4]]);
        assert_eq!(plan, vec![vec![0, 1, 2, 3], vec![4]]);
        // Overlapping warm groups: first claim wins, the loser re-packs.
        let plan = plan_round(
            &classes,
            &fusable,
            &[2, 4],
            &[vec![1, 3], vec![3, 4]],
        );
        assert_eq!(plan, vec![vec![1, 3], vec![0, 2], vec![4]]);
        // Mixed-policy warm groups never survive re-validation.
        let plan = plan_round(
            &[0, 0, 1, 1],
            &[true; 4],
            &[2],
            &[vec![1, 2]],
        );
        assert_eq!(plan, vec![vec![0, 1], vec![2, 3]]);
    }

    /// Regression (policy churn): the pre-lane loop applied the engine
    /// policy once per adjacent policy change — an interleaved live set
    /// swapped once per step per session. The planned round applies
    /// each distinct policy exactly once.
    #[test]
    fn lane_plan_applies_each_policy_once_per_round() {
        let classes = [0usize, 1, 0, 1, 0, 1];
        let fusable = [true; 6];
        // The old round-robin order would swap 6 times.
        let naive: Vec<Vec<usize>> = (0..6).map(|i| vec![i]).collect();
        assert_eq!(policy_swaps(&naive, &classes), 6);
        for lanes in [&[][..], &[2, 4][..]] {
            let plan = plan_round(&classes, &fusable, lanes, &[]);
            assert_eq!(
                policy_swaps(&plan, &classes),
                2,
                "lanes {lanes:?}: one apply per distinct policy"
            );
        }
        // Mixed-policy sessions never share a fused group.
        let plan = plan_round(&classes, &fusable, &[2, 4], &[]);
        for g in &plan {
            assert!(
                g.iter().all(|&i| classes[i] == classes[g[0]]),
                "mixed-policy group {g:?}"
            );
        }
    }

    /// The ISSUE's lane-group invariants over random live sets — now
    /// with random sticky (warm) groups in play: every session planned
    /// exactly once, fused sizes come from the ladder, groups are
    /// policy-pure, non-fusable sessions always solo, each policy
    /// applied once per round, and **a warm group is never broken while
    /// all its lanes stay eligible** (it reappears verbatim in the
    /// plan).
    #[test]
    fn lane_plan_invariants_hold_for_arbitrary_live_sets() {
        proptest::check("plan_round invariants", 256, |rng| {
            let n = rng.range(0, 24);
            let n_classes = rng.range(1, 5);
            let classes: Vec<usize> =
                (0..n).map(|_| rng.below(n_classes)).collect();
            let fusable: Vec<bool> =
                (0..n).map(|_| rng.below(3) > 0).collect();
            let mut lanes: Vec<usize> = (0..rng.range(0, 4))
                .map(|_| rng.range(2, 9))
                .collect();
            lanes.sort_unstable();
            lanes.dedup();
            // Random disjoint "warm groups from last round": how the
            // worker feeds them, membership may have gone stale in any
            // way (non-fusable members, off-ladder sizes after a
            // departure, class drift after a policy override).
            let mut sticky: Vec<Vec<usize>> = Vec::new();
            if n > 0 {
                let mut pool_idx: Vec<usize> = (0..n).collect();
                for _ in 0..rng.range(0, 4) {
                    let want = rng.range(1, 6);
                    if pool_idx.len() < want {
                        break;
                    }
                    let mut g = Vec::with_capacity(want);
                    for _ in 0..want {
                        let j = rng.below(pool_idx.len());
                        g.push(pool_idx.swap_remove(j));
                    }
                    sticky.push(g);
                }
            }
            let plan = plan_round(&classes, &fusable, &lanes, &sticky);
            // Sticky groups that should survive: ladder-sized,
            // all-fusable, policy-pure (disjoint by construction).
            for g in &sticky {
                let eligible = lanes.contains(&g.len())
                    && g.iter().all(|&i| fusable[i])
                    && g.iter().all(|&i| classes[i] == classes[g[0]]);
                if eligible && !plan.contains(g) {
                    return Err(format!(
                        "warm group {g:?} broken while all lanes \
                         eligible: plan {plan:?}"
                    ));
                }
            }
            let mut seen = vec![0usize; n];
            for g in &plan {
                if g.is_empty() {
                    return Err("empty group".into());
                }
                for &i in g {
                    if i >= n {
                        return Err(format!("index {i} out of range"));
                    }
                    seen[i] += 1;
                }
                if g.len() > 1 {
                    if !lanes.contains(&g.len()) {
                        return Err(format!(
                            "fused group size {} not in ladder {lanes:?}",
                            g.len()
                        ));
                    }
                    if g.iter().any(|&i| !fusable[i]) {
                        return Err(format!(
                            "non-fusable session fused: {g:?}"
                        ));
                    }
                }
                if g.iter().any(|&i| classes[i] != classes[g[0]]) {
                    return Err(format!("mixed-policy group {g:?}"));
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err(format!(
                    "sessions not planned exactly once: {seen:?}"
                ));
            }
            let distinct: std::collections::BTreeSet<usize> =
                classes.iter().copied().collect();
            if policy_swaps(&plan, &classes) != distinct.len() {
                return Err(format!(
                    "policy applied more than once per round: plan \
                     {plan:?} classes {classes:?}"
                ));
            }
            Ok(())
        });
    }

    fn stub_entry(id: u64) -> ParkedEntry {
        ParkedEntry {
            id,
            tenant: 0,
            priority: 0,
            deadline: None,
            due: None,
            policy: ExitPolicy::Never,
            conversation: None,
            queue_seconds: 0.0,
            admitted: Instant::now(),
            token_seconds: Vec::new(),
            prompt: String::new(),
            max_new: 8,
            emitted: 0,
            retries: 0,
            parked: ParkedSession::stub(vec![1, 2, 3]),
        }
    }

    fn stub_ticket(id: u64, not_before: Instant) -> RecoveryTicket {
        RecoveryTicket {
            id,
            tenant: 0,
            priority: 0,
            deadline: None,
            policy: ExitPolicy::Never,
            conversation: None,
            queue_seconds: 0.0,
            admitted: Instant::now(),
            token_seconds: Vec::new(),
            prompt: String::new(),
            max_new: 8,
            emitted: 0,
            retries: 0,
            not_before,
        }
    }

    /// Micro-checkpoint store: capacity bounds new ids, refreshing an
    /// already-stored id always succeeds (a live session's newer
    /// checkpoint supersedes its older one, never competing with other
    /// requests for room), and dropping frees the slot.
    #[test]
    fn heal_plane_checkpoints_bounded_and_replaceable() {
        let plane = HealPlane::new(2);
        assert!(plane.store_checkpoint(1, ParkedSession::stub(vec![1])));
        assert!(plane.store_checkpoint(2, ParkedSession::stub(vec![2])));
        // Full: a third id is refused, its request recovers from
        // scratch instead of evicting someone else's checkpoint.
        assert!(!plane.store_checkpoint(3, ParkedSession::stub(vec![3])));
        assert!(plane.checkpoint(3).is_none());
        // Refreshing id 1 with a longer tail succeeds at capacity, and
        // reads are non-consuming clones (retries can re-read).
        assert!(plane
            .store_checkpoint(1, ParkedSession::stub(vec![1, 4, 5])));
        assert_eq!(plane.checkpoint(1).unwrap().tokens(), &[1, 4, 5]);
        assert!(plane.checkpoint(1).is_some());
        let (entries, bytes) = plane.usage();
        assert_eq!(entries, 2);
        // Stub snapshots carry no stage caches, so they pin no bytes.
        assert_eq!(bytes, 0);
        plane.drop_checkpoint(1);
        assert!(plane.checkpoint(1).is_none());
        assert!(plane.store_checkpoint(3, ParkedSession::stub(vec![3])));
    }

    /// Ticket queue: empty poll, earliest-due-first release, a
    /// not-yet-due queue reports the wait to maturity, and quarantine's
    /// drain takes everything left.
    #[test]
    fn heal_plane_tickets_release_earliest_due_first() {
        let plane = HealPlane::new(2);
        let now = Instant::now();
        assert!(!plane.has_pending());
        assert!(matches!(plane.take_due(now), TicketPoll::Empty));
        plane.submit(stub_ticket(1, now + Duration::from_secs(60)));
        plane.submit(stub_ticket(2, now));
        assert!(plane.has_pending());
        match plane.take_due(now) {
            TicketPoll::Due(t) => assert_eq!(t.id, 2),
            _ => panic!("expected the due ticket"),
        }
        match plane.take_due(now) {
            TicketPoll::Waiting(d) => {
                assert!(d <= Duration::from_secs(60));
            }
            _ => panic!("expected a maturing ticket"),
        }
        let drained = plane.drain_pending();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].id, 1);
        assert!(!plane.has_pending());
    }

    /// Registry lifecycle: an unknown id opens (touch misses), a
    /// completed turn registers it, the next turn's snapshot replaces —
    /// and releases — the previous one, and the idle sweep expires the
    /// conversation and its stored history.
    #[test]
    fn convo_plane_tracks_turns_and_expires_idle_history() {
        use crate::inference::CacheSnapshot;

        let snap = |tokens: &[i32]| CacheSnapshot {
            tokens: tokens.to_vec(),
            stage_caches: Vec::new(),
            deficit: 0,
        };
        let plane = ConvoPlane::new(Duration::from_millis(0));
        let store = TieredStore::new(64, 0);
        assert!(!plane.touch(7), "unknown id is an opening turn");
        // Turn 1 completes with its history stored.
        assert!(store.insert(snap(&[1, 2, 3])));
        plane.complete_turn(7, Some(vec![1, 2, 3]), Some(&store));
        assert_eq!(plane.active(), 1);
        assert!(plane.touch(7), "registered id is a follow-up turn");
        // Turn 2's deeper snapshot replaces turn 1's, which is removed
        // from the store.
        assert!(store.insert(snap(&[1, 2, 3, 4, 5])));
        plane.complete_turn(7, Some(vec![1, 2, 3, 4, 5]), Some(&store));
        assert_eq!(store.len(), 1);
        assert!(store.lookup(&[1, 2, 3, 4, 5]).is_some());
        // A turn that failed to snapshot keeps the previous restore
        // point.
        plane.complete_turn(7, None, Some(&store));
        assert_eq!(store.len(), 1);
        // Zero TTL: the sweep expires the conversation and releases its
        // stored history.
        plane.expire_idle(Some(&store));
        assert_eq!(plane.active(), 0);
        assert!(store.is_empty());
        assert_eq!(plane.counters.stats().expired, 1);
        // Expired ids open again.
        assert!(!plane.touch(7));
    }

    /// Resume order: highest priority first; within a priority,
    /// deadlined before deadline-less, earlier deadline first.
    #[test]
    fn park_store_resumes_highest_value_first() {
        let store = ParkStore::new(4);
        let now = Instant::now();
        let mk = |id, priority, due: Option<Duration>| {
            let mut e = stub_entry(id);
            e.priority = priority;
            e.due = due.map(|d| now + d);
            e
        };
        for e in [
            mk(0, 0, None),
            mk(1, 1, None),
            mk(2, 1, Some(Duration::from_millis(50))),
            mk(3, 1, Some(Duration::from_millis(9))),
        ] {
            assert!(store.try_reserve());
            store.park_reserved(e);
        }
        assert!(!store.try_reserve(), "store at capacity");
        let order: Vec<u64> =
            std::iter::from_fn(|| store.take_best().map(|e| e.id))
                .collect();
        assert_eq!(order, vec![3, 2, 1, 0]);
        assert_eq!(store.peak(), 4);
        assert!(store.is_empty());
    }

    /// The satellite invariant pair: across any interleaving of
    /// reserve / park / take, parked + reserved never exceeds the
    /// budget, a reservation is only refused at capacity, and every
    /// parked entry is eventually taken — never silently dropped.
    #[test]
    fn prop_park_store_bounded_and_lossless() {
        proptest::check("park store budget", 128, |rng| {
            let capacity = rng.range(1, 5);
            let store = ParkStore::new(capacity);
            let mut next_id = 0u64;
            let mut reserved = 0usize;
            let mut inside = std::collections::BTreeSet::<u64>::new();
            for _ in 0..rng.range(10, 60) {
                match rng.below(3) {
                    0 => {
                        if store.try_reserve() {
                            reserved += 1;
                        } else if inside.len() + reserved < capacity {
                            return Err(
                                "reserve refused with room".into()
                            );
                        }
                    }
                    1 if reserved > 0 => {
                        let id = next_id;
                        next_id += 1;
                        let n = store.park_reserved(stub_entry(id));
                        reserved -= 1;
                        inside.insert(id);
                        if n > capacity {
                            return Err(format!(
                                "parked {n} > capacity {capacity}"
                            ));
                        }
                    }
                    _ => match store.take_best() {
                        Some(e) => {
                            if !inside.remove(&e.id) {
                                return Err(format!(
                                    "took unknown id {}",
                                    e.id
                                ));
                            }
                        }
                        None => {
                            if !inside.is_empty() {
                                return Err(
                                    "store lost parked entries".into()
                                );
                            }
                        }
                    },
                }
                if store.len() != inside.len() {
                    return Err(format!(
                        "len {} != model {}",
                        store.len(),
                        inside.len()
                    ));
                }
                if store.len() + reserved > capacity {
                    return Err("budget exceeded".into());
                }
            }
            while let Some(e) = store.take_best() {
                if !inside.remove(&e.id) {
                    return Err(format!("drained unknown id {}", e.id));
                }
            }
            if !inside.is_empty() {
                return Err(format!(
                    "entries lost at drain: {inside:?}"
                ));
            }
            Ok(())
        });
    }

    /// Preemption only ever displaces the lowest-value eligible
    /// session — never one that is not strictly lower-value than the
    /// urgent request, and never a higher-value one while a
    /// lower-value candidate exists.
    #[test]
    fn prop_preemption_targets_lowest_value_only() {
        proptest::check("preemption victim", 256, |rng| {
            let now = Instant::now();
            let horizon = Duration::from_millis(25);
            let n = rng.range(0, 8);
            let live: Vec<VictimInfo> = (0..n)
                .map(|_| VictimInfo {
                    priority: rng.range(0, 3) as i32,
                    due: if rng.below(2) == 0 {
                        None
                    } else {
                        Some(
                            now + Duration::from_millis(
                                rng.range(0, 200) as u64,
                            ),
                        )
                    },
                })
                .collect();
            let urgent_priority = rng.range(0, 3) as i32;
            let eligible = |v: &VictimInfo| {
                v.priority < urgent_priority
                    || (v.priority == urgent_priority
                        && match v.due {
                            None => true,
                            Some(d) => {
                                d.saturating_duration_since(now) > horizon
                            }
                        })
            };
            match preemption_victim(&live, urgent_priority, now, horizon)
            {
                None => {
                    if live.iter().any(eligible) {
                        return Err(
                            "no victim though one was eligible".into()
                        );
                    }
                }
                Some(i) => {
                    let v = &live[i];
                    if !eligible(v) {
                        return Err(format!(
                            "ineligible victim {v:?} for urgent \
                             priority {urgent_priority}"
                        ));
                    }
                    for (j, o) in live.iter().enumerate() {
                        if j == i || !eligible(o) {
                            continue;
                        }
                        let strictly_lower = o.priority < v.priority
                            || (o.priority == v.priority
                                && match (o.due, v.due) {
                                    (None, Some(_)) => true,
                                    (Some(a), Some(b)) => a > b,
                                    _ => false,
                                });
                        if strictly_lower {
                            return Err(format!(
                                "victim {i} ({v:?}) not lowest-value: \
                                 {j} ({o:?}) is lower"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
