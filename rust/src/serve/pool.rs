//! The engine worker pool: N workers, each owning a full inference engine
//! built *inside* its thread from a [`ModelState`] clone — the `xla`
//! runtime types are `Rc`-based and `!Send`, so only host-resident state
//! crosses thread boundaries (the same topology the training workers and
//! the pipelined engine's stage threads use).
//!
//! All workers pull from one [`Scheduler`] queue and report completions
//! over an mpsc channel. The pool deliberately exposes more than the eval
//! harness's `Generator` trait (text + seconds): serving metrics need the
//! token counts and per-exit [`ExitStats`](crate::inference::ExitStats)
//! carried by [`GenOutput`], so workers drive engines through the
//! [`PoolEngine`] adapter below.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::inference::{
    GenOutput, ModelState, PipelinedEngine, SequentialEngine,
};

use super::metrics::ServeMetrics;
use super::request::{ServeRequest, ServeResponse};
use super::scheduler::{Policy, Scheduler};

/// Which engine each pool worker wraps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// [`SequentialEngine`] — KV recomputation ("recompute" on the CLI).
    Sequential,
    /// [`PipelinedEngine`] — thread-per-stage KV back-fill.
    Pipelined,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "recompute" | "sequential" => Ok(EngineKind::Sequential),
            "pipelined" => Ok(EngineKind::Pipelined),
            other => {
                bail!("unknown engine kind {other:?} (recompute|pipelined)")
            }
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    pub workers: usize,
    pub engine: EngineKind,
    /// Default exit threshold; requests may override per-request.
    pub threshold: f32,
    pub policy: Policy,
}

/// The engine surface the pool needs beyond `Generator`: token outputs
/// with exit stats, and per-request threshold updates.
trait PoolEngine {
    fn apply_threshold(&mut self, t: f32);
    fn generate_out(
        &mut self,
        prompt: &str,
        max_new: usize,
    ) -> Result<GenOutput>;
    /// Tear down engine-owned resources (threads), if any.
    fn finish(self: Box<Self>) {}
}

impl PoolEngine for SequentialEngine {
    fn apply_threshold(&mut self, t: f32) {
        self.threshold = t;
    }

    fn generate_out(
        &mut self,
        prompt: &str,
        max_new: usize,
    ) -> Result<GenOutput> {
        self.generate_text(prompt, max_new)
    }
}

impl PoolEngine for PipelinedEngine {
    fn apply_threshold(&mut self, t: f32) {
        self.set_threshold(t);
    }

    fn generate_out(
        &mut self,
        prompt: &str,
        max_new: usize,
    ) -> Result<GenOutput> {
        self.generate_text(prompt, max_new)
    }

    fn finish(self: Box<Self>) {
        (*self).shutdown();
    }
}

enum WorkerEvent {
    /// Engine built and compiled; the worker is about to start serving.
    Ready { worker: usize },
    Done(ServeResponse),
    /// One request failed; the worker keeps serving.
    Failed { id: u64, worker: usize, error: String },
    /// The worker itself died (engine construction failed).
    Fatal { worker: usize, error: String },
}

/// A pool of engine workers multiplexing a shared request queue.
///
/// Every submitted request produces exactly one `Done`/`Failed` event, and
/// [`EnginePool::run_batch`] consumes exactly one event per request it
/// submitted — so batches never see a previous batch's responses. Direct
/// [`EnginePool::submit`] is for fire-and-forget use only and must not be
/// mixed with `run_batch` on the same pool.
pub struct EnginePool {
    cfg: PoolConfig,
    sched: Arc<Scheduler>,
    events: Receiver<WorkerEvent>,
    /// Events received while waiting for something else (e.g. a `Done`
    /// arriving during the readiness wait); consumed before `recv`.
    stash: VecDeque<WorkerEvent>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Workers that have not reported `Fatal`.
    alive: usize,
    /// Every live worker has reported `Ready`.
    ready: bool,
}

impl EnginePool {
    /// Spawn `cfg.workers` engine workers over clones of `state`. Engine
    /// construction (compiling the stage executables) happens inside each
    /// worker thread; construction failures surface on the next
    /// [`EnginePool::run_batch`].
    pub fn new(state: ModelState, cfg: PoolConfig) -> EnginePool {
        assert!(cfg.workers > 0, "pool needs at least one worker");
        let sched = Arc::new(Scheduler::new(cfg.policy));
        let (tx, events) = channel::<WorkerEvent>();
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let sched = Arc::clone(&sched);
            let tx = tx.clone();
            let state = state.clone();
            let handle = std::thread::Builder::new()
                .name(format!("serve-{w}"))
                .spawn(move || worker_main(w, state, cfg, sched, tx))
                .expect("spawn serve worker");
            workers.push(handle);
        }
        // Workers hold the only event senders, so `events.recv` errors
        // out instead of hanging if every worker dies.
        drop(tx);
        let alive = workers.len();
        EnginePool {
            cfg,
            sched,
            events,
            stash: VecDeque::new(),
            workers,
            alive,
            ready: false,
        }
    }

    pub fn config(&self) -> PoolConfig {
        self.cfg
    }

    /// Enqueue one request (non-blocking). The response event stays in
    /// the pool's channel; use `run_batch` unless you never read results.
    pub fn submit(&self, req: ServeRequest) {
        self.sched.push(req);
    }

    /// Next event, preferring ones stashed during the readiness wait.
    fn next_event(&mut self) -> Result<WorkerEvent> {
        if let Some(e) = self.stash.pop_front() {
            return Ok(e);
        }
        self.events
            .recv()
            .ok()
            .context("all pool workers exited unexpectedly")
    }

    /// Block until every live worker has built its engine (or died
    /// trying), so batch wall-clocks measure serving, not compilation.
    fn wait_ready(&mut self) -> Result<()> {
        if self.ready {
            return Ok(());
        }
        let mut pending = self.workers.len();
        let mut last_error = String::new();
        while pending > 0 {
            match self.next_event()? {
                WorkerEvent::Ready { .. } => pending -= 1,
                WorkerEvent::Fatal { worker, error } => {
                    pending -= 1;
                    self.alive -= 1;
                    eprintln!("[serve] worker {worker} died: {error}");
                    last_error = error;
                }
                other => self.stash.push_back(other),
            }
        }
        if self.alive == 0 {
            bail!("every pool worker died; last error: {last_error}");
        }
        self.ready = true;
        Ok(())
    }

    /// Submit a whole request set, wait for every completion, and return
    /// the responses (sorted by request id) plus aggregate metrics. Any
    /// failed request fails the whole batch — but only after every
    /// request is accounted for, so the pool stays reusable.
    pub fn run_batch(
        &mut self,
        reqs: Vec<ServeRequest>,
    ) -> Result<(Vec<ServeResponse>, ServeMetrics)> {
        self.wait_ready()?;
        if self.alive == 0 {
            bail!("no live pool workers");
        }
        let n = reqs.len();
        let t0 = Instant::now();
        for r in reqs {
            self.submit(r);
        }
        let mut responses = Vec::with_capacity(n);
        let mut failures = Vec::new();
        while responses.len() + failures.len() < n {
            match self.next_event()? {
                WorkerEvent::Done(r) => responses.push(r),
                WorkerEvent::Failed { id, worker, error } => {
                    failures.push(format!(
                        "request {id} on worker {worker}: {error}"
                    ));
                }
                WorkerEvent::Fatal { worker, error } => {
                    self.alive -= 1;
                    if self.alive == 0 {
                        bail!(
                            "every pool worker died with requests \
                             outstanding; last error (worker {worker}): \
                             {error}"
                        );
                    }
                    eprintln!("[serve] worker {worker} died: {error}");
                }
                WorkerEvent::Ready { .. } => {}
            }
        }
        if !failures.is_empty() {
            bail!("{} of {n} requests failed: {}", failures.len(),
                  failures.join("; "));
        }
        let wall = t0.elapsed().as_secs_f64();
        responses.sort_by_key(|r| r.id);
        let metrics = ServeMetrics::from_responses(&responses, wall);
        Ok((responses, metrics))
    }

    /// Close the queue, drain, and join every worker.
    pub fn shutdown(mut self) -> Result<()> {
        self.sched.close();
        for (i, h) in std::mem::take(&mut self.workers)
            .into_iter()
            .enumerate()
        {
            if h.join().is_err() {
                bail!("serve worker {i} panicked");
            }
        }
        Ok(())
    }
}

impl Drop for EnginePool {
    /// Error paths that skip [`EnginePool::shutdown`] must still release
    /// the workers: closing the queue makes every `Scheduler::pop` return
    /// `None`, so the (detached) threads drain and exit instead of
    /// blocking forever on the condvar.
    fn drop(&mut self) {
        self.sched.close();
    }
}

fn worker_main(
    worker: usize,
    state: ModelState,
    cfg: PoolConfig,
    sched: Arc<Scheduler>,
    events: Sender<WorkerEvent>,
) {
    let mut engine: Box<dyn PoolEngine> = match build_engine(state, cfg) {
        Ok(e) => e,
        Err(e) => {
            events
                .send(WorkerEvent::Fatal { worker, error: format!("{e:#}") })
                .ok();
            return;
        }
    };
    events.send(WorkerEvent::Ready { worker }).ok();
    while let Some((req, queue_seconds)) = sched.pop() {
        engine.apply_threshold(req.threshold.unwrap_or(cfg.threshold));
        let t0 = Instant::now();
        // Every popped request must produce exactly one event, even if
        // the engine panics — otherwise `run_batch` waits forever on the
        // lost request while other workers keep the channel open.
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                engine.generate_out(&req.prompt, req.max_new)
            }),
        );
        match result {
            Ok(Ok(output)) => {
                events
                    .send(WorkerEvent::Done(ServeResponse {
                        id: req.id,
                        worker,
                        output,
                        queue_seconds,
                        total_seconds: queue_seconds
                            + t0.elapsed().as_secs_f64(),
                    }))
                    .ok();
            }
            Ok(Err(e)) => {
                events
                    .send(WorkerEvent::Failed {
                        id: req.id,
                        worker,
                        error: format!("{e:#}"),
                    })
                    .ok();
            }
            Err(_) => {
                events
                    .send(WorkerEvent::Failed {
                        id: req.id,
                        worker,
                        error: "worker panicked during generation".into(),
                    })
                    .ok();
                // The engine may be in a corrupt state: retire the worker
                // (dropping the engine tears its threads down via channel
                // close) instead of serving more requests with it.
                events
                    .send(WorkerEvent::Fatal {
                        worker,
                        error: "panicked during generation; worker retired"
                            .into(),
                    })
                    .ok();
                return;
            }
        }
    }
    engine.finish();
}

fn build_engine(
    state: ModelState,
    cfg: PoolConfig,
) -> Result<Box<dyn PoolEngine>> {
    Ok(match cfg.engine {
        EngineKind::Sequential => Box::new(
            SequentialEngine::new(state, cfg.threshold)
                .context("building sequential engine")?,
        ),
        EngineKind::Pipelined => Box::new(
            PipelinedEngine::new(state, cfg.threshold)
                .context("building pipelined engine")?,
        ),
    })
}
