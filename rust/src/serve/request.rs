//! Serving request/response types and request-set builders.

use std::time::Duration;

use crate::data::tasks::EvalTask;
use crate::inference::{ExitPolicy, GenOutput};

/// One generation request; `id`s are caller-assigned and echoed back in
/// the response (the pool sorts batch results by id).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    /// Per-request exit policy; `None` uses the pool default
    /// ([`crate::serve::PoolConfig::policy`]).
    pub policy: Option<ExitPolicy>,
    /// Scheduling priority under `Policy::Priority` — higher is served
    /// first (default 0).
    pub priority: i32,
    /// Relative deadline from submission. Under `Policy::Priority`, ties
    /// in priority are served earliest-deadline-first; requests without a
    /// deadline queue behind any deadlined peer of the same priority.
    pub deadline: Option<Duration>,
    /// Tenant id for weighted-fairness scheduling (default 0). When the
    /// scheduler is configured with tenant weights, each tenant's share
    /// of dispatched work tracks its weight even under bursty arrivals;
    /// ids outside the configured weight table share tenant 0's
    /// accounting.
    pub tenant: usize,
    /// Arrival offset relative to the batch start: the batch driver
    /// holds this request's submission until the offset elapses, so one
    /// batch can model staggered/bursty arrivals (a deadlined request
    /// arriving while a long session already holds the only live slot
    /// is what preemption exists for). Offsets are honored in request
    /// order; a later request with a smaller offset submits immediately.
    pub start_after: Option<Duration>,
    /// Conversation id keying the pool's conversation registry. When
    /// set, the pool snapshots the turn's end-of-turn KV state (prompt
    /// ⧺ generated) into its snapshot store on completion, and a later
    /// request with the same id whose prompt extends that history
    /// restores it — prefilling only the new turn's text. Conversations
    /// idle past the pool's TTL are expired and their stored history
    /// released.
    pub conversation: Option<u64>,
}

impl ServeRequest {
    pub fn new(
        id: u64,
        prompt: impl Into<String>,
        max_new: usize,
    ) -> ServeRequest {
        ServeRequest {
            id,
            prompt: prompt.into(),
            max_new,
            policy: None,
            priority: 0,
            deadline: None,
            tenant: 0,
            start_after: None,
            conversation: None,
        }
    }

    /// Serve this request under its own exit policy instead of the pool
    /// default.
    pub fn with_policy(mut self, policy: ExitPolicy) -> ServeRequest {
        self.policy = Some(policy);
        self
    }

    /// Sugar for [`ServeRequest::with_policy`] with the paper's
    /// confidence rule — the migration spelling for pre-policy callers.
    pub fn with_threshold(self, t: f32) -> ServeRequest {
        self.with_policy(ExitPolicy::confidence(t))
    }

    pub fn with_priority(mut self, priority: i32) -> ServeRequest {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> ServeRequest {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_tenant(mut self, tenant: usize) -> ServeRequest {
        self.tenant = tenant;
        self
    }

    /// Delay this request's submission by `offset` from batch start
    /// (staggered-arrival modeling; see [`ServeRequest::start_after`]).
    pub fn with_start_after(mut self, offset: Duration) -> ServeRequest {
        self.start_after = Some(offset);
        self
    }

    /// Serve this request as one turn of conversation `id`: its
    /// end-of-turn KV state is snapshotted for the conversation's next
    /// turn, and its own prefill restores whatever history the previous
    /// turn left (see [`ServeRequest::conversation`]).
    pub fn with_conversation(mut self, id: u64) -> ServeRequest {
        self.conversation = Some(id);
        self
    }
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: u64,
    /// Index of the pool worker that served the request.
    pub worker: usize,
    pub output: GenOutput,
    /// Time the request waited queued before a worker admitted it.
    pub queue_seconds: f64,
    /// Time to first token: queue wait + prefill + the first decode step.
    /// Equals `total_seconds` for degenerate requests that emit nothing.
    pub ttft_seconds: f64,
    /// Per-token emission gaps, one entry per generated token:
    /// `token_seconds[0]` spans admission to the first token (prefill
    /// included), later entries the gap since the previous token — under
    /// continuous batching that includes steps the worker spent on other
    /// live sessions.
    pub token_seconds: Vec<f64>,
    /// Queue + service time — the latency a client observes.
    pub total_seconds: f64,
    /// The request's relative deadline, echoed back so metrics can count
    /// deadline misses (`total_seconds` vs. this).
    pub deadline: Option<Duration>,
    /// The request's tenant id, echoed back so metrics can report
    /// per-tenant token shares.
    pub tenant: usize,
    /// Recovery re-admission attempts this request survived (0 for the
    /// common fault-free case). A non-zero count means the self-healing
    /// layer restored the session from a micro-checkpoint (or re-ran it
    /// from scratch) after a fault — invisibly: the stream is identical
    /// to a fault-free run.
    pub retries: u32,
}

/// Build an `n`-request set by cycling the task suite's prompts,
/// round-robin across tasks (for prompt-length diversity), skipping
/// examples whose prompt + generation budget exceed the KV-cache capacity
/// (byte tokenizer: one token per byte, plus BOS and slack).
///
/// Panics if no example fits — the capacity is then too small to serve
/// the suite at all.
pub fn requests_from_tasks(
    suite: &[EvalTask],
    n: usize,
    max_seq: usize,
) -> Vec<ServeRequest> {
    let per_task: Vec<Vec<(&String, usize)>> = suite
        .iter()
        .map(|t| {
            t.examples
                .iter()
                .filter(|e| e.prompt.len() + t.max_new_tokens + 4 < max_seq)
                .map(|e| (&e.prompt, t.max_new_tokens))
                .collect()
        })
        .collect();
    let longest = per_task.iter().map(|v| v.len()).max().unwrap_or(0);
    let mut flat = Vec::new();
    for i in 0..longest {
        for tv in &per_task {
            if let Some(&(p, m)) = tv.get(i) {
                flat.push((p, m));
            }
        }
    }
    assert!(
        !flat.is_empty(),
        "no task example fits cache capacity {max_seq}"
    );
    (0..n)
        .map(|i| {
            let (prompt, max_new) = flat[i % flat.len()];
            ServeRequest::new(i as u64, prompt.as_str(), max_new)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use crate::data::synth::{Corpus, CorpusSpec};
    use crate::data::tasks;

    use super::*;

    #[test]
    fn request_set_cycles_and_fits_capacity() {
        let c = Corpus::build(&CorpusSpec {
            seed: 2,
            n_entities: 8,
            target_bytes: 20_000,
        });
        let suite = tasks::all_tasks(&c, 4, 1);
        let reqs = requests_from_tasks(&suite, 10, 256);
        assert_eq!(reqs.len(), 10);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.prompt.len() + r.max_new + 4 < 256, "{r:?}");
            assert!(r.policy.is_none());
        }
        // Round-robin across tasks: the first few requests are not all
        // from the same task (prompts differ in shape).
        assert_ne!(reqs[0].prompt, reqs[1].prompt);
    }

    #[test]
    fn per_request_policy_override() {
        // `with_threshold` is sugar for the confidence policy.
        let r = ServeRequest::new(3, "hi", 8).with_threshold(0.4);
        assert_eq!(r.policy, Some(ExitPolicy::confidence(0.4)));
        assert_eq!(r.priority, 0);
        assert_eq!(r.deadline, None);
        let r = ServeRequest::new(4, "hi", 8)
            .with_policy(ExitPolicy::Entropy { max_nats: 1.0 });
        assert_eq!(r.policy, Some(ExitPolicy::Entropy { max_nats: 1.0 }));
    }

    #[test]
    fn priority_and_deadline_builders() {
        let r = ServeRequest::new(4, "hi", 8)
            .with_priority(3)
            .with_deadline(std::time::Duration::from_millis(250))
            .with_tenant(2);
        assert_eq!(r.priority, 3);
        assert_eq!(
            r.deadline,
            Some(std::time::Duration::from_millis(250))
        );
        assert_eq!(r.tenant, 2);
        assert_eq!(ServeRequest::new(5, "hi", 8).tenant, 0);
        assert_eq!(ServeRequest::new(5, "hi", 8).start_after, None);
        let r = ServeRequest::new(6, "hi", 8)
            .with_start_after(std::time::Duration::from_millis(5));
        assert_eq!(
            r.start_after,
            Some(std::time::Duration::from_millis(5))
        );
        assert_eq!(ServeRequest::new(7, "hi", 8).conversation, None);
        let r = ServeRequest::new(7, "hi", 8).with_conversation(42);
        assert_eq!(r.conversation, Some(42));
    }
}
