//! Aggregate serving metrics: throughput, latency percentiles, queueing,
//! time-to-first-token, per-token latency, and merged per-exit usage —
//! the serving-side analogue of the paper's Figure 8 axes
//! (quality/latency vs. threshold), lifted to a multi-request batch.

use std::sync::Mutex;

use crate::inference::{ExitStats, LaneTraffic, PrefixCacheStats, TierStats};
pub use crate::metrics::percentile;

use super::faults::{FaultSite, FAULT_SITES};
use super::request::ServeResponse;

/// Lane-fusion activity of the decode hot path: how often the pool
/// stepped sessions through fused batched passes vs solo windows — the
/// "did compute batching actually happen" observability the fused
/// decode work is judged by — plus the host⇄device KV-cache traffic the
/// device-resident lane groups exist to eliminate ("did residency
/// actually happen"): zero per-step gathers/scatters at steady state,
/// with traffic only at group formation and lane departure.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LaneStats {
    /// Fused `run_lanes` invocations (each is one batched XLA dispatch
    /// chain per stage, whatever the lane count).
    pub fused_calls: u64,
    /// Decode steps taken inside fused calls (one per lane per call).
    pub fused_steps: u64,
    /// Decode steps taken on the solo windowed path.
    pub solo_steps: u64,
    /// Stages skipped entirely because every lane of a fused call had
    /// already taken an early exit.
    pub stages_skipped: u64,
    /// Engine-resident exit-policy swaps workers performed. With
    /// policy-ordered rounds this is bounded by distinct policies per
    /// round, not by live sessions (the pre-lane loop swapped once per
    /// adjacent policy change, i.e. up to once per step).
    pub policy_applies: u64,
    /// Host→device lane-cache copies (lane×stage units): group
    /// formations under residency, every fused step without it.
    pub cache_gathers: u64,
    /// Device→host lane-cache copies (lane×stage units): group
    /// dissolutions under residency, every fused step without it.
    pub cache_scatters: u64,
    /// Bytes moved host→device by `cache_gathers`.
    pub cache_gather_bytes: u64,
    /// Bytes moved device→host by `cache_scatters`.
    pub cache_scatter_bytes: u64,
    /// Fused rounds served by an already-resident lane group — the
    /// steady-state fast path (no cache traffic at all).
    pub warm_group_hits: u64,
    /// Fused rounds that had to gather a fresh lane group (first round
    /// of a new group, or the scheduler re-planned membership).
    pub cold_group_forms: u64,
    /// Lane-occupancy histogram: (lane count B, fused calls at B).
    pub occupancy: Vec<(usize, u64)>,
}

impl LaneStats {
    /// Decode steps per engine dispatch round: `(fused + solo steps) /
    /// (fused calls + solo steps)`. Above 1.0 means fused lane groups
    /// formed — N live sessions cost fewer than N dispatch rounds.
    pub fn steps_per_dispatch(&self) -> f64 {
        let dispatches = self.fused_calls + self.solo_steps;
        if dispatches == 0 {
            return 0.0;
        }
        (self.fused_steps + self.solo_steps) as f64 / dispatches as f64
    }

    fn occupancy_add(&mut self, width: usize, calls: u64) {
        match self.occupancy.iter_mut().find(|(w, _)| *w == width) {
            Some(e) => e.1 += calls,
            None => {
                self.occupancy.push((width, calls));
                self.occupancy.sort();
            }
        }
    }

    /// Accumulate another reading into this one.
    pub fn merge(&mut self, other: &LaneStats) {
        self.fused_calls += other.fused_calls;
        self.fused_steps += other.fused_steps;
        self.solo_steps += other.solo_steps;
        self.stages_skipped += other.stages_skipped;
        self.policy_applies += other.policy_applies;
        self.cache_gathers += other.cache_gathers;
        self.cache_scatters += other.cache_scatters;
        self.cache_gather_bytes += other.cache_gather_bytes;
        self.cache_scatter_bytes += other.cache_scatter_bytes;
        self.warm_group_hits += other.warm_group_hits;
        self.cold_group_forms += other.cold_group_forms;
        for &(w, c) in &other.occupancy {
            self.occupancy_add(w, c);
        }
    }

    /// Counter delta `self - baseline` (saturating): activity since an
    /// earlier reading of the same counters.
    pub fn since(&self, baseline: &LaneStats) -> LaneStats {
        let mut out = LaneStats {
            fused_calls: self
                .fused_calls
                .saturating_sub(baseline.fused_calls),
            fused_steps: self
                .fused_steps
                .saturating_sub(baseline.fused_steps),
            solo_steps: self.solo_steps.saturating_sub(baseline.solo_steps),
            stages_skipped: self
                .stages_skipped
                .saturating_sub(baseline.stages_skipped),
            policy_applies: self
                .policy_applies
                .saturating_sub(baseline.policy_applies),
            cache_gathers: self
                .cache_gathers
                .saturating_sub(baseline.cache_gathers),
            cache_scatters: self
                .cache_scatters
                .saturating_sub(baseline.cache_scatters),
            cache_gather_bytes: self
                .cache_gather_bytes
                .saturating_sub(baseline.cache_gather_bytes),
            cache_scatter_bytes: self
                .cache_scatter_bytes
                .saturating_sub(baseline.cache_scatter_bytes),
            warm_group_hits: self
                .warm_group_hits
                .saturating_sub(baseline.warm_group_hits),
            cold_group_forms: self
                .cold_group_forms
                .saturating_sub(baseline.cold_group_forms),
            occupancy: Vec::new(),
        };
        for &(w, c) in &self.occupancy {
            let base = baseline
                .occupancy
                .iter()
                .find(|(bw, _)| *bw == w)
                .map_or(0, |(_, bc)| *bc);
            if c > base {
                out.occupancy_add(w, c - base);
            }
        }
        out
    }
}

/// Interleaved stage-chain activity of the pipelined decode hot path:
/// how many live sessions each interleaved round pushed down the chain
/// together — the "did bubble filling actually happen" observability,
/// the interleaving analogue of [`LaneStats`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct InterleaveStats {
    /// Interleaved rounds (each submits every member's width-1 window
    /// down the stage chain before collecting any token).
    pub rounds: u64,
    /// Decode steps taken inside interleaved rounds (one per member per
    /// round).
    pub steps: u64,
    /// In-flight-occupancy histogram: (sessions in flight N, rounds at
    /// N). Any entry with N >= 2 is an observed overlap of sessions on
    /// the chain.
    pub occupancy: Vec<(usize, u64)>,
}

impl InterleaveStats {
    /// Mean sessions in flight per interleaved round.
    pub fn mean_in_flight(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.steps as f64 / self.rounds as f64
    }

    /// Deepest interleaving any round reached.
    pub fn max_in_flight(&self) -> usize {
        self.occupancy.iter().map(|&(n, _)| n).max().unwrap_or(0)
    }

    fn occupancy_add(&mut self, width: usize, rounds: u64) {
        match self.occupancy.iter_mut().find(|(w, _)| *w == width) {
            Some(e) => e.1 += rounds,
            None => {
                self.occupancy.push((width, rounds));
                self.occupancy.sort();
            }
        }
    }

    /// Accumulate another reading into this one.
    pub fn merge(&mut self, other: &InterleaveStats) {
        self.rounds += other.rounds;
        self.steps += other.steps;
        for &(w, c) in &other.occupancy {
            self.occupancy_add(w, c);
        }
    }

    /// Counter delta `self - baseline` (saturating): activity since an
    /// earlier reading of the same counters.
    pub fn since(&self, baseline: &InterleaveStats) -> InterleaveStats {
        let mut out = InterleaveStats {
            rounds: self.rounds.saturating_sub(baseline.rounds),
            steps: self.steps.saturating_sub(baseline.steps),
            occupancy: Vec::new(),
        };
        for &(w, c) in &self.occupancy {
            let base = baseline
                .occupancy
                .iter()
                .find(|(bw, _)| *bw == w)
                .map_or(0, |(_, bc)| *bc);
            if c > base {
                out.occupancy_add(w, c - base);
            }
        }
        out
    }
}

/// Control-plane activity of the serving pool: preemptions (live
/// sessions parked for an urgent deadlined request), resumes of parked
/// sessions, park/resume fault counts, admission-control sheds and
/// degrades, and the park store's occupancy peak — the "did the control
/// plane actually act" observability the SLO features are judged by.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SloStats {
    /// Live sessions parked to admit an urgent deadlined request.
    pub preemptions: u64,
    /// Parked sessions resumed from their snapshots.
    pub resumes: u64,
    /// Park attempts whose cache snapshot failed (the request fails
    /// typed; the batch keeps going).
    pub park_failures: u64,
    /// Resume attempts whose cache restore failed (ditto).
    pub resume_failures: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Requests degraded (budget-clamped) by admission control.
    pub degraded: u64,
    /// Most sessions the park store held at once.
    pub parked_peak: u64,
}

impl SloStats {
    /// Accumulate another reading into this one (`parked_peak` takes the
    /// max — it is an occupancy peak, not a flow).
    pub fn merge(&mut self, other: &SloStats) {
        self.preemptions += other.preemptions;
        self.resumes += other.resumes;
        self.park_failures += other.park_failures;
        self.resume_failures += other.resume_failures;
        self.shed += other.shed;
        self.degraded += other.degraded;
        self.parked_peak = self.parked_peak.max(other.parked_peak);
    }

    /// Counter delta `self - baseline` (saturating). `parked_peak`
    /// carries the later reading through: a peak has no meaningful
    /// per-window delta.
    pub fn since(&self, baseline: &SloStats) -> SloStats {
        SloStats {
            preemptions: self
                .preemptions
                .saturating_sub(baseline.preemptions),
            resumes: self.resumes.saturating_sub(baseline.resumes),
            park_failures: self
                .park_failures
                .saturating_sub(baseline.park_failures),
            resume_failures: self
                .resume_failures
                .saturating_sub(baseline.resume_failures),
            shed: self.shed.saturating_sub(baseline.shed),
            degraded: self.degraded.saturating_sub(baseline.degraded),
            parked_peak: self.parked_peak,
        }
    }
}

/// Thread-safe control-plane counters shared by every worker of a pool
/// (the SLO analogue of [`LaneCounters`]). Shed/degrade counts live on
/// the scheduler and are folded in at metrics-assembly time.
#[derive(Debug, Default)]
pub struct SloCounters {
    inner: Mutex<SloStats>,
}

impl SloCounters {
    /// Counter snapshot.
    pub fn stats(&self) -> SloStats {
        *self.inner.lock().unwrap()
    }

    /// One live session parked to admit an urgent request.
    pub fn record_preemption(&self) {
        self.inner.lock().unwrap().preemptions += 1;
    }

    /// One parked session resumed.
    pub fn record_resume(&self) {
        self.inner.lock().unwrap().resumes += 1;
    }

    /// One park whose snapshot failed.
    pub fn record_park_failure(&self) {
        self.inner.lock().unwrap().park_failures += 1;
    }

    /// One resume whose restore failed.
    pub fn record_resume_failure(&self) {
        self.inner.lock().unwrap().resume_failures += 1;
    }

    /// Observe the park store's current occupancy (keeps the max).
    pub fn observe_parked(&self, parked: u64) {
        let mut s = self.inner.lock().unwrap();
        s.parked_peak = s.parked_peak.max(parked);
    }
}

/// Self-healing activity of the serving pool: faults injected by the
/// chaos plan and observed organically, micro-checkpoints captured,
/// recovery attempts and their outcomes, re-decoded tokens, engine
/// restarts, and worker quarantines — the "did recovery actually work"
/// observability the self-healing layer is judged by.
///
/// Accounting invariant (asserted by the chaos suite): every
/// recovery-*triggering* failure increments exactly one `observed` slot
/// and is later resolved as exactly one of `recoveries` (the session
/// was re-admitted and lived) or `recovery_failures` (its retry budget
/// ran out), so `recoveries == observed_total() - recovery_failures`
/// once a batch drains. Failures *inside* a recovery episode (e.g. a
/// restore that fails on re-admission) consume `retries`, not
/// `observed`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Faults the chaos plan injected, per seam
    /// ([`FaultSite::index`]-indexed; [`FaultSite::ALL`] order).
    pub injected: [u64; FAULT_SITES],
    /// Recovery-triggering failures observed, per seam — injected or
    /// organic, attributed by
    /// [`classify_failure`](super::faults::classify_failure).
    pub observed: [u64; FAULT_SITES],
    /// Decode-time micro-checkpoints captured into the bounded store.
    pub checkpoints: u64,
    /// Checkpoint captures that errored or were refused by the store's
    /// capacity (best-effort: the session keeps its previous
    /// checkpoint).
    pub checkpoint_failures: u64,
    /// Recovery re-admission attempts (every episode consumes at least
    /// one; failed attempts retry with exponential backoff).
    pub retries: u64,
    /// Recovery episodes that ended with the session live again.
    pub recoveries: u64,
    /// Recovery episodes that exhausted their retry budget (the request
    /// fails typed, carrying its retry count).
    pub recovery_failures: u64,
    /// Tokens re-decoded between a restored checkpoint and the failure
    /// point — suppressed from the stream, so recovery stays invisible
    /// to the client.
    pub redecoded_tokens: u64,
    /// Engines torn down and rebuilt by the supervisor (poisoned stage
    /// chain or worker panic).
    pub restarts: u64,
    /// Workers quarantined after too many consecutive engine failures
    /// (capacity shrinks; the shed/degrade path absorbs the load).
    pub quarantines: u64,
}

impl FaultStats {
    /// Faults injected across all seams.
    pub fn injected_total(&self) -> u64 {
        self.injected.iter().sum()
    }

    /// Recovery-triggering failures observed across all seams.
    pub fn observed_total(&self) -> u64 {
        self.observed.iter().sum()
    }

    /// Accumulate another reading into this one.
    pub fn merge(&mut self, other: &FaultStats) {
        for i in 0..FAULT_SITES {
            self.injected[i] += other.injected[i];
            self.observed[i] += other.observed[i];
        }
        self.checkpoints += other.checkpoints;
        self.checkpoint_failures += other.checkpoint_failures;
        self.retries += other.retries;
        self.recoveries += other.recoveries;
        self.recovery_failures += other.recovery_failures;
        self.redecoded_tokens += other.redecoded_tokens;
        self.restarts += other.restarts;
        self.quarantines += other.quarantines;
    }

    /// Counter delta `self - baseline` (saturating): activity since an
    /// earlier reading of the same counters.
    pub fn since(&self, baseline: &FaultStats) -> FaultStats {
        let mut out = FaultStats {
            checkpoints: self
                .checkpoints
                .saturating_sub(baseline.checkpoints),
            checkpoint_failures: self
                .checkpoint_failures
                .saturating_sub(baseline.checkpoint_failures),
            retries: self.retries.saturating_sub(baseline.retries),
            recoveries: self.recoveries.saturating_sub(baseline.recoveries),
            recovery_failures: self
                .recovery_failures
                .saturating_sub(baseline.recovery_failures),
            redecoded_tokens: self
                .redecoded_tokens
                .saturating_sub(baseline.redecoded_tokens),
            restarts: self.restarts.saturating_sub(baseline.restarts),
            quarantines: self
                .quarantines
                .saturating_sub(baseline.quarantines),
            ..FaultStats::default()
        };
        for i in 0..FAULT_SITES {
            out.injected[i] =
                self.injected[i].saturating_sub(baseline.injected[i]);
            out.observed[i] =
                self.observed[i].saturating_sub(baseline.observed[i]);
        }
        out
    }
}

/// Thread-safe self-healing counters shared by every worker of a pool
/// (the fault analogue of [`SloCounters`]).
#[derive(Debug, Default)]
pub struct FaultCounters {
    inner: Mutex<FaultStats>,
}

impl FaultCounters {
    /// Counter snapshot.
    pub fn stats(&self) -> FaultStats {
        *self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, FaultStats> {
        // Counter state is plain-old-data: a panic mid-update cannot
        // leave it torn, so a poisoned lock is safe to adopt (the
        // supervisor keeps recording through worker panics).
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// One fault injected by the chaos plan at `site`.
    pub fn record_injected(&self, site: FaultSite) {
        self.lock().injected[site.index()] += 1;
    }

    /// One recovery-triggering failure observed at `site`.
    pub fn record_observed(&self, site: FaultSite) {
        self.lock().observed[site.index()] += 1;
    }

    /// One micro-checkpoint capture: stored, or refused/errored.
    pub fn record_checkpoint(&self, stored: bool) {
        let mut s = self.lock();
        if stored {
            s.checkpoints += 1;
        } else {
            s.checkpoint_failures += 1;
        }
    }

    /// One recovery re-admission attempt.
    pub fn record_retry(&self) {
        self.lock().retries += 1;
    }

    /// One recovery episode resolved with the session live again.
    pub fn record_recovery(&self) {
        self.lock().recoveries += 1;
    }

    /// One recovery episode resolved by an exhausted retry budget.
    pub fn record_recovery_failure(&self) {
        self.lock().recovery_failures += 1;
    }

    /// `n` checkpoint-tail tokens re-decoded invisibly.
    pub fn record_redecoded(&self, n: u64) {
        self.lock().redecoded_tokens += n;
    }

    /// One engine torn down and rebuilt by the supervisor.
    pub fn record_restart(&self) {
        self.lock().restarts += 1;
    }

    /// One worker quarantined after consecutive engine failures.
    pub fn record_quarantine(&self) {
        self.lock().quarantines += 1;
    }
}

/// Conversational-serving activity of the pool: turns served, history
/// restores on follow-up turns, end-of-turn snapshots taken, and idle
/// expiries — the "did multi-turn reuse actually happen" observability
/// the conversation layer is judged by. A follow-up turn whose history
/// restore hits pays prefill only for its own new text (O(new turn),
/// not O(history)); `saved_positions` counts what the restores skipped.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ConvoStats {
    /// Conversation-tagged requests completed (turns served).
    pub turns: u64,
    /// Opening turns admitted (no history to restore yet).
    pub first_turns: u64,
    /// Follow-up turns whose admission restored cached history.
    pub restore_hits: u64,
    /// Follow-up turns that re-prefilled their history cold.
    pub restore_misses: u64,
    /// Prefill positions conversation turns skipped thanks to restores.
    pub saved_positions: u64,
    /// End-of-turn snapshots stored for the next turn.
    pub snapshots: u64,
    /// End-of-turn snapshots the store refused (budget pressure).
    pub snapshots_rejected: u64,
    /// End-of-turn snapshot captures that errored (best-effort: the
    /// turn's response is unaffected; the next turn prefills cold).
    pub snapshot_failures: u64,
    /// Conversations expired under the idle TTL (registry entry dropped
    /// and stored history released).
    pub expired: u64,
}

impl ConvoStats {
    /// Follow-up turns that restored history over all follow-up turns
    /// (0.0 before any follow-up turn).
    pub fn restore_hit_rate(&self) -> f64 {
        let followups = self.restore_hits + self.restore_misses;
        self.restore_hits as f64 / followups.max(1) as f64
    }

    /// Mean prefill positions saved per served turn.
    pub fn saved_per_turn(&self) -> f64 {
        self.saved_positions as f64 / self.turns.max(1) as f64
    }

    /// Accumulate another reading into this one.
    pub fn merge(&mut self, other: &ConvoStats) {
        self.turns += other.turns;
        self.first_turns += other.first_turns;
        self.restore_hits += other.restore_hits;
        self.restore_misses += other.restore_misses;
        self.saved_positions += other.saved_positions;
        self.snapshots += other.snapshots;
        self.snapshots_rejected += other.snapshots_rejected;
        self.snapshot_failures += other.snapshot_failures;
        self.expired += other.expired;
    }

    /// Counter delta `self - baseline` (saturating): activity since an
    /// earlier reading of the same counters.
    pub fn since(&self, baseline: &ConvoStats) -> ConvoStats {
        ConvoStats {
            turns: self.turns.saturating_sub(baseline.turns),
            first_turns: self
                .first_turns
                .saturating_sub(baseline.first_turns),
            restore_hits: self
                .restore_hits
                .saturating_sub(baseline.restore_hits),
            restore_misses: self
                .restore_misses
                .saturating_sub(baseline.restore_misses),
            saved_positions: self
                .saved_positions
                .saturating_sub(baseline.saved_positions),
            snapshots: self.snapshots.saturating_sub(baseline.snapshots),
            snapshots_rejected: self
                .snapshots_rejected
                .saturating_sub(baseline.snapshots_rejected),
            snapshot_failures: self
                .snapshot_failures
                .saturating_sub(baseline.snapshot_failures),
            expired: self.expired.saturating_sub(baseline.expired),
        }
    }
}

/// Thread-safe conversation counters shared by every worker of a pool
/// (the conversational analogue of [`SloCounters`]).
#[derive(Debug, Default)]
pub struct ConvoCounters {
    inner: Mutex<ConvoStats>,
}

impl ConvoCounters {
    /// Counter snapshot.
    pub fn stats(&self) -> ConvoStats {
        *self.inner.lock().unwrap()
    }

    /// One opening turn admitted.
    pub fn record_first_turn(&self) {
        self.inner.lock().unwrap().first_turns += 1;
    }

    /// One follow-up turn admitted: whether its history restore hit,
    /// and how many prefill positions the restore skipped.
    pub fn record_restore(&self, hit: bool, saved_positions: u64) {
        let mut s = self.inner.lock().unwrap();
        if hit {
            s.restore_hits += 1;
        } else {
            s.restore_misses += 1;
        }
        s.saved_positions += saved_positions;
    }

    /// One conversation turn completed.
    pub fn record_turn(&self) {
        self.inner.lock().unwrap().turns += 1;
    }

    /// One end-of-turn snapshot capture: stored, or refused by the
    /// store's budget.
    pub fn record_snapshot(&self, stored: bool) {
        let mut s = self.inner.lock().unwrap();
        if stored {
            s.snapshots += 1;
        } else {
            s.snapshots_rejected += 1;
        }
    }

    /// One end-of-turn snapshot capture that errored.
    pub fn record_snapshot_failure(&self) {
        self.inner.lock().unwrap().snapshot_failures += 1;
    }

    /// `n` conversations expired under the idle TTL.
    pub fn record_expired(&self, n: u64) {
        self.inner.lock().unwrap().expired += n;
    }
}

/// Point-in-time snapshot-memory gauges, sampled when a batch closes:
/// every `CacheSnapshot` the serving stack holds, under one roof — the
/// prefix/conversation store (host tier), its pinned device-resident
/// tier, and the control plane's park store. Gauges, not flows: merge
/// and delta semantics do not apply; each batch reports the occupancy
/// it ended with.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SnapshotMemory {
    /// Host-tier snapshots resident in the prefix/conversation store.
    pub cached_entries: usize,
    /// Positions those snapshots hold (the store's budget currency).
    pub cached_positions: usize,
    /// Host bytes those snapshots occupy.
    pub cached_bytes: usize,
    /// Entries pinned device-resident by the tiered store.
    pub device_entries: usize,
    /// Positions pinned device-resident.
    pub device_positions: usize,
    /// Bytes modeled device-resident.
    pub device_bytes: usize,
    /// Sessions parked in the control plane's park store.
    pub parked_entries: usize,
    /// Host bytes their cache snapshots occupy.
    pub parked_bytes: usize,
    /// Live sessions with a decode-time micro-checkpoint in the
    /// self-healing store.
    pub checkpoint_entries: usize,
    /// Host bytes those micro-checkpoints occupy.
    pub checkpoint_bytes: usize,
}

impl SnapshotMemory {
    /// All snapshot bytes the serving stack holds (host copies plus the
    /// device-modeled tier).
    pub fn total_bytes(&self) -> usize {
        self.cached_bytes
            + self.device_bytes
            + self.parked_bytes
            + self.checkpoint_bytes
    }
}

/// One tenant's slice of a batch: requests completed, tokens generated,
/// and its fraction of all generated tokens — what the weighted-fairness
/// accounting is checked against.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantShare {
    pub tenant: usize,
    pub requests: usize,
    pub tokens: usize,
    /// `tokens` over the batch's total generated tokens.
    pub share: f64,
}

/// Thread-safe lane counters shared by every worker of a pool (the
/// lane-fusion analogue of the shared [`PrefixCacheStore`] stats).
///
/// [`PrefixCacheStore`]: crate::inference::PrefixCacheStore
#[derive(Debug, Default)]
pub struct LaneCounters {
    inner: Mutex<LaneStats>,
    interleave: Mutex<InterleaveStats>,
}

impl LaneCounters {
    /// Counter snapshot.
    pub fn stats(&self) -> LaneStats {
        self.inner.lock().unwrap().clone()
    }

    /// One fused call over `width` lanes that skipped `stages_skipped`
    /// stages because every lane had fired.
    pub fn record_fused(&self, width: usize, stages_skipped: usize) {
        let mut s = self.inner.lock().unwrap();
        s.fused_calls += 1;
        s.fused_steps += width as u64;
        s.stages_skipped += stages_skipped as u64;
        s.occupancy_add(width, 1);
    }

    /// One solo decode step.
    pub fn record_solo(&self) {
        self.inner.lock().unwrap().solo_steps += 1;
    }

    /// One engine-resident exit-policy swap.
    pub fn record_policy_apply(&self) {
        self.inner.lock().unwrap().policy_applies += 1;
    }

    /// Fold an engine's lane-cache traffic delta
    /// ([`DecodeBackend::lane_traffic`] read minus the previous read)
    /// into the pool counters. Workers call this once per round.
    ///
    /// [`DecodeBackend::lane_traffic`]:
    /// crate::inference::DecodeBackend::lane_traffic
    pub fn record_traffic(&self, d: &LaneTraffic) {
        let mut s = self.inner.lock().unwrap();
        s.cache_gathers += d.cache_gathers;
        s.cache_scatters += d.cache_scatters;
        s.cache_gather_bytes += d.gather_bytes;
        s.cache_scatter_bytes += d.scatter_bytes;
        s.warm_group_hits += d.warm_hits;
        s.cold_group_forms += d.cold_forms;
    }

    /// Interleaved-round counter snapshot.
    pub fn interleave_stats(&self) -> InterleaveStats {
        self.interleave.lock().unwrap().clone()
    }

    /// One interleaved stage-chain round over `width` live sessions.
    pub fn record_interleaved(&self, width: usize) {
        let mut s = self.interleave.lock().unwrap();
        s.rounds += 1;
        s.steps += width as u64;
        s.occupancy_add(width, 1);
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    /// Generated tokens summed over all requests.
    pub total_tokens: usize,
    /// Wall clock of the whole batch (first submit to last completion) —
    /// the throughput denominator.
    pub wall_seconds: f64,
    pub p50_latency_seconds: f64,
    pub p95_latency_seconds: f64,
    /// Time-to-first-token percentiles across requests (queue + prefill +
    /// first decode step) — the streaming responsiveness metric
    /// continuous batching exists to improve. p99 is the SLO-attainment
    /// tail the control plane is judged by.
    pub p50_ttft_seconds: f64,
    pub p95_ttft_seconds: f64,
    pub p99_ttft_seconds: f64,
    /// Steady-state per-token emission-gap percentiles, pooled over every
    /// generated token of every request *except* each request's first
    /// (whose gap includes prefill and is already reported as TTFT).
    pub p50_token_gap_seconds: f64,
    pub p95_token_gap_seconds: f64,
    pub mean_queue_seconds: f64,
    /// Requests that completed after their stated deadline (queue +
    /// service vs. the request's relative deadline); deadline-less
    /// requests never miss.
    pub deadline_misses: usize,
    /// Requests that carried a deadline at all — the denominator of
    /// [`ServeMetrics::deadline_miss_rate`].
    pub deadlined: usize,
    /// Per-exit usage merged across all requests.
    pub exits: ExitStats,
    /// Prefix KV-cache activity during the batch, read from the pool's
    /// shared store (all zeros when the cache is disabled).
    pub prefix: PrefixCacheStats,
    /// Lane-fusion activity during the batch: fused vs solo decode
    /// steps, lane occupancy, stages skipped by all-lanes-fired, and
    /// policy swaps (all zeros when lane fusion is off or unavailable).
    pub lanes: LaneStats,
    /// Interleaved stage-chain activity during the batch (pipelined
    /// engine): rounds, steps, and the in-flight-sessions occupancy
    /// histogram (all zeros on non-interleaving engines).
    pub interleave: InterleaveStats,
    /// Control-plane activity during the batch: preemptions, resumes,
    /// park/resume faults, sheds, degrades, park-store peak (all zeros
    /// with the control plane disabled).
    pub slo: SloStats,
    /// Conversational-serving activity during the batch: turns served,
    /// history-restore hit rate, prefill positions saved, end-of-turn
    /// snapshots, TTL expiries (all zeros when no request carried a
    /// conversation id).
    pub convo: ConvoStats,
    /// Device-tier activity of the tiered snapshot store during the
    /// batch: device vs host hits, promotions, demotions (all zeros
    /// with the device tier disabled).
    pub tier: TierStats,
    /// Self-healing activity during the batch: injected/observed faults
    /// per seam, micro-checkpoints, recovery retries and outcomes,
    /// re-decoded tokens, engine restarts, quarantines (all zeros with
    /// chaos and recovery off).
    pub faults: FaultStats,
    /// Snapshot-memory occupancy when the batch closed: prefix-store,
    /// device-tier, park-store, and checkpoint-store
    /// entries/positions/bytes under one block (a gauge, unlike the
    /// counter deltas above).
    pub snapshot_memory: SnapshotMemory,
    /// Per-tenant completion shares, ascending by tenant id (one entry,
    /// tenant 0, when the batch never set tenants).
    pub tenants: Vec<TenantShare>,
}

impl ServeMetrics {
    pub fn from_responses(
        responses: &[ServeResponse],
        wall_seconds: f64,
    ) -> ServeMetrics {
        let lats: Vec<f64> =
            responses.iter().map(|r| r.total_seconds).collect();
        let ttfts: Vec<f64> =
            responses.iter().map(|r| r.ttft_seconds).collect();
        // Skip each request's first gap: it spans prefill and would
        // otherwise dominate p95 with what TTFT already measures.
        let gaps: Vec<f64> = responses
            .iter()
            .flat_map(|r| r.token_seconds.iter().skip(1).copied())
            .collect();
        let mut exits = ExitStats::default();
        for r in responses {
            exits.merge(&r.output.stats);
        }
        let total_tokens: usize =
            responses.iter().map(|r| r.output.tokens.len()).sum();
        // Per-tenant completion shares, ascending by tenant id.
        let mut tenants: Vec<TenantShare> = Vec::new();
        for r in responses {
            match tenants.iter_mut().find(|t| t.tenant == r.tenant) {
                Some(t) => {
                    t.requests += 1;
                    t.tokens += r.output.tokens.len();
                }
                None => tenants.push(TenantShare {
                    tenant: r.tenant,
                    requests: 1,
                    tokens: r.output.tokens.len(),
                    share: 0.0,
                }),
            }
        }
        tenants.sort_by_key(|t| t.tenant);
        for t in &mut tenants {
            t.share = t.tokens as f64 / total_tokens.max(1) as f64;
        }
        let n = responses.len().max(1) as f64;
        ServeMetrics {
            requests: responses.len(),
            total_tokens,
            wall_seconds,
            p50_latency_seconds: percentile(&lats, 0.50),
            p95_latency_seconds: percentile(&lats, 0.95),
            p50_ttft_seconds: percentile(&ttfts, 0.50),
            p95_ttft_seconds: percentile(&ttfts, 0.95),
            p99_ttft_seconds: percentile(&ttfts, 0.99),
            p50_token_gap_seconds: percentile(&gaps, 0.50),
            p95_token_gap_seconds: percentile(&gaps, 0.95),
            mean_queue_seconds: responses
                .iter()
                .map(|r| r.queue_seconds)
                .sum::<f64>()
                / n,
            deadline_misses: responses
                .iter()
                .filter(|r| {
                    r.deadline
                        .is_some_and(|d| r.total_seconds > d.as_secs_f64())
                })
                .count(),
            deadlined: responses
                .iter()
                .filter(|r| r.deadline.is_some())
                .count(),
            exits,
            prefix: PrefixCacheStats::default(),
            lanes: LaneStats::default(),
            interleave: InterleaveStats::default(),
            slo: SloStats::default(),
            convo: ConvoStats::default(),
            tier: TierStats::default(),
            faults: FaultStats::default(),
            snapshot_memory: SnapshotMemory::default(),
            tenants,
        }
    }

    /// Deadline misses over deadlined requests (0.0 when no request
    /// carried a deadline) — the SLO-attainment headline number.
    pub fn deadline_miss_rate(&self) -> f64 {
        self.deadline_misses as f64 / self.deadlined.max(1) as f64
    }

    /// Fraction of admissions that restored a cached prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        self.prefix.hit_rate()
    }

    /// Prefill positions skipped thanks to prefix-cache hits.
    pub fn prefill_positions_saved(&self) -> u64 {
        self.prefix.saved_positions
    }

    /// Aggregate generated tokens per wall-clock second.
    pub fn throughput_tps(&self) -> f64 {
        self.total_tokens as f64 / self.wall_seconds.max(1e-9)
    }

    /// Fraction of tokens emitted at early exits.
    pub fn early_fraction(&self, n_layers: usize) -> f64 {
        self.exits.early_fraction(n_layers)
    }
}

#[cfg(test)]
mod tests {
    use crate::inference::GenOutput;

    use super::*;

    fn resp(id: u64, n_tokens: usize, total: f64, queue: f64) -> ServeResponse {
        let mut stats = ExitStats::default();
        for _ in 0..n_tokens {
            stats.record(4);
        }
        // Synthetic but shape-consistent stream timing: the first token
        // costs half the service time, the rest split the remainder.
        let service = total - queue;
        let mut token_seconds = vec![service / 2.0];
        for _ in 1..n_tokens {
            token_seconds.push(service / (2.0 * (n_tokens - 1) as f64));
        }
        ServeResponse {
            id,
            worker: 0,
            output: GenOutput {
                tokens: vec![65; n_tokens],
                text: "a".repeat(n_tokens),
                seconds: service,
                stats,
            },
            queue_seconds: queue,
            ttft_seconds: queue + service / 2.0,
            token_seconds,
            total_seconds: total,
            deadline: None,
            tenant: 0,
            retries: 0,
        }
    }

    #[test]
    fn metrics_aggregate_responses() {
        let rs = vec![resp(0, 4, 0.2, 0.1), resp(1, 6, 0.4, 0.0)];
        let m = ServeMetrics::from_responses(&rs, 0.5);
        assert_eq!(m.requests, 2);
        assert_eq!(m.total_tokens, 10);
        assert!((m.throughput_tps() - 20.0).abs() < 1e-9);
        assert_eq!(m.p50_latency_seconds, 0.2);
        assert_eq!(m.p95_latency_seconds, 0.4);
        assert!((m.mean_queue_seconds - 0.05).abs() < 1e-12);
        assert_eq!(m.exits.total(), 10);
        // Layer 4 == n_layers here: nothing exited early.
        assert_eq!(m.early_fraction(4), 0.0);
    }

    #[test]
    fn metrics_report_ttft_and_token_gaps() {
        // TTFTs: 0.1 + 0.05 = 0.15 and 0.0 + 0.2 = 0.2.
        let rs = vec![resp(0, 4, 0.2, 0.1), resp(1, 6, 0.4, 0.0)];
        let m = ServeMetrics::from_responses(&rs, 0.5);
        assert!((m.p50_ttft_seconds - 0.15).abs() < 1e-12);
        assert!((m.p95_ttft_seconds - 0.2).abs() < 1e-12);
        // The prefill-heavy first-token gaps (0.05 and 0.2) are excluded:
        // only the 3 + 5 steady-state gaps remain, so even p95 stays at
        // the steady-state level instead of echoing TTFT.
        assert!(m.p50_token_gap_seconds > 0.0);
        assert!(m.p95_token_gap_seconds >= m.p50_token_gap_seconds);
        assert!((m.p95_token_gap_seconds - 0.04).abs() < 1e-12);
        assert!(m.p95_token_gap_seconds < 0.05);
    }

    #[test]
    fn metrics_default_is_empty() {
        let m = ServeMetrics::from_responses(&[], 0.0);
        assert_eq!(m.requests, 0);
        assert_eq!(m.p50_ttft_seconds, 0.0);
        assert_eq!(m.p50_token_gap_seconds, 0.0);
        assert_eq!(m.deadline_misses, 0);
        assert_eq!(m.prefix.lookups(), 0);
        assert_eq!(m.prefix_hit_rate(), 0.0);
    }

    #[test]
    fn metrics_count_deadline_misses() {
        use std::time::Duration;

        let mut on_time = resp(0, 4, 0.2, 0.0);
        on_time.deadline = Some(Duration::from_secs(1));
        let mut late = resp(1, 4, 0.4, 0.1);
        late.deadline = Some(Duration::from_millis(100));
        // No deadline: slow but never a miss.
        let unconstrained = resp(2, 4, 9.0, 0.0);
        let m = ServeMetrics::from_responses(
            &[on_time, late, unconstrained],
            1.0,
        );
        assert_eq!(m.deadline_misses, 1);
        // Miss rate is over *deadlined* requests only: 1 of 2, not 1 of 3.
        assert_eq!(m.deadlined, 2);
        assert!((m.deadline_miss_rate() - 0.5).abs() < 1e-12);
        // p99 TTFT sits at or above p95.
        assert!(m.p99_ttft_seconds >= m.p95_ttft_seconds);
    }

    #[test]
    fn metrics_report_tenant_shares() {
        let mut a = resp(0, 6, 0.2, 0.0);
        a.tenant = 1;
        let mut b = resp(1, 2, 0.2, 0.0);
        b.tenant = 0;
        let mut c = resp(2, 2, 0.2, 0.0);
        c.tenant = 1;
        let m = ServeMetrics::from_responses(&[a, b, c], 1.0);
        assert_eq!(m.tenants.len(), 2);
        assert_eq!(m.tenants[0].tenant, 0);
        assert_eq!(m.tenants[0].requests, 1);
        assert_eq!(m.tenants[0].tokens, 2);
        assert!((m.tenants[0].share - 0.2).abs() < 1e-12);
        assert_eq!(m.tenants[1].tenant, 1);
        assert_eq!(m.tenants[1].requests, 2);
        assert_eq!(m.tenants[1].tokens, 8);
        assert!((m.tenants[1].share - 0.8).abs() < 1e-12);
        // Shares sum to 1 whenever tokens were generated.
        let sum: f64 = m.tenants.iter().map(|t| t.share).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slo_counters_record_merge_and_since() {
        let c = SloCounters::default();
        assert_eq!(c.stats(), SloStats::default());
        c.record_preemption();
        c.record_preemption();
        c.record_resume();
        c.record_park_failure();
        c.observe_parked(2);
        c.observe_parked(1);
        let s = c.stats();
        assert_eq!(s.preemptions, 2);
        assert_eq!(s.resumes, 1);
        assert_eq!(s.park_failures, 1);
        assert_eq!(s.resume_failures, 0);
        assert_eq!(s.parked_peak, 2, "peak keeps the max, not the last");
        // Delta attribution, as run_batch uses it.
        let base = s;
        c.record_resume();
        c.record_resume_failure();
        c.observe_parked(3);
        let d = c.stats().since(&base);
        assert_eq!(d.preemptions, 0);
        assert_eq!(d.resumes, 1);
        assert_eq!(d.resume_failures, 1);
        assert_eq!(d.parked_peak, 3, "peak carries the later reading");
        // Merge folds flows and maxes the peak.
        let mut merged = base;
        merged.merge(&d);
        assert_eq!(merged.preemptions, 2);
        assert_eq!(merged.resumes, 2);
        assert_eq!(merged.parked_peak, 3);
        // Scheduler-side sheds/degrades fold in at assembly time.
        let mut with_sched = merged;
        with_sched.merge(&SloStats {
            shed: 4,
            degraded: 2,
            ..SloStats::default()
        });
        assert_eq!(with_sched.shed, 4);
        assert_eq!(with_sched.degraded, 2);
    }

    #[test]
    fn lane_stats_steps_per_dispatch_and_since() {
        let c = LaneCounters::default();
        assert_eq!(c.stats().steps_per_dispatch(), 0.0, "no activity");
        // Two fused calls (4 + 2 lanes) and two solo steps: 8 steps over
        // 4 dispatch rounds.
        c.record_fused(4, 0);
        c.record_fused(2, 3);
        c.record_solo();
        c.record_solo();
        c.record_policy_apply();
        let s = c.stats();
        assert_eq!(s.fused_calls, 2);
        assert_eq!(s.fused_steps, 6);
        assert_eq!(s.solo_steps, 2);
        assert_eq!(s.stages_skipped, 3);
        assert_eq!(s.policy_applies, 1);
        assert_eq!(s.occupancy, vec![(2, 1), (4, 1)]);
        assert!((s.steps_per_dispatch() - 2.0).abs() < 1e-12);
        // Delta attribution, as run_batch uses it.
        let base = s.clone();
        c.record_fused(4, 0);
        let d = c.stats().since(&base);
        assert_eq!(d.fused_calls, 1);
        assert_eq!(d.fused_steps, 4);
        assert_eq!(d.solo_steps, 0);
        assert_eq!(d.occupancy, vec![(4, 1)]);
        // since + merge round-trips to the later reading.
        let mut merged = base;
        merged.merge(&d);
        assert_eq!(merged, c.stats());
        // Solo-only serving reads as exactly 1 step per dispatch.
        let solo = LaneCounters::default();
        solo.record_solo();
        assert!((solo.stats().steps_per_dispatch() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lane_stats_fold_in_cache_traffic_deltas() {
        let c = LaneCounters::default();
        // A cold form (4 lanes x 2 stages gathered), two warm rounds,
        // then one departure scatter — the resident steady-state shape.
        c.record_traffic(&LaneTraffic {
            cache_gathers: 8,
            gather_bytes: 8 * 1024,
            cold_forms: 1,
            ..LaneTraffic::default()
        });
        c.record_traffic(&LaneTraffic {
            warm_hits: 2,
            ..LaneTraffic::default()
        });
        c.record_traffic(&LaneTraffic {
            cache_scatters: 2,
            scatter_bytes: 2 * 1024,
            ..LaneTraffic::default()
        });
        let s = c.stats();
        assert_eq!(s.cache_gathers, 8);
        assert_eq!(s.cache_scatters, 2);
        assert_eq!(s.cache_gather_bytes, 8 * 1024);
        assert_eq!(s.cache_scatter_bytes, 2 * 1024);
        assert_eq!(s.warm_group_hits, 2);
        assert_eq!(s.cold_group_forms, 1);
        // Delta attribution and merge round-trip, as run_batch uses them.
        let base = s.clone();
        c.record_traffic(&LaneTraffic {
            warm_hits: 3,
            ..LaneTraffic::default()
        });
        let d = c.stats().since(&base);
        assert_eq!(d.warm_group_hits, 3);
        assert_eq!(d.cache_gathers, 0);
        let mut merged = base;
        merged.merge(&d);
        assert_eq!(merged, c.stats());
        // The engine-side counter is monotonic; `LaneTraffic::since`
        // produces the per-round delta workers feed in.
        let t0 = LaneTraffic {
            cache_gathers: 8,
            warm_hits: 1,
            ..LaneTraffic::default()
        };
        let t1 = LaneTraffic { cache_gathers: 8, warm_hits: 4, ..t0 };
        let dt = t1.since(&t0);
        assert_eq!(dt.cache_gathers, 0);
        assert_eq!(dt.warm_hits, 3);
    }

    #[test]
    fn interleave_stats_occupancy_and_since() {
        let c = LaneCounters::default();
        assert_eq!(c.interleave_stats().mean_in_flight(), 0.0);
        c.record_interleaved(3);
        c.record_interleaved(3);
        c.record_interleaved(1);
        let s = c.interleave_stats();
        assert_eq!(s.rounds, 3);
        assert_eq!(s.steps, 7);
        assert_eq!(s.occupancy, vec![(1, 1), (3, 2)]);
        assert_eq!(s.max_in_flight(), 3);
        assert!((s.mean_in_flight() - 7.0 / 3.0).abs() < 1e-12);
        // Delta attribution, as run_batch uses it.
        let base = s.clone();
        c.record_interleaved(2);
        let d = c.interleave_stats().since(&base);
        assert_eq!(d.rounds, 1);
        assert_eq!(d.steps, 2);
        assert_eq!(d.occupancy, vec![(2, 1)]);
        // since + merge round-trips to the later reading.
        let mut merged = base;
        merged.merge(&d);
        assert_eq!(merged, c.interleave_stats());
    }

    #[test]
    fn convo_counters_record_merge_and_since() {
        let c = ConvoCounters::default();
        assert_eq!(c.stats(), ConvoStats::default());
        assert_eq!(c.stats().restore_hit_rate(), 0.0);
        // Turn 1 opens; turns 2 and 3 restore; turn 4 misses.
        c.record_first_turn();
        c.record_restore(true, 40);
        c.record_restore(true, 60);
        c.record_restore(false, 0);
        for _ in 0..4 {
            c.record_turn();
        }
        c.record_snapshot(true);
        c.record_snapshot(true);
        c.record_snapshot(false);
        c.record_snapshot_failure();
        c.record_expired(2);
        let s = c.stats();
        assert_eq!(s.turns, 4);
        assert_eq!(s.first_turns, 1);
        assert_eq!(s.restore_hits, 2);
        assert_eq!(s.restore_misses, 1);
        assert_eq!(s.saved_positions, 100);
        assert_eq!(s.snapshots, 2);
        assert_eq!(s.snapshots_rejected, 1);
        assert_eq!(s.snapshot_failures, 1);
        assert_eq!(s.expired, 2);
        assert!((s.restore_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.saved_per_turn() - 25.0).abs() < 1e-12);
        // Delta attribution, as run_batch uses it.
        let base = s;
        c.record_restore(true, 10);
        c.record_turn();
        let d = c.stats().since(&base);
        assert_eq!(d.turns, 1);
        assert_eq!(d.restore_hits, 1);
        assert_eq!(d.saved_positions, 10);
        assert_eq!(d.first_turns, 0);
        // since + merge round-trips to the later reading.
        let mut merged = base;
        merged.merge(&d);
        assert_eq!(merged, c.stats());
    }

    #[test]
    fn snapshot_memory_totals_all_tiers() {
        let m = SnapshotMemory {
            cached_entries: 3,
            cached_positions: 40,
            cached_bytes: 4096,
            device_entries: 1,
            device_positions: 12,
            device_bytes: 1024,
            parked_entries: 2,
            parked_bytes: 2048,
            checkpoint_entries: 1,
            checkpoint_bytes: 512,
        };
        assert_eq!(m.total_bytes(), 4096 + 1024 + 2048 + 512);
        assert_eq!(SnapshotMemory::default().total_bytes(), 0);
        // Fresh batch metrics carry empty gauges and convo counters.
        let zero = ServeMetrics::from_responses(&[], 0.0);
        assert_eq!(zero.snapshot_memory, SnapshotMemory::default());
        assert_eq!(zero.convo, ConvoStats::default());
        assert_eq!(zero.tier.lookups(), 0);
    }

    #[test]
    fn fault_counters_record_merge_and_since() {
        let c = FaultCounters::default();
        assert_eq!(c.stats(), FaultStats::default());
        c.record_injected(FaultSite::StagePanic);
        c.record_injected(FaultSite::Decode);
        c.record_observed(FaultSite::StagePanic);
        c.record_checkpoint(true);
        c.record_checkpoint(true);
        c.record_checkpoint(false);
        c.record_retry();
        c.record_retry();
        c.record_recovery();
        c.record_redecoded(5);
        c.record_restart();
        let s = c.stats();
        assert_eq!(s.injected_total(), 2);
        assert_eq!(s.injected[FaultSite::StagePanic.index()], 1);
        assert_eq!(s.injected[FaultSite::Decode.index()], 1);
        assert_eq!(s.observed_total(), 1);
        assert_eq!(s.checkpoints, 2);
        assert_eq!(s.checkpoint_failures, 1);
        assert_eq!(s.retries, 2);
        assert_eq!(s.recoveries, 1);
        assert_eq!(s.recovery_failures, 0);
        assert_eq!(s.redecoded_tokens, 5);
        assert_eq!(s.restarts, 1);
        assert_eq!(s.quarantines, 0);
        // The chaos acceptance identity on a drained batch: every
        // observed fault resolved as a recovery or an exhausted budget.
        assert_eq!(
            s.recoveries,
            s.observed_total() - s.recovery_failures
        );
        // Delta attribution, as run_batch uses it.
        let base = s;
        c.record_observed(FaultSite::Resume);
        c.record_recovery_failure();
        c.record_quarantine();
        let d = c.stats().since(&base);
        assert_eq!(d.injected_total(), 0);
        assert_eq!(d.observed[FaultSite::Resume.index()], 1);
        assert_eq!(d.recovery_failures, 1);
        assert_eq!(d.quarantines, 1);
        assert_eq!(d.recoveries, 0);
        // since + merge round-trips to the later reading.
        let mut merged = base;
        merged.merge(&d);
        assert_eq!(merged, c.stats());
        // Fresh batch metrics carry an all-zero faults block.
        let zero = ServeMetrics::from_responses(&[], 0.0);
        assert_eq!(zero.faults, FaultStats::default());
    }

    #[test]
    fn metrics_surface_prefix_cache_stats() {
        use crate::inference::PrefixCacheStats;

        let mut m = ServeMetrics::from_responses(&[resp(0, 4, 0.2, 0.0)], 0.5);
        m.prefix.merge(&PrefixCacheStats {
            hits: 3,
            misses: 1,
            saved_positions: 120,
            ..PrefixCacheStats::default()
        });
        assert_eq!(m.prefix_hit_rate(), 0.75);
        assert_eq!(m.prefill_positions_saved(), 120);
    }
}
