//! Aggregate serving metrics: throughput, latency percentiles, queueing,
//! time-to-first-token, per-token latency, and merged per-exit usage —
//! the serving-side analogue of the paper's Figure 8 axes
//! (quality/latency vs. threshold), lifted to a multi-request batch.

use crate::inference::{ExitStats, PrefixCacheStats};
pub use crate::metrics::percentile;

use super::request::ServeResponse;

#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    /// Generated tokens summed over all requests.
    pub total_tokens: usize,
    /// Wall clock of the whole batch (first submit to last completion) —
    /// the throughput denominator.
    pub wall_seconds: f64,
    pub p50_latency_seconds: f64,
    pub p95_latency_seconds: f64,
    /// Time-to-first-token percentiles across requests (queue + prefill +
    /// first decode step) — the streaming responsiveness metric
    /// continuous batching exists to improve.
    pub p50_ttft_seconds: f64,
    pub p95_ttft_seconds: f64,
    /// Steady-state per-token emission-gap percentiles, pooled over every
    /// generated token of every request *except* each request's first
    /// (whose gap includes prefill and is already reported as TTFT).
    pub p50_token_gap_seconds: f64,
    pub p95_token_gap_seconds: f64,
    pub mean_queue_seconds: f64,
    /// Requests that completed after their stated deadline (queue +
    /// service vs. the request's relative deadline); deadline-less
    /// requests never miss.
    pub deadline_misses: usize,
    /// Per-exit usage merged across all requests.
    pub exits: ExitStats,
    /// Prefix KV-cache activity during the batch, read from the pool's
    /// shared store (all zeros when the cache is disabled).
    pub prefix: PrefixCacheStats,
}

impl ServeMetrics {
    pub fn from_responses(
        responses: &[ServeResponse],
        wall_seconds: f64,
    ) -> ServeMetrics {
        let lats: Vec<f64> =
            responses.iter().map(|r| r.total_seconds).collect();
        let ttfts: Vec<f64> =
            responses.iter().map(|r| r.ttft_seconds).collect();
        // Skip each request's first gap: it spans prefill and would
        // otherwise dominate p95 with what TTFT already measures.
        let gaps: Vec<f64> = responses
            .iter()
            .flat_map(|r| r.token_seconds.iter().skip(1).copied())
            .collect();
        let mut exits = ExitStats::default();
        for r in responses {
            exits.merge(&r.output.stats);
        }
        let n = responses.len().max(1) as f64;
        ServeMetrics {
            requests: responses.len(),
            total_tokens: responses
                .iter()
                .map(|r| r.output.tokens.len())
                .sum(),
            wall_seconds,
            p50_latency_seconds: percentile(&lats, 0.50),
            p95_latency_seconds: percentile(&lats, 0.95),
            p50_ttft_seconds: percentile(&ttfts, 0.50),
            p95_ttft_seconds: percentile(&ttfts, 0.95),
            p50_token_gap_seconds: percentile(&gaps, 0.50),
            p95_token_gap_seconds: percentile(&gaps, 0.95),
            mean_queue_seconds: responses
                .iter()
                .map(|r| r.queue_seconds)
                .sum::<f64>()
                / n,
            deadline_misses: responses
                .iter()
                .filter(|r| {
                    r.deadline
                        .is_some_and(|d| r.total_seconds > d.as_secs_f64())
                })
                .count(),
            exits,
            prefix: PrefixCacheStats::default(),
        }
    }

    /// Fraction of admissions that restored a cached prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        self.prefix.hit_rate()
    }

    /// Prefill positions skipped thanks to prefix-cache hits.
    pub fn prefill_positions_saved(&self) -> u64 {
        self.prefix.saved_positions
    }

    /// Aggregate generated tokens per wall-clock second.
    pub fn throughput_tps(&self) -> f64 {
        self.total_tokens as f64 / self.wall_seconds.max(1e-9)
    }

    /// Fraction of tokens emitted at early exits.
    pub fn early_fraction(&self, n_layers: usize) -> f64 {
        self.exits.early_fraction(n_layers)
    }
}

#[cfg(test)]
mod tests {
    use crate::inference::GenOutput;

    use super::*;

    fn resp(id: u64, n_tokens: usize, total: f64, queue: f64) -> ServeResponse {
        let mut stats = ExitStats::default();
        for _ in 0..n_tokens {
            stats.record(4);
        }
        // Synthetic but shape-consistent stream timing: the first token
        // costs half the service time, the rest split the remainder.
        let service = total - queue;
        let mut token_seconds = vec![service / 2.0];
        for _ in 1..n_tokens {
            token_seconds.push(service / (2.0 * (n_tokens - 1) as f64));
        }
        ServeResponse {
            id,
            worker: 0,
            output: GenOutput {
                tokens: vec![65; n_tokens],
                text: "a".repeat(n_tokens),
                seconds: service,
                stats,
            },
            queue_seconds: queue,
            ttft_seconds: queue + service / 2.0,
            token_seconds,
            total_seconds: total,
            deadline: None,
        }
    }

    #[test]
    fn metrics_aggregate_responses() {
        let rs = vec![resp(0, 4, 0.2, 0.1), resp(1, 6, 0.4, 0.0)];
        let m = ServeMetrics::from_responses(&rs, 0.5);
        assert_eq!(m.requests, 2);
        assert_eq!(m.total_tokens, 10);
        assert!((m.throughput_tps() - 20.0).abs() < 1e-9);
        assert_eq!(m.p50_latency_seconds, 0.2);
        assert_eq!(m.p95_latency_seconds, 0.4);
        assert!((m.mean_queue_seconds - 0.05).abs() < 1e-12);
        assert_eq!(m.exits.total(), 10);
        // Layer 4 == n_layers here: nothing exited early.
        assert_eq!(m.early_fraction(4), 0.0);
    }

    #[test]
    fn metrics_report_ttft_and_token_gaps() {
        // TTFTs: 0.1 + 0.05 = 0.15 and 0.0 + 0.2 = 0.2.
        let rs = vec![resp(0, 4, 0.2, 0.1), resp(1, 6, 0.4, 0.0)];
        let m = ServeMetrics::from_responses(&rs, 0.5);
        assert!((m.p50_ttft_seconds - 0.15).abs() < 1e-12);
        assert!((m.p95_ttft_seconds - 0.2).abs() < 1e-12);
        // The prefill-heavy first-token gaps (0.05 and 0.2) are excluded:
        // only the 3 + 5 steady-state gaps remain, so even p95 stays at
        // the steady-state level instead of echoing TTFT.
        assert!(m.p50_token_gap_seconds > 0.0);
        assert!(m.p95_token_gap_seconds >= m.p50_token_gap_seconds);
        assert!((m.p95_token_gap_seconds - 0.04).abs() < 1e-12);
        assert!(m.p95_token_gap_seconds < 0.05);
    }

    #[test]
    fn metrics_default_is_empty() {
        let m = ServeMetrics::from_responses(&[], 0.0);
        assert_eq!(m.requests, 0);
        assert_eq!(m.p50_ttft_seconds, 0.0);
        assert_eq!(m.p50_token_gap_seconds, 0.0);
        assert_eq!(m.deadline_misses, 0);
        assert_eq!(m.prefix.lookups(), 0);
        assert_eq!(m.prefix_hit_rate(), 0.0);
    }

    #[test]
    fn metrics_count_deadline_misses() {
        use std::time::Duration;

        let mut on_time = resp(0, 4, 0.2, 0.0);
        on_time.deadline = Some(Duration::from_secs(1));
        let mut late = resp(1, 4, 0.4, 0.1);
        late.deadline = Some(Duration::from_millis(100));
        // No deadline: slow but never a miss.
        let unconstrained = resp(2, 4, 9.0, 0.0);
        let m = ServeMetrics::from_responses(
            &[on_time, late, unconstrained],
            1.0,
        );
        assert_eq!(m.deadline_misses, 1);
    }

    #[test]
    fn metrics_surface_prefix_cache_stats() {
        use crate::inference::PrefixCacheStats;

        let mut m = ServeMetrics::from_responses(&[resp(0, 4, 0.2, 0.0)], 0.5);
        m.prefix.merge(&PrefixCacheStats {
            hits: 3,
            misses: 1,
            saved_positions: 120,
            ..PrefixCacheStats::default()
        });
        assert_eq!(m.prefix_hit_rate(), 0.75);
        assert_eq!(m.prefill_positions_saved(), 120);
    }
}
