//! Aggregate serving metrics: throughput, latency percentiles, queueing,
//! and merged per-exit usage — the serving-side analogue of the paper's
//! Figure 8 axes (quality/latency vs. threshold), lifted to a
//! multi-request batch.

use crate::inference::ExitStats;
pub use crate::metrics::percentile;

use super::request::ServeResponse;

#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    /// Generated tokens summed over all requests.
    pub total_tokens: usize,
    /// Wall clock of the whole batch (first submit to last completion) —
    /// the throughput denominator.
    pub wall_seconds: f64,
    pub p50_latency_seconds: f64,
    pub p95_latency_seconds: f64,
    pub mean_queue_seconds: f64,
    /// Per-exit usage merged across all requests.
    pub exits: ExitStats,
}

impl ServeMetrics {
    pub fn from_responses(
        responses: &[ServeResponse],
        wall_seconds: f64,
    ) -> ServeMetrics {
        let lats: Vec<f64> =
            responses.iter().map(|r| r.total_seconds).collect();
        let mut exits = ExitStats::default();
        for r in responses {
            exits.merge(&r.output.stats);
        }
        let n = responses.len().max(1) as f64;
        ServeMetrics {
            requests: responses.len(),
            total_tokens: responses
                .iter()
                .map(|r| r.output.tokens.len())
                .sum(),
            wall_seconds,
            p50_latency_seconds: percentile(&lats, 0.50),
            p95_latency_seconds: percentile(&lats, 0.95),
            mean_queue_seconds: responses
                .iter()
                .map(|r| r.queue_seconds)
                .sum::<f64>()
                / n,
            exits,
        }
    }

    /// Aggregate generated tokens per wall-clock second.
    pub fn throughput_tps(&self) -> f64 {
        self.total_tokens as f64 / self.wall_seconds.max(1e-9)
    }

    /// Fraction of tokens emitted at early exits.
    pub fn early_fraction(&self, n_layers: usize) -> f64 {
        self.exits.early_fraction(n_layers)
    }
}

#[cfg(test)]
mod tests {
    use crate::inference::GenOutput;

    use super::*;

    fn resp(id: u64, n_tokens: usize, total: f64, queue: f64) -> ServeResponse {
        let mut stats = ExitStats::default();
        for _ in 0..n_tokens {
            stats.record(4);
        }
        ServeResponse {
            id,
            worker: 0,
            output: GenOutput {
                tokens: vec![65; n_tokens],
                text: "a".repeat(n_tokens),
                seconds: total - queue,
                stats,
            },
            queue_seconds: queue,
            total_seconds: total,
        }
    }

    #[test]
    fn metrics_aggregate_responses() {
        let rs = vec![resp(0, 4, 0.2, 0.1), resp(1, 6, 0.4, 0.0)];
        let m = ServeMetrics::from_responses(&rs, 0.5);
        assert_eq!(m.requests, 2);
        assert_eq!(m.total_tokens, 10);
        assert!((m.throughput_tps() - 20.0).abs() < 1e-9);
        assert_eq!(m.p50_latency_seconds, 0.2);
        assert_eq!(m.p95_latency_seconds, 0.4);
        assert!((m.mean_queue_seconds - 0.05).abs() < 1e-12);
        assert_eq!(m.exits.total(), 10);
        // Layer 4 == n_layers here: nothing exited early.
        assert_eq!(m.early_fraction(4), 0.0);
    }
}
