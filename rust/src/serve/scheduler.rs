//! The serving queue: submitted requests wait here until an engine worker
//! pops them.
//!
//! Two policies:
//!
//! - **FIFO** — arrival order; fair, and the baseline any latency claim
//!   is measured against.
//! - **Shortest-prompt-first (SPF)** — byte-tokenised prompt length as
//!   the service-time proxy; the classic mean-latency optimisation when
//!   request sizes are heterogeneous (long summarisation prompts would
//!   otherwise head-of-line-block short QA ones).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use super::request::ServeRequest;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    ShortestPromptFirst,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        match s {
            "fifo" => Ok(Policy::Fifo),
            "spf" | "shortest-prompt-first" => Ok(Policy::ShortestPromptFirst),
            other => bail!("unknown scheduling policy {other:?} (fifo|spf)"),
        }
    }
}

struct Queued {
    req: ServeRequest,
    enqueued: Instant,
}

#[derive(Default)]
struct State {
    pending: VecDeque<Queued>,
    closed: bool,
}

/// Thread-safe request queue shared between submitters and pool workers.
pub struct Scheduler {
    policy: Policy,
    state: Mutex<State>,
    cv: Condvar,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Enqueue a request. Panics if the queue was already closed.
    pub fn push(&self, req: ServeRequest) {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "push after close");
        st.pending.push_back(Queued { req, enqueued: Instant::now() });
        self.cv.notify_one();
    }

    /// Number of queued (not yet claimed) requests.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: workers drain what is pending, then `pop` returns
    /// `None` and they exit.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    /// Block until a request is available (or the queue is closed and
    /// drained). Returns the request and its queue wait in seconds.
    pub fn pop(&self) -> Option<(ServeRequest, f64)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(i) = self.select(&st.pending) {
                let q = st.pending.remove(i).unwrap();
                return Some((q.req, q.enqueued.elapsed().as_secs_f64()));
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Index of the next request under the configured policy.
    fn select(&self, pending: &VecDeque<Queued>) -> Option<usize> {
        if pending.is_empty() {
            return None;
        }
        match self.policy {
            Policy::Fifo => Some(0),
            // Ties break by arrival order (stable min over index).
            Policy::ShortestPromptFirst => (0..pending.len())
                .min_by_key(|&i| (pending[i].req.prompt.len(), i)),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;
    use std::time::Duration;

    use super::*;

    fn req(id: u64, prompt: &str) -> ServeRequest {
        ServeRequest::new(id, prompt, 8)
    }

    #[test]
    fn fifo_pops_in_arrival_order() {
        let s = Scheduler::new(Policy::Fifo);
        s.push(req(0, "long prompt here"));
        s.push(req(1, "x"));
        s.push(req(2, "mid"));
        s.close();
        let ids: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn spf_pops_shortest_prompt_first_with_stable_ties() {
        let s = Scheduler::new(Policy::ShortestPromptFirst);
        s.push(req(0, "aaaa"));
        s.push(req(1, "a"));
        s.push(req(2, "aa"));
        s.push(req(3, "a"));
        s.close();
        let ids: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        assert_eq!(ids, vec![1, 3, 2, 0]);
    }

    #[test]
    fn close_drains_pending_then_ends() {
        let s = Scheduler::new(Policy::Fifo);
        s.push(req(0, "a"));
        s.push(req(1, "b"));
        assert_eq!(s.len(), 2);
        s.close();
        assert_eq!(s.pop().unwrap().0.id, 0);
        assert_eq!(s.pop().unwrap().0.id, 1);
        assert!(s.pop().is_none());
        assert!(s.is_empty());
    }

    #[test]
    fn pop_blocks_until_push_and_reports_queue_time() {
        let s = Arc::new(Scheduler::new(Policy::Fifo));
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.push(req(7, "hi"));
            s2.close();
        });
        let (r, q) = s.pop().expect("request");
        assert_eq!(r.id, 7);
        assert!(q >= 0.0);
        assert!(s.pop().is_none());
        h.join().unwrap();
    }

    #[test]
    fn policy_parse_round_trips() {
        assert_eq!(Policy::parse("fifo").unwrap(), Policy::Fifo);
        assert_eq!(Policy::parse("spf").unwrap(), Policy::ShortestPromptFirst);
        assert!(Policy::parse("lifo").is_err());
    }
}
