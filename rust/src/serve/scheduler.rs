//! The serving queue — and, since the SLO control plane landed, the
//! place where the pool *acts* on load and deadlines instead of just
//! measuring them:
//!
//! - **Admission control + load shedding** ([`ShedPolicy`]): `submit`
//!   rejects requests with a typed [`Admission::Shed`] when the queue is
//!   past its depth bound or the predicted TTFT (queue depth × a service
//!   -time EMA fed by [`Scheduler::note_done`]) exceeds its bound, and
//!   *degrades* requests (clamping `max_new`) past a softer depth
//!   threshold — bounded queues instead of unbounded latency.
//! - **Weighted per-tenant fairness**: with tenant weights configured,
//!   dispatch picks the tenant with the smallest weighted virtual time
//!   (`v_t += max_new / weight_t` per pop, idle tenants clamped forward
//!   on re-arrival so they cannot bank credit), then applies the base
//!   policy within that tenant — one tenant's burst cannot starve the
//!   rest.
//! - **Deadline urgency** ([`Scheduler::pop_urgent_when`]): pool workers
//!   pull the minimum-slack deadlined request past the normal order when
//!   its slack is within the preemption horizon — the trigger for
//!   parking a low-value live session.
//!
//! Three base policies order dispatch within a tenant (or globally, when
//! fairness is off):
//!
//! - **FIFO** — arrival order; fair, and the baseline any latency claim
//!   is measured against.
//! - **Shortest-prompt-first (SPF)** — byte-tokenised prompt length as
//!   the service-time proxy; the classic mean-latency optimisation when
//!   request sizes are heterogeneous (long summarisation prompts would
//!   otherwise head-of-line-block short QA ones).
//! - **Priority** — highest [`ServeRequest::priority`] first; ties go to
//!   the earliest absolute deadline (earliest-deadline-first), with
//!   deadline-less requests after any deadlined peer, then arrival order.

use std::cmp::Reverse;
use std::collections::VecDeque;
use std::fmt;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::request::ServeRequest;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    #[default]
    Fifo,
    ShortestPromptFirst,
    Priority,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        match s {
            "fifo" => Ok(Policy::Fifo),
            "spf" | "shortest-prompt-first" => Ok(Policy::ShortestPromptFirst),
            "priority" | "edf" => Ok(Policy::Priority),
            other => {
                bail!("unknown scheduling policy {other:?} (fifo|spf|priority)")
            }
        }
    }
}

/// Admission-control bounds applied at [`Scheduler::submit`]. All bounds
/// default off; a zero depth means unbounded.
#[derive(Debug, Clone, PartialEq)]
pub struct ShedPolicy {
    /// Shed incoming requests while the queue holds at least this many
    /// (0 = unbounded).
    pub max_queue_depth: usize,
    /// Shed incoming requests whose predicted TTFT — queue depth × the
    /// service-time EMA fed by [`Scheduler::note_done`] — exceeds this
    /// bound. Inactive until the first completion primes the EMA.
    pub max_predicted_ttft: Option<Duration>,
    /// Degrade (rather than shed) incoming requests while the queue
    /// holds at least this many, clamping `max_new` to
    /// `degrade_max_new` (0 = off).
    pub degrade_depth: usize,
    /// Token budget degraded requests are clamped to.
    pub degrade_max_new: usize,
}

impl Default for ShedPolicy {
    fn default() -> ShedPolicy {
        ShedPolicy {
            max_queue_depth: 0,
            max_predicted_ttft: None,
            degrade_depth: 0,
            degrade_max_new: 16,
        }
    }
}

/// Why a request was shed at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The queue was at or past [`ShedPolicy::max_queue_depth`].
    QueueFull { depth: usize, limit: usize },
    /// Predicted TTFT exceeded [`ShedPolicy::max_predicted_ttft`].
    PredictedTtft { predicted_ms: u64, limit_ms: u64 },
}

impl fmt::Display for ShedReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShedReason::QueueFull { depth, limit } => {
                write!(f, "queue full (depth {depth} >= limit {limit})")
            }
            ShedReason::PredictedTtft { predicted_ms, limit_ms } => write!(
                f,
                "predicted TTFT {predicted_ms}ms exceeds limit {limit_ms}ms"
            ),
        }
    }
}

/// Typed outcome of [`Scheduler::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Queued as-is.
    Queued,
    /// Queued with `max_new` clamped to the degraded budget.
    Degraded { max_new: usize },
    /// Rejected by the shed policy; the request was not queued.
    Shed(ShedReason),
    /// Rejected because the queue is closed.
    Closed,
}

impl Admission {
    /// Whether the request was queued (possibly degraded).
    pub fn accepted(&self) -> bool {
        matches!(self, Admission::Queued | Admission::Degraded { .. })
    }
}

/// Scheduler construction knobs; [`Scheduler::new`] is the all-defaults
/// spelling (no shedding, no tenant fairness).
#[derive(Debug, Clone, Default)]
pub struct SchedConfig {
    pub policy: Policy,
    /// Admission-control bounds; `None` admits everything.
    pub shed: Option<ShedPolicy>,
    /// Per-tenant weights; empty disables fairness. Tenant ids at or
    /// past the table length share tenant 0's accounting.
    pub tenant_weights: Vec<f64>,
}

struct Queued {
    req: ServeRequest,
    enqueued: Instant,
}

#[derive(Default)]
struct State {
    pending: VecDeque<Queued>,
    closed: bool,
    shed: u64,
    degraded: u64,
    /// EMA of per-request service seconds, fed by `note_done` — the
    /// coarse signal behind predicted-TTFT shedding.
    service_ema: f64,
    /// Per-tenant weighted virtual time (fairness on only).
    vtime: Vec<f64>,
    /// Virtual time of the most recently dispatched tenant, used to
    /// clamp idle tenants forward on re-arrival.
    vnow: f64,
}

/// Thread-safe request queue shared between submitters and pool workers.
pub struct Scheduler {
    policy: Policy,
    shed: Option<ShedPolicy>,
    weights: Vec<f64>,
    state: Mutex<State>,
    cv: Condvar,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler::new_with(SchedConfig { policy, ..SchedConfig::default() })
    }

    pub fn new_with(cfg: SchedConfig) -> Scheduler {
        let vtime = vec![0.0; cfg.tenant_weights.len()];
        Scheduler {
            policy: cfg.policy,
            shed: cfg.shed,
            weights: cfg.tenant_weights,
            state: Mutex::new(State { vtime, ..State::default() }),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Whether weighted tenant fairness is configured.
    pub fn fairness_enabled(&self) -> bool {
        !self.weights.is_empty()
    }

    /// Requests shed by admission control so far.
    pub fn shed_count(&self) -> u64 {
        self.state.lock().unwrap().shed
    }

    /// Requests degraded (budget-clamped) by admission control so far.
    pub fn degraded_count(&self) -> u64 {
        self.state.lock().unwrap().degraded
    }

    /// Feed one completed request's service time into the EMA behind
    /// predicted-TTFT shedding. Pool workers call this as requests
    /// settle; tests can call it directly to prime the predictor.
    pub fn note_done(&self, service_seconds: f64) {
        let mut st = self.state.lock().unwrap();
        st.service_ema = if st.service_ema > 0.0 {
            0.8 * st.service_ema + 0.2 * service_seconds
        } else {
            service_seconds
        };
    }

    /// Enqueue a request through admission control. Requests may be
    /// queued as-is, queued with a degraded token budget, shed with a
    /// typed reason, or rejected because the queue is closed — a shed or
    /// closed request is *not* queued and will never produce a worker
    /// event.
    pub fn submit(&self, mut req: ServeRequest) -> Admission {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Admission::Closed;
        }
        let depth = st.pending.len();
        let mut admission = Admission::Queued;
        if let Some(shed) = &self.shed {
            if shed.max_queue_depth > 0 && depth >= shed.max_queue_depth {
                st.shed += 1;
                return Admission::Shed(ShedReason::QueueFull {
                    depth,
                    limit: shed.max_queue_depth,
                });
            }
            if let Some(limit) = shed.max_predicted_ttft {
                let predicted = depth as f64 * st.service_ema;
                if st.service_ema > 0.0 && predicted > limit.as_secs_f64() {
                    st.shed += 1;
                    return Admission::Shed(ShedReason::PredictedTtft {
                        predicted_ms: (predicted * 1e3) as u64,
                        limit_ms: limit.as_millis() as u64,
                    });
                }
            }
            if shed.degrade_depth > 0
                && depth >= shed.degrade_depth
                && req.max_new > shed.degrade_max_new
            {
                req.max_new = shed.degrade_max_new;
                st.degraded += 1;
                admission = Admission::Degraded { max_new: req.max_new };
            }
        }
        if !self.weights.is_empty() {
            let t = self.tenant_of(&req);
            let idle = !st
                .pending
                .iter()
                .any(|q| self.tenant_of(&q.req) == t);
            if idle {
                // Catch-up clamp: a tenant that sat idle re-enters at the
                // current virtual time instead of cashing in banked
                // credit and monopolising dispatch.
                st.vtime[t] = st.vtime[t].max(st.vnow);
            }
        }
        st.pending.push_back(Queued { req, enqueued: Instant::now() });
        self.cv.notify_one();
        admission
    }

    /// Enqueue a request. Returns `false` — rejecting the request — when
    /// the queue has already been closed or admission control shed it:
    /// submitting to a shut-down pool is an error for the caller to
    /// handle, never a submitter panic. [`Scheduler::submit`] is the
    /// typed spelling.
    #[must_use]
    pub fn push(&self, req: ServeRequest) -> bool {
        self.submit(req).accepted()
    }

    /// Number of queued (not yet claimed) requests.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: workers drain what is pending, then `pop` returns
    /// `None` and they exit. Subsequent `push` calls are rejected.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    /// Block until a request is available (or the queue is closed and
    /// drained). Returns the request and its queue wait in seconds.
    pub fn pop(&self) -> Option<(ServeRequest, f64)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(popped) = self.pop_locked(&mut st) {
                return Some(popped);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Non-blocking pop: `None` when nothing is queued right now. This is
    /// how continuous-batching workers admit requests *between* decode
    /// steps without stalling their live sessions.
    pub fn try_pop(&self) -> Option<(ServeRequest, f64)> {
        let mut st = self.state.lock().unwrap();
        self.pop_locked(&mut st)
    }

    /// Non-blocking pop biased by a caller-supplied score (lower is
    /// better): among the fairness-selected tenant's pending requests,
    /// take the best-scoring one, breaking score ties with the base
    /// policy order. The pool's lane-aware admission scores requests by
    /// lane-group compatibility (matching exit policy, predicted-shallow
    /// traffic) so a warm group is completed before a solo is started.
    pub fn try_pop_preferring<F>(
        &self,
        score: F,
    ) -> Option<(ServeRequest, f64)>
    where
        F: Fn(&ServeRequest) -> i64,
    {
        let mut st = self.state.lock().unwrap();
        let cands = self.candidates(&st);
        if cands.is_empty() {
            return None;
        }
        let best = cands
            .iter()
            .map(|&i| score(&st.pending[i].req))
            .min()
            .unwrap();
        let narrowed: Vec<usize> = cands
            .into_iter()
            .filter(|&i| score(&st.pending[i].req) == best)
            .collect();
        let i = self.select_among(&st.pending, &narrowed)?;
        self.take(&mut st, i)
    }

    /// Deadline-urgency pop, the preemption trigger: find the pending
    /// deadlined request with the least slack; if that slack is within
    /// `horizon` (or the deadline already passed) *and* `pred` approves
    /// it (the pool checks "is there a parkable victim and park-store
    /// room"), remove and return it. `None` otherwise — the request
    /// stays queued for the normal dispatch path. Non-blocking; ignores
    /// the base policy and fairness order deliberately (urgency), though
    /// the popped tenant is still charged its virtual time.
    pub fn pop_urgent_when<F>(
        &self,
        horizon: Duration,
        mut pred: F,
    ) -> Option<(ServeRequest, f64)>
    where
        F: FnMut(&ServeRequest) -> bool,
    {
        let mut st = self.state.lock().unwrap();
        let now = Instant::now();
        let mut best: Option<(Instant, usize)> = None;
        for (i, q) in st.pending.iter().enumerate() {
            if let Some(d) = q.req.deadline {
                let due = q.enqueued + d;
                let better = match best {
                    None => true,
                    Some((bd, _)) => due < bd,
                };
                if better {
                    best = Some((due, i));
                }
            }
        }
        let (due, i) = best?;
        if due.saturating_duration_since(now) > horizon {
            return None;
        }
        if !pred(&st.pending[i].req) {
            return None;
        }
        self.take(&mut st, i)
    }

    /// Select-and-remove core shared by `pop` and `try_pop`.
    fn pop_locked(&self, st: &mut State) -> Option<(ServeRequest, f64)> {
        let cands = self.candidates(st);
        let i = self.select_among(&st.pending, &cands)?;
        self.take(st, i)
    }

    /// Remove index `i`, charging tenant virtual time when fairness is
    /// on (`v_t += max_new / w_t`; `max_new` is the service proxy).
    fn take(&self, st: &mut State, i: usize) -> Option<(ServeRequest, f64)> {
        let q = st.pending.remove(i).unwrap();
        if !self.weights.is_empty() {
            let t = self.tenant_of(&q.req);
            st.vnow = st.vtime[t];
            st.vtime[t] +=
                q.req.max_new.max(1) as f64 / self.weights[t].max(1e-9);
        }
        Some((q.req, q.enqueued.elapsed().as_secs_f64()))
    }

    /// Candidate indices for the next dispatch: everything, or — with
    /// fairness on — the pending requests of the minimum-virtual-time
    /// tenant.
    fn candidates(&self, st: &State) -> Vec<usize> {
        if self.weights.is_empty() {
            return (0..st.pending.len()).collect();
        }
        let Some(t) = self.pick_tenant(st) else {
            return Vec::new();
        };
        (0..st.pending.len())
            .filter(|&i| self.tenant_of(&st.pending[i].req) == t)
            .collect()
    }

    /// The pending tenant with the smallest virtual time (ties to the
    /// lower tenant id).
    fn pick_tenant(&self, st: &State) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for q in &st.pending {
            let t = self.tenant_of(&q.req);
            let v = st.vtime[t];
            let better = match best {
                None => true,
                Some((bv, bt)) => v < bv || (v == bv && t < bt),
            };
            if better {
                best = Some((v, t));
            }
        }
        best.map(|(_, t)| t)
    }

    fn tenant_of(&self, r: &ServeRequest) -> usize {
        if r.tenant < self.weights.len() {
            r.tenant
        } else {
            0
        }
    }

    /// Index of the next request under the configured policy, restricted
    /// to `cands` (ascending pending indices).
    fn select_among(
        &self,
        pending: &VecDeque<Queued>,
        cands: &[usize],
    ) -> Option<usize> {
        if cands.is_empty() {
            return None;
        }
        match self.policy {
            // Candidates are ascending, so min index = earliest arrival.
            Policy::Fifo => cands.first().copied(),
            // Ties break by arrival order (stable min over index).
            Policy::ShortestPromptFirst => cands
                .iter()
                .copied()
                .min_by_key(|&i| (pending[i].req.prompt.len(), i)),
            // Highest priority; then earliest absolute deadline, with
            // deadline-less requests last; then arrival order.
            Policy::Priority => cands.iter().copied().min_by_key(|&i| {
                let q = &pending[i];
                let due = q.req.deadline.map(|d| q.enqueued + d);
                (
                    Reverse(q.req.priority),
                    due.is_none(),
                    due.unwrap_or(q.enqueued),
                    i,
                )
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;
    use std::time::Duration;

    use super::*;

    fn req(id: u64, prompt: &str) -> ServeRequest {
        ServeRequest::new(id, prompt, 8)
    }

    #[test]
    fn fifo_pops_in_arrival_order() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.push(req(0, "long prompt here")));
        assert!(s.push(req(1, "x")));
        assert!(s.push(req(2, "mid")));
        s.close();
        let ids: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn spf_pops_shortest_prompt_first_with_stable_ties() {
        let s = Scheduler::new(Policy::ShortestPromptFirst);
        assert!(s.push(req(0, "aaaa")));
        assert!(s.push(req(1, "a")));
        assert!(s.push(req(2, "aa")));
        assert!(s.push(req(3, "a")));
        s.close();
        let ids: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        assert_eq!(ids, vec![1, 3, 2, 0]);
    }

    #[test]
    fn priority_policy_orders_by_priority_then_deadline() {
        let s = Scheduler::new(Policy::Priority);
        // Same priority, later deadline.
        assert!(s.push(
            req(0, "a").with_deadline(Duration::from_secs(60))
        ));
        // Highest priority wins regardless of arrival.
        assert!(s.push(req(1, "b").with_priority(5)));
        // Same priority as 0, sooner deadline: beats 0.
        assert!(s.push(
            req(2, "c").with_deadline(Duration::from_secs(1))
        ));
        // Same priority, no deadline: after every deadlined peer.
        assert!(s.push(req(3, "d")));
        // No deadline, arrived after 3: FIFO between the deadline-less.
        assert!(s.push(req(4, "e")));
        s.close();
        let ids: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        assert_eq!(ids, vec![1, 2, 0, 3, 4]);
    }

    /// Priority-then-EDF under a synthetic contended load: 48 requests
    /// with mixed priorities and deadlines, drained in one go. Deadlines
    /// are whole seconds apart, so the microsecond enqueue jitter of
    /// same-process pushes cannot flip the earliest-absolute-deadline
    /// order, and the expected sequence is exactly the stable sort by
    /// (priority desc, has-deadline first, deadline asc, arrival).
    #[test]
    fn priority_policy_orders_contended_load_by_priority_then_edf() {
        use crate::util::rng::Rng;

        let s = Scheduler::new(Policy::Priority);
        let mut rng = Rng::new(0xEE11E);
        let n = 48u64;
        let mut spec: Vec<(i32, Option<u64>)> = Vec::new();
        for id in 0..n {
            let priority = rng.below(3) as i32;
            let deadline = if rng.below(4) == 0 {
                None
            } else {
                Some(1 + rng.below(1000) as u64)
            };
            spec.push((priority, deadline));
            let mut r = req(id, "x").with_priority(priority);
            if let Some(secs) = deadline {
                r = r.with_deadline(Duration::from_secs(secs));
            }
            assert!(s.push(r));
        }
        s.close();
        let got: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        let mut want: Vec<u64> = (0..n).collect();
        want.sort_by_key(|&id| {
            let (priority, deadline) = spec[id as usize];
            (
                std::cmp::Reverse(priority),
                deadline.is_none(),
                deadline.unwrap_or(0),
                id,
            )
        });
        assert_eq!(got, want, "spec {spec:?}");
        // Sanity on the shape of the load: all three priorities and both
        // deadline kinds occurred, so the test really exercised the
        // tie-break chain.
        for p in 0..3 {
            assert!(spec.iter().any(|&(pr, _)| pr == p));
        }
        assert!(spec.iter().any(|&(_, d)| d.is_none()));
        assert!(spec.iter().any(|&(_, d)| d.is_some()));
    }

    /// The same contended queue drained by racing consumers: every
    /// request is delivered exactly once, regardless of which worker
    /// pops it.
    #[test]
    fn contended_pops_deliver_each_request_exactly_once() {
        let s = Arc::new(Scheduler::new(Policy::Priority));
        for id in 0..64u64 {
            assert!(s.push(
                req(id, "x").with_priority((id % 5) as i32)
            ));
        }
        s.close();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                let mut ids = Vec::new();
                while let Some((r, _)) = s.pop() {
                    ids.push(r.id);
                }
                ids
            }));
        }
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
    }

    /// Regression (push-after-close panic): a closed queue rejects new
    /// requests instead of panicking the submitter.
    #[test]
    fn push_after_close_is_rejected_not_a_panic() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.push(req(0, "a")));
        s.close();
        assert!(!s.push(req(1, "b")), "push after close must be rejected");
        assert_eq!(s.submit(req(2, "c")), Admission::Closed);
        assert_eq!(s.len(), 1, "rejected request must not be queued");
        assert_eq!(s.pop().unwrap().0.id, 0);
        assert!(s.pop().is_none());
    }

    #[test]
    fn try_pop_never_blocks() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.try_pop().is_none(), "empty open queue: no block, None");
        assert!(s.push(req(3, "hi")));
        assert_eq!(s.try_pop().unwrap().0.id, 3);
        assert!(s.try_pop().is_none());
        s.close();
        assert!(s.try_pop().is_none());
    }

    #[test]
    fn close_drains_pending_then_ends() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.push(req(0, "a")));
        assert!(s.push(req(1, "b")));
        assert_eq!(s.len(), 2);
        s.close();
        assert_eq!(s.pop().unwrap().0.id, 0);
        assert_eq!(s.pop().unwrap().0.id, 1);
        assert!(s.pop().is_none());
        assert!(s.is_empty());
    }

    #[test]
    fn pop_blocks_until_push_and_reports_queue_time() {
        let s = Arc::new(Scheduler::new(Policy::Fifo));
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            assert!(s2.push(req(7, "hi")));
            s2.close();
        });
        let (r, q) = s.pop().expect("request");
        assert_eq!(r.id, 7);
        assert!(q >= 0.0);
        assert!(s.pop().is_none());
        h.join().unwrap();
    }

    #[test]
    fn policy_parse_round_trips() {
        assert_eq!(Policy::parse("fifo").unwrap(), Policy::Fifo);
        assert_eq!(Policy::parse("spf").unwrap(), Policy::ShortestPromptFirst);
        assert_eq!(Policy::parse("priority").unwrap(), Policy::Priority);
        assert_eq!(Policy::parse("edf").unwrap(), Policy::Priority);
        assert!(Policy::parse("lifo").is_err());
    }

    // ---- admission control / shedding ----

    fn sched_with(shed: ShedPolicy, weights: &[f64]) -> Scheduler {
        Scheduler::new_with(SchedConfig {
            policy: Policy::Fifo,
            shed: Some(shed),
            tenant_weights: weights.to_vec(),
        })
    }

    #[test]
    fn queue_depth_bound_sheds_with_typed_reason() {
        let s = sched_with(
            ShedPolicy { max_queue_depth: 2, ..ShedPolicy::default() },
            &[],
        );
        assert_eq!(s.submit(req(0, "a")), Admission::Queued);
        assert_eq!(s.submit(req(1, "b")), Admission::Queued);
        match s.submit(req(2, "c")) {
            Admission::Shed(ShedReason::QueueFull { depth, limit }) => {
                assert_eq!((depth, limit), (2, 2));
            }
            other => panic!("expected queue-full shed, got {other:?}"),
        }
        assert_eq!(s.len(), 2, "shed request must not be queued");
        assert_eq!(s.shed_count(), 1);
        // Draining makes room again.
        assert!(s.try_pop().is_some());
        assert_eq!(s.submit(req(3, "d")), Admission::Queued);
    }

    #[test]
    fn predicted_ttft_bound_sheds_once_primed() {
        let s = sched_with(
            ShedPolicy {
                max_predicted_ttft: Some(Duration::from_millis(1500)),
                ..ShedPolicy::default()
            },
            &[],
        );
        // Unprimed EMA: everything admits regardless of depth.
        for id in 0..3 {
            assert_eq!(s.submit(req(id, "a")), Admission::Queued);
        }
        // Prime at 1s per request: depth 3 predicts 3s > 1.5s.
        s.note_done(1.0);
        match s.submit(req(3, "b")) {
            Admission::Shed(ShedReason::PredictedTtft {
                predicted_ms,
                limit_ms,
            }) => {
                assert_eq!(limit_ms, 1500);
                assert!(predicted_ms >= 2999, "{predicted_ms}");
            }
            other => panic!("expected TTFT shed, got {other:?}"),
        }
        // Drain to depth 1: predicted 1s <= 1.5s admits again.
        assert!(s.try_pop().is_some());
        assert!(s.try_pop().is_some());
        assert_eq!(s.submit(req(4, "c")), Admission::Queued);
    }

    #[test]
    fn degrade_clamps_budget_past_soft_depth() {
        let s = sched_with(
            ShedPolicy {
                degrade_depth: 1,
                degrade_max_new: 4,
                ..ShedPolicy::default()
            },
            &[],
        );
        assert_eq!(s.submit(req(0, "a")), Admission::Queued);
        assert_eq!(
            s.submit(req(1, "b")),
            Admission::Degraded { max_new: 4 }
        );
        // Already under the degraded budget: queued untouched.
        assert_eq!(
            s.submit(ServeRequest::new(2, "c", 2)),
            Admission::Queued
        );
        assert_eq!(s.degraded_count(), 1);
        let budgets: Vec<usize> =
            std::iter::from_fn(|| s.try_pop().map(|(r, _)| r.max_new))
                .collect();
        assert_eq!(budgets, vec![8, 4, 2]);
    }

    /// Property: shedding is monotone in offered load — at a fixed depth
    /// bound, submitting a prefix of the same arrival sequence never
    /// sheds more than submitting the whole thing.
    #[test]
    fn prop_shedding_monotone_in_load() {
        crate::util::proptest::check("shed monotone", 64, |rng| {
            let limit = 1 + rng.below(6);
            let total = 2 + rng.below(24);
            let cut = rng.below(total + 1);
            let shed_upto = |n: usize| -> u64 {
                let s = sched_with(
                    ShedPolicy {
                        max_queue_depth: limit,
                        ..ShedPolicy::default()
                    },
                    &[],
                );
                for id in 0..n {
                    let _ = s.submit(req(id as u64, "x"));
                }
                s.shed_count()
            };
            let (partial, full) = (shed_upto(cut), shed_upto(total));
            if partial > full {
                return Err(format!(
                    "{cut} arrivals shed {partial} but {total} shed {full} \
                     (limit {limit})"
                ));
            }
            // With no draining, the counts are exactly determined.
            let want = total.saturating_sub(limit) as u64;
            if full != want {
                return Err(format!(
                    "expected {want} sheds at depth limit {limit} over \
                     {total} arrivals, got {full}"
                ));
            }
            Ok(())
        });
    }

    // ---- weighted tenant fairness ----

    #[test]
    fn weighted_fairness_splits_backlogged_tenants_by_weight() {
        let s = sched_with(ShedPolicy::default(), &[3.0, 1.0]);
        for id in 0..40u64 {
            assert!(s.push(req(id, "x").with_tenant((id % 2) as usize)));
        }
        // Both tenants stay backlogged for the first 20 pops: tenant 0
        // (weight 3) should take ~3 of every 4 dispatches.
        let mut counts = [0usize; 2];
        for _ in 0..20 {
            let (r, _) = s.try_pop().unwrap();
            counts[r.tenant] += 1;
        }
        assert!(
            (14..=16).contains(&counts[0]),
            "weight-3 tenant took {} of 20",
            counts[0]
        );
        // Everything still drains.
        while s.try_pop().is_some() {}
        assert!(s.is_empty());
    }

    #[test]
    fn idle_tenant_cannot_bank_credit() {
        let s = sched_with(ShedPolicy::default(), &[1.0, 1.0]);
        // Tenant 0 runs alone for a while.
        for id in 0..10u64 {
            assert!(s.push(req(id, "x").with_tenant(0)));
        }
        for _ in 0..10 {
            assert!(s.try_pop().is_some());
        }
        // Tenant 1 arrives with a burst; both tenants now pending.
        for id in 10..20u64 {
            assert!(s.push(req(id, "x").with_tenant(1)));
        }
        for id in 20..30u64 {
            assert!(s.push(req(id, "x").with_tenant(0)));
        }
        // Equal weights from here: the first 8 pops cannot all go to the
        // returning tenant (the catch-up clamp erased its idle credit).
        let mut counts = [0usize; 2];
        for _ in 0..8 {
            counts[s.try_pop().unwrap().0.tenant] += 1;
        }
        assert!(
            counts[0] >= 3 && counts[1] >= 3,
            "post-idle dispatch should interleave, got {counts:?}"
        );
    }

    /// Property: under random bursty arrivals with both tenants kept
    /// backlogged, dispatch shares converge to the configured weights.
    #[test]
    fn prop_weighted_shares_converge_under_bursts() {
        crate::util::proptest::check("fairness converges", 32, |rng| {
            let w0 = 1.0 + rng.below(4) as f64;
            let w1 = 1.0 + rng.below(4) as f64;
            let s = sched_with(ShedPolicy::default(), &[w0, w1]);
            // Random interleaved bursts, everything enqueued up front so
            // both tenants stay backlogged throughout the drain.
            let mut id = 0u64;
            let mut per_tenant = [0usize; 2];
            while per_tenant[0] < 30 || per_tenant[1] < 30 {
                let t = rng.below(2);
                let burst = 1 + rng.below(6);
                for _ in 0..burst {
                    assert!(s.push(req(id, "x").with_tenant(t)));
                    per_tenant[t] += 1;
                    id += 1;
                }
            }
            // Pop while both tenants still have pending work; count
            // dispatches.
            let mut served = [0usize; 2];
            let mut pending = per_tenant;
            while pending[0] > 0 && pending[1] > 0 {
                let (r, _) = s.try_pop().unwrap();
                served[r.tenant] += 1;
                pending[r.tenant] -= 1;
            }
            let total = (served[0] + served[1]) as f64;
            if total < 20.0 {
                return Ok(()); // degenerate drain, too short to judge
            }
            let want0 = w0 / (w0 + w1);
            let got0 = served[0] as f64 / total;
            if (got0 - want0).abs() > 0.15 {
                return Err(format!(
                    "weights ({w0},{w1}): tenant0 share {got0:.3}, \
                     want {want0:.3} (served {served:?})"
                ));
            }
            Ok(())
        });
    }

    // ---- deadline urgency (the preemption trigger) ----

    #[test]
    fn pop_urgent_only_fires_within_horizon() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.push(req(0, "no deadline")));
        assert!(s.push(
            req(1, "far").with_deadline(Duration::from_secs(600))
        ));
        // Nothing urgent: deadline-less and far-future requests stay.
        assert!(s
            .pop_urgent_when(Duration::from_millis(50), |_| true)
            .is_none());
        assert_eq!(s.len(), 2);
        // A near deadline within the horizon pops past FIFO order.
        assert!(s.push(
            req(2, "soon").with_deadline(Duration::from_millis(10))
        ));
        let (r, _) = s
            .pop_urgent_when(Duration::from_secs(1), |_| true)
            .expect("urgent request");
        assert_eq!(r.id, 2);
        // The predicate can veto (no victim / no park room): request
        // stays queued.
        assert!(s.push(
            req(3, "soon2").with_deadline(Duration::from_millis(10))
        ));
        assert!(s
            .pop_urgent_when(Duration::from_secs(1), |_| false)
            .is_none());
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn try_pop_preferring_biases_by_score_then_policy() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.push(req(0, "a")));
        assert!(s.push(req(1, "b")));
        assert!(s.push(req(2, "c")));
        // Prefer odd ids: 1 wins despite FIFO order; ties (0 vs 2) then
        // fall back to FIFO.
        let score = |r: &ServeRequest| if r.id % 2 == 1 { 0 } else { 1 };
        assert_eq!(s.try_pop_preferring(score).unwrap().0.id, 1);
        assert_eq!(s.try_pop_preferring(score).unwrap().0.id, 0);
        assert_eq!(s.try_pop_preferring(score).unwrap().0.id, 2);
        assert!(s.try_pop_preferring(score).is_none());
    }
}
