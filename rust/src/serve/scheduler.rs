//! The serving queue: submitted requests wait here until an engine worker
//! pops them.
//!
//! Three policies:
//!
//! - **FIFO** — arrival order; fair, and the baseline any latency claim
//!   is measured against.
//! - **Shortest-prompt-first (SPF)** — byte-tokenised prompt length as
//!   the service-time proxy; the classic mean-latency optimisation when
//!   request sizes are heterogeneous (long summarisation prompts would
//!   otherwise head-of-line-block short QA ones).
//! - **Priority** — highest [`ServeRequest::priority`] first; ties go to
//!   the earliest absolute deadline (earliest-deadline-first), with
//!   deadline-less requests after any deadlined peer, then arrival order.

use std::cmp::Reverse;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use super::request::ServeRequest;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    ShortestPromptFirst,
    Priority,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        match s {
            "fifo" => Ok(Policy::Fifo),
            "spf" | "shortest-prompt-first" => Ok(Policy::ShortestPromptFirst),
            "priority" | "edf" => Ok(Policy::Priority),
            other => {
                bail!("unknown scheduling policy {other:?} (fifo|spf|priority)")
            }
        }
    }
}

struct Queued {
    req: ServeRequest,
    enqueued: Instant,
}

#[derive(Default)]
struct State {
    pending: VecDeque<Queued>,
    closed: bool,
}

/// Thread-safe request queue shared between submitters and pool workers.
pub struct Scheduler {
    policy: Policy,
    state: Mutex<State>,
    cv: Condvar,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Enqueue a request. Returns `false` — rejecting the request — when
    /// the queue has already been closed: submitting to a shut-down pool
    /// is an error for the caller to handle, never a submitter panic.
    #[must_use]
    pub fn push(&self, req: ServeRequest) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return false;
        }
        st.pending.push_back(Queued { req, enqueued: Instant::now() });
        self.cv.notify_one();
        true
    }

    /// Number of queued (not yet claimed) requests.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: workers drain what is pending, then `pop` returns
    /// `None` and they exit. Subsequent `push` calls are rejected.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    /// Block until a request is available (or the queue is closed and
    /// drained). Returns the request and its queue wait in seconds.
    pub fn pop(&self) -> Option<(ServeRequest, f64)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(popped) = self.pop_locked(&mut st) {
                return Some(popped);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Non-blocking pop: `None` when nothing is queued right now. This is
    /// how continuous-batching workers admit requests *between* decode
    /// steps without stalling their live sessions.
    pub fn try_pop(&self) -> Option<(ServeRequest, f64)> {
        let mut st = self.state.lock().unwrap();
        self.pop_locked(&mut st)
    }

    /// Select-and-remove core shared by `pop` and `try_pop`.
    fn pop_locked(&self, st: &mut State) -> Option<(ServeRequest, f64)> {
        let i = self.select(&st.pending)?;
        let q = st.pending.remove(i).unwrap();
        Some((q.req, q.enqueued.elapsed().as_secs_f64()))
    }

    /// Index of the next request under the configured policy.
    fn select(&self, pending: &VecDeque<Queued>) -> Option<usize> {
        if pending.is_empty() {
            return None;
        }
        match self.policy {
            Policy::Fifo => Some(0),
            // Ties break by arrival order (stable min over index).
            Policy::ShortestPromptFirst => (0..pending.len())
                .min_by_key(|&i| (pending[i].req.prompt.len(), i)),
            // Highest priority; then earliest absolute deadline, with
            // deadline-less requests last; then arrival order.
            Policy::Priority => (0..pending.len()).min_by_key(|&i| {
                let q = &pending[i];
                let due = q.req.deadline.map(|d| q.enqueued + d);
                (
                    Reverse(q.req.priority),
                    due.is_none(),
                    due.unwrap_or(q.enqueued),
                    i,
                )
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;
    use std::time::Duration;

    use super::*;

    fn req(id: u64, prompt: &str) -> ServeRequest {
        ServeRequest::new(id, prompt, 8)
    }

    #[test]
    fn fifo_pops_in_arrival_order() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.push(req(0, "long prompt here")));
        assert!(s.push(req(1, "x")));
        assert!(s.push(req(2, "mid")));
        s.close();
        let ids: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn spf_pops_shortest_prompt_first_with_stable_ties() {
        let s = Scheduler::new(Policy::ShortestPromptFirst);
        assert!(s.push(req(0, "aaaa")));
        assert!(s.push(req(1, "a")));
        assert!(s.push(req(2, "aa")));
        assert!(s.push(req(3, "a")));
        s.close();
        let ids: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        assert_eq!(ids, vec![1, 3, 2, 0]);
    }

    #[test]
    fn priority_policy_orders_by_priority_then_deadline() {
        let s = Scheduler::new(Policy::Priority);
        // Same priority, later deadline.
        assert!(s.push(
            req(0, "a").with_deadline(Duration::from_secs(60))
        ));
        // Highest priority wins regardless of arrival.
        assert!(s.push(req(1, "b").with_priority(5)));
        // Same priority as 0, sooner deadline: beats 0.
        assert!(s.push(
            req(2, "c").with_deadline(Duration::from_secs(1))
        ));
        // Same priority, no deadline: after every deadlined peer.
        assert!(s.push(req(3, "d")));
        // No deadline, arrived after 3: FIFO between the deadline-less.
        assert!(s.push(req(4, "e")));
        s.close();
        let ids: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        assert_eq!(ids, vec![1, 2, 0, 3, 4]);
    }

    /// Priority-then-EDF under a synthetic contended load: 48 requests
    /// with mixed priorities and deadlines, drained in one go. Deadlines
    /// are whole seconds apart, so the microsecond enqueue jitter of
    /// same-process pushes cannot flip the earliest-absolute-deadline
    /// order, and the expected sequence is exactly the stable sort by
    /// (priority desc, has-deadline first, deadline asc, arrival).
    #[test]
    fn priority_policy_orders_contended_load_by_priority_then_edf() {
        use crate::util::rng::Rng;

        let s = Scheduler::new(Policy::Priority);
        let mut rng = Rng::new(0xEE11E);
        let n = 48u64;
        let mut spec: Vec<(i32, Option<u64>)> = Vec::new();
        for id in 0..n {
            let priority = rng.below(3) as i32;
            let deadline = if rng.below(4) == 0 {
                None
            } else {
                Some(1 + rng.below(1000) as u64)
            };
            spec.push((priority, deadline));
            let mut r = req(id, "x").with_priority(priority);
            if let Some(secs) = deadline {
                r = r.with_deadline(Duration::from_secs(secs));
            }
            assert!(s.push(r));
        }
        s.close();
        let got: Vec<u64> =
            std::iter::from_fn(|| s.pop().map(|(r, _)| r.id)).collect();
        let mut want: Vec<u64> = (0..n).collect();
        want.sort_by_key(|&id| {
            let (priority, deadline) = spec[id as usize];
            (
                std::cmp::Reverse(priority),
                deadline.is_none(),
                deadline.unwrap_or(0),
                id,
            )
        });
        assert_eq!(got, want, "spec {spec:?}");
        // Sanity on the shape of the load: all three priorities and both
        // deadline kinds occurred, so the test really exercised the
        // tie-break chain.
        for p in 0..3 {
            assert!(spec.iter().any(|&(pr, _)| pr == p));
        }
        assert!(spec.iter().any(|&(_, d)| d.is_none()));
        assert!(spec.iter().any(|&(_, d)| d.is_some()));
    }

    /// The same contended queue drained by racing consumers: every
    /// request is delivered exactly once, regardless of which worker
    /// pops it.
    #[test]
    fn contended_pops_deliver_each_request_exactly_once() {
        let s = Arc::new(Scheduler::new(Policy::Priority));
        for id in 0..64u64 {
            assert!(s.push(
                req(id, "x").with_priority((id % 5) as i32)
            ));
        }
        s.close();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                let mut ids = Vec::new();
                while let Some((r, _)) = s.pop() {
                    ids.push(r.id);
                }
                ids
            }));
        }
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
    }

    /// Regression (push-after-close panic): a closed queue rejects new
    /// requests instead of panicking the submitter.
    #[test]
    fn push_after_close_is_rejected_not_a_panic() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.push(req(0, "a")));
        s.close();
        assert!(!s.push(req(1, "b")), "push after close must be rejected");
        assert_eq!(s.len(), 1, "rejected request must not be queued");
        assert_eq!(s.pop().unwrap().0.id, 0);
        assert!(s.pop().is_none());
    }

    #[test]
    fn try_pop_never_blocks() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.try_pop().is_none(), "empty open queue: no block, None");
        assert!(s.push(req(3, "hi")));
        assert_eq!(s.try_pop().unwrap().0.id, 3);
        assert!(s.try_pop().is_none());
        s.close();
        assert!(s.try_pop().is_none());
    }

    #[test]
    fn close_drains_pending_then_ends() {
        let s = Scheduler::new(Policy::Fifo);
        assert!(s.push(req(0, "a")));
        assert!(s.push(req(1, "b")));
        assert_eq!(s.len(), 2);
        s.close();
        assert_eq!(s.pop().unwrap().0.id, 0);
        assert_eq!(s.pop().unwrap().0.id, 1);
        assert!(s.pop().is_none());
        assert!(s.is_empty());
    }

    #[test]
    fn pop_blocks_until_push_and_reports_queue_time() {
        let s = Arc::new(Scheduler::new(Policy::Fifo));
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            assert!(s2.push(req(7, "hi")));
            s2.close();
        });
        let (r, q) = s.pop().expect("request");
        assert_eq!(r.id, 7);
        assert!(q >= 0.0);
        assert!(s.pop().is_none());
        h.join().unwrap();
    }

    #[test]
    fn policy_parse_round_trips() {
        assert_eq!(Policy::parse("fifo").unwrap(), Policy::Fifo);
        assert_eq!(Policy::parse("spf").unwrap(), Policy::ShortestPromptFirst);
        assert_eq!(Policy::parse("priority").unwrap(), Policy::Priority);
        assert_eq!(Policy::parse("edf").unwrap(), Policy::Priority);
        assert!(Policy::parse("lifo").is_err());
    }
}
