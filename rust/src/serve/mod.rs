//! Multi-request early-exit serving — a request queue + scheduler
//! multiplexing many concurrent generation requests over a pool of
//! inference-engine workers, with continuous batching and streamed
//! token responses.
//!
//! The paper's Section 4 inference methods are designed to be
//! serving-compatible (KV-cache-aware early exits); follow-up work shows
//! the real-world speedup of early exit only materialises under a
//! batched, multi-request front-end with iteration-level scheduling.
//! This module supplies that front-end for both engines:
//!
//! - [`request`] — request/response types (per-request exit policies
//!   ([`ExitPolicy`](crate::inference::ExitPolicy), via
//!   [`ServeRequest::with_policy`] or the `with_threshold` confidence
//!   sugar), priorities, deadlines; TTFT and per-token stream timing on
//!   responses) and request-set builders over the eval task suite.
//! - [`scheduler`] — the shared queue with FIFO, shortest-prompt-first,
//!   and priority/earliest-deadline policies, plus the non-blocking
//!   `try_pop` continuous batching admits through.
//! - [`pool`] — [`EnginePool`]: N worker threads, each owning a
//!   [`SequentialEngine`](crate::inference::SequentialEngine) or
//!   [`PipelinedEngine`](crate::inference::PipelinedEngine) built
//!   in-thread (the `xla` runtime is `!Send`; only
//!   [`ModelState`](crate::inference::ModelState) crosses threads). Each
//!   worker is a continuous-batching loop over resumable
//!   [`DecodeSession`](crate::inference::DecodeSession)s: up to
//!   [`PoolConfig::max_concurrent`] live sessions stepped round-robin,
//!   new requests admitted between steps, every token streamed as a
//!   [`ServeEvent`] the moment it is emitted. Batches return per-request
//!   outcomes ([`BatchOutcome`]): one poisoned prompt fails alone. With
//!   [`PoolConfig::prefix_cache_positions`] set, the pool keeps one
//!   [`PrefixCacheStore`](crate::inference::PrefixCacheStore) of
//!   post-prefill KV snapshots **shared across all workers**, so
//!   admissions sharing a prompt prefix (system-prompt traffic) restore
//!   it — whichever worker prefilled it — and prefill only the suffix,
//!   on either engine (the pipelined engine snapshots and restores over
//!   its stage chain's drain protocol).
//!   Workers step their live sessions in policy-ordered rounds with
//!   **lane-fused batched decode** ([`PoolConfig::lane_fusion`]):
//!   same-policy sessions with no recompute deficit advance through one
//!   batched XLA call per stage (the manifest's `decode_lanes`
//!   executables, greedy largest group first), the rest step solo —
//!   output-invisibly (`tests/batched_decode_equivalence.rs`). Pipelined
//!   workers instead run **interleaved rounds**: every live session's
//!   window is submitted down the stage chain before any token is
//!   collected, overlapping sessions on the chain — output-invisibly too
//!   (`tests/pipelined_serving_equivalence.rs`).
//! - [`metrics`] — aggregate serving metrics: throughput tokens/s,
//!   p50/p95 request latency, p50/p95 time-to-first-token, p50/p95
//!   per-token gaps, queueing, deadline misses, merged per-exit usage,
//!   prefix-cache hit-rate / prefill-positions-saved, lane-fusion
//!   activity ([`LaneStats`]: fused vs solo steps, lane occupancy,
//!   stages skipped, policy swaps), and interleaved-round activity
//!   ([`InterleaveStats`]: rounds, steps, and the in-flight-sessions
//!   occupancy histogram that makes bubble-filling observable).
//!
//! Entry points: `ee-llm serve-bench` (CLI), the `serving_throughput`
//! bench, and `examples/serve_demo.rs`.

pub mod metrics;
pub mod pool;
pub mod request;
pub mod scheduler;

pub use metrics::{
    percentile, InterleaveStats, LaneCounters, LaneStats, ServeMetrics,
};
pub use pool::{
    plan_round, BatchOutcome, EngineKind, EnginePool, PoolConfig,
    RequestFailure, ServeEvent,
};
pub use request::{requests_from_tasks, ServeRequest, ServeResponse};
pub use scheduler::{Policy, Scheduler};
