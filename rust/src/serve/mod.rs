//! Multi-request early-exit serving — a request queue + scheduler
//! multiplexing many concurrent generation requests over a pool of
//! inference-engine workers, with continuous batching and streamed
//! token responses.
//!
//! The paper's Section 4 inference methods are designed to be
//! serving-compatible (KV-cache-aware early exits); follow-up work shows
//! the real-world speedup of early exit only materialises under a
//! batched, multi-request front-end with iteration-level scheduling.
//! This module supplies that front-end for both engines:
//!
//! - [`request`] — request/response types (per-request exit policies
//!   ([`ExitPolicy`](crate::inference::ExitPolicy), via
//!   [`ServeRequest::with_policy`] or the `with_threshold` confidence
//!   sugar), priorities, deadlines; TTFT and per-token stream timing on
//!   responses) and request-set builders over the eval task suite.
//! - [`scheduler`] — the shared queue with FIFO, shortest-prompt-first,
//!   and priority/earliest-deadline policies, plus the non-blocking
//!   `try_pop` continuous batching admits through. The queue is also
//!   the **admission-control** seam: a [`ShedPolicy`] bounds queue
//!   depth and predicted TTFT at enqueue ([`Scheduler::submit`]
//!   returns a typed [`Admission`] — queued, budget-degraded, shed
//!   with reason, or closed), and a tenant-weight table turns dispatch
//!   into weighted fairness over [`ServeRequest::tenant`] (per-tenant
//!   virtual time; bursty tenants converge to their weights).
//! - [`pool`] — [`EnginePool`]: N worker threads, each owning a
//!   [`SequentialEngine`](crate::inference::SequentialEngine) or
//!   [`PipelinedEngine`](crate::inference::PipelinedEngine) built
//!   in-thread (the `xla` runtime is `!Send`; only
//!   [`ModelState`](crate::inference::ModelState) crosses threads). Each
//!   worker is a continuous-batching loop over resumable
//!   [`DecodeSession`](crate::inference::DecodeSession)s: up to
//!   [`PoolConfig::max_concurrent`] live sessions stepped round-robin,
//!   new requests admitted between steps, every token streamed as a
//!   [`ServeEvent`] the moment it is emitted. Batches return per-request
//!   outcomes ([`BatchOutcome`]): one poisoned prompt fails alone. With
//!   [`PoolConfig::prefix_cache_positions`] set, the pool keeps one
//!   tiered snapshot store
//!   ([`TieredStore`](crate::inference::TieredStore)) of post-prefill
//!   and end-of-turn KV snapshots **shared across all workers**, so
//!   admissions sharing a prompt prefix (system-prompt traffic) restore
//!   it — whichever worker prefilled it — and prefill only the suffix,
//!   on either engine (the pipelined engine snapshots and restores over
//!   its stage chain's drain protocol); within
//!   [`PoolConfig::device_tier_positions`], the store's hottest entries
//!   stay pinned device-resident.
//!   **Conversational serving** ([`ServeRequest::with_conversation`]):
//!   a completed turn's end-of-turn KV state (prompt ⧺ generated) is
//!   snapshotted into the same store before its session closes, so the
//!   conversation's next turn restores the whole history and prefills
//!   only its own new text; a pool-wide registry expires conversations
//!   idle past [`PoolConfig::convo_idle_ttl`], releasing their stored
//!   history.
//!   Workers step their live sessions in policy-ordered rounds with
//!   **lane-fused batched decode** ([`PoolConfig::lane_fusion`]):
//!   same-policy sessions with no recompute deficit advance through one
//!   batched XLA call per stage (the manifest's `decode_lanes`
//!   executables, greedy largest group first), the rest step solo —
//!   output-invisibly (`tests/batched_decode_equivalence.rs`). Pipelined
//!   workers instead run **interleaved rounds**: every live session's
//!   window is submitted down the stage chain before any token is
//!   collected, overlapping sessions on the chain — output-invisibly too
//!   (`tests/pipelined_serving_equivalence.rs`).
//!   The pool's **SLO control plane** ([`ControlConfig`]) adds
//!   deadline-driven preemption on top: a full worker parks its
//!   lowest-value live session (a host-resident
//!   [`ParkedSession`](crate::inference::ParkedSession) snapshot in a
//!   strictly bounded pool-wide store) to admit a queued request about
//!   to blow its deadline, and the parked session resumes — on any
//!   worker — once a slot frees, with its original token stream intact
//!   (`tests/slo_serving_equivalence.rs`). Shed requests surface as
//!   typed [`BatchOutcome::sheds`] outcomes, park/resume faults as
//!   per-request failures that never wipe a batch.
//!   **Self-healing serving** ([`HealConfig`], [`faults`]): a
//!   deterministic per-worker chaos schedule ([`FaultPlan`],
//!   `serve-bench --chaos`) injects faults at every serving seam; live
//!   sessions capture decode-time micro-checkpoints at a fixed token
//!   cadence, failed requests re-admit from them (bounded retries,
//!   exponential backoff) with already-streamed tokens suppressed on
//!   replay — recovered streams are identical to fault-free runs
//!   (`tests/chaos_recovery_equivalence.rs`) — and a panicked or
//!   chain-poisoned engine is rebuilt in place, quarantining the
//!   worker after repeated flaps.
//! - [`faults`] — the fault-injection plan/injector
//!   ([`FaultSite`]/[`FaultPlan`]/[`FaultInjector`]): pinned-seed,
//!   per-worker, per-site deterministic schedules, plus failure
//!   classification and the recovery backoff curve.
//! - [`metrics`] — aggregate serving metrics: throughput tokens/s,
//!   p50/p95 request latency, p50/p95 time-to-first-token, p50/p95
//!   per-token gaps, queueing, deadline misses, merged per-exit usage,
//!   prefix-cache hit-rate / prefill-positions-saved, lane-fusion
//!   activity ([`LaneStats`]: fused vs solo steps, lane occupancy,
//!   stages skipped, policy swaps), interleaved-round activity
//!   ([`InterleaveStats`]: rounds, steps, and the in-flight-sessions
//!   occupancy histogram that makes bubble-filling observable), and
//!   the SLO surface: p99 TTFT, deadline-miss rate over deadlined
//!   requests, control-plane counters ([`SloStats`]:
//!   preempt/resume/park-fault/shed/degrade, park-store peak),
//!   per-tenant token shares ([`TenantShare`]), conversation counters
//!   ([`ConvoStats`]: turns, restore hit rate, prefill positions saved,
//!   end-of-turn snapshots, TTL expiries), device-tier activity
//!   ([`crate::inference::TierStats`]), and the unified
//!   [`SnapshotMemory`] gauge (prefix store + device tier + park store
//!   under one block).
//!
//! Entry points: `ee-llm serve-bench` (CLI), the `serving_throughput`
//! bench, and `examples/serve_demo.rs`.

pub mod faults;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod scheduler;

pub use faults::{
    classify_failure, injected_error, recovery_backoff, FaultInjector,
    FaultPlan, FaultSite, FAULT_SITES,
};
pub use metrics::{
    percentile, ConvoCounters, ConvoStats, FaultCounters, FaultStats,
    InterleaveStats, LaneCounters, LaneStats, ServeMetrics, SloCounters,
    SloStats, SnapshotMemory, TenantShare,
};
pub use pool::{
    plan_round, BatchOutcome, ControlConfig, ControlFault, EngineKind,
    EnginePool, HealConfig, Outcome, PoolConfig, RequestFailure,
    ServeEvent, Shed,
};
pub use request::{requests_from_tasks, ServeRequest, ServeResponse};
pub use scheduler::{
    Admission, Policy, SchedConfig, Scheduler, ShedPolicy, ShedReason,
};
