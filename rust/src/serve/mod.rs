//! Multi-request early-exit serving — a request queue + scheduler
//! multiplexing many concurrent generation requests over a pool of
//! inference-engine workers.
//!
//! The paper's Section 4 inference methods are designed to be
//! serving-compatible (KV-cache-aware early exits); follow-up work shows
//! the real-world speedup of early exit only materialises under a
//! batched, multi-request front-end. This module supplies that front-end
//! for both engines:
//!
//! - [`request`] — request/response types, per-request thresholds, and
//!   request-set builders over the eval task suite.
//! - [`scheduler`] — the shared queue with FIFO and shortest-prompt-first
//!   policies.
//! - [`pool`] — [`EnginePool`]: N worker threads, each owning a
//!   [`SequentialEngine`](crate::inference::SequentialEngine) or
//!   [`PipelinedEngine`](crate::inference::PipelinedEngine) built
//!   in-thread (the `xla` runtime is `!Send`; only
//!   [`ModelState`](crate::inference::ModelState) crosses threads).
//! - [`metrics`] — aggregate serving metrics: throughput tokens/s,
//!   p50/p95 request latency, queueing, merged per-exit usage.
//!
//! Entry points: `ee-llm serve-bench` (CLI), the `serving_throughput`
//! bench, and `examples/serve_demo.rs`.

pub mod metrics;
pub mod pool;
pub mod request;
pub mod scheduler;

pub use metrics::{percentile, ServeMetrics};
pub use pool::{EngineKind, EnginePool, PoolConfig};
pub use request::{requests_from_tasks, ServeRequest, ServeResponse};
pub use scheduler::{Policy, Scheduler};
