//! Deterministic fault injection for the self-healing serving pool.
//!
//! PR 8 introduced a single-seam fault hook ([`super::ControlFault`]):
//! one park or resume per pool could be poisoned, unconditionally. The
//! chaos harness generalizes it into a *plan*: every recovery-relevant
//! seam of the serving stack gets its own independent fault rate, and a
//! pinned seed makes the whole schedule reproducible — the same plan on
//! the same workload injects the same faults at the same steps, so a
//! chaos run can be compared token-for-token against its fault-free
//! twin (`tests/chaos_recovery_equivalence.rs`).
//!
//! The plan is pure data ([`FaultPlan`], parsed from the
//! `serve-bench --chaos SPEC` flag); each worker derives its own
//! [`FaultInjector`] by forking the plan's seed with the worker index,
//! and each seam inside a worker draws from its own forked stream — so
//! the decision sequence at one seam is independent of how often any
//! other seam is consulted, and adding a new seam never perturbs the
//! schedules of existing ones.
//!
//! Injected faults are *synthesized at the seam*: the pool fabricates
//! the typed error a real failure would produce (every message contains
//! `"injected"`) and releases engine state exactly as the organic
//! failure path would, so recovery is exercised against honest
//! wreckage. Fault accounting lands in
//! [`super::metrics::FaultStats`].

use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Number of injectable seams ([`FaultSite::ALL`]).
pub const FAULT_SITES: usize = 10;

/// One injectable seam of the serving stack. Sites mirror the places a
/// request can organically fail: the decode dispatch paths, the stage
/// chain, and every KV-snapshot transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultSite {
    /// A fused lane-group decode dispatch (sequential engine) fails
    /// before touching any lane's caches; the group falls back to solo
    /// retries.
    FusedDispatch,
    /// An interleaved round's window submission fails before reaching
    /// the stage chain (pipelined engine).
    SubmitWindow,
    /// An interleaved round's token collect fails before reading the
    /// stage chain (pipelined engine).
    CollectWindow,
    /// A stage thread of the pipelined chain is killed mid-round,
    /// poisoning the chain until the supervisor rebuilds the engine.
    StagePanic,
    /// A KV-snapshot capture (decode-time micro-checkpoint) fails; the
    /// session keeps its previous checkpoint.
    Snapshot,
    /// A KV-snapshot restore during a recovery re-admission fails,
    /// consuming one retry.
    Restore,
    /// The prefix-cache restore during admission prefill fails; the
    /// request enters recovery from scratch.
    PrefixRestore,
    /// The park snapshot of a preemption victim fails (the seam
    /// [`super::ControlFault::ParkSnapshot`] poisoned).
    Park,
    /// The restore of a parked session fails on resume (the seam
    /// [`super::ControlFault::ResumeRestore`] poisoned).
    Resume,
    /// A solo decode step fails (the generic engine-failure bucket;
    /// also where organic failures with no better attribution land).
    Decode,
}

impl FaultSite {
    pub const ALL: [FaultSite; FAULT_SITES] = [
        FaultSite::FusedDispatch,
        FaultSite::SubmitWindow,
        FaultSite::CollectWindow,
        FaultSite::StagePanic,
        FaultSite::Snapshot,
        FaultSite::Restore,
        FaultSite::PrefixRestore,
        FaultSite::Park,
        FaultSite::Resume,
        FaultSite::Decode,
    ];

    /// Dense index into per-site arrays ([`FAULT_SITES`] wide).
    pub fn index(self) -> usize {
        Self::ALL.iter().position(|&s| s == self).unwrap_or(0)
    }

    /// The spec key naming this site in `--chaos` specs and JSON
    /// output.
    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::FusedDispatch => "dispatch",
            FaultSite::SubmitWindow => "submit",
            FaultSite::CollectWindow => "collect",
            FaultSite::StagePanic => "panic",
            FaultSite::Snapshot => "snapshot",
            FaultSite::Restore => "restore",
            FaultSite::PrefixRestore => "prefix",
            FaultSite::Park => "park",
            FaultSite::Resume => "resume",
            FaultSite::Decode => "decode",
        }
    }

    pub fn parse(s: &str) -> Option<FaultSite> {
        FaultSite::ALL.iter().copied().find(|f| f.as_str() == s)
    }
}

/// A deterministic fault schedule: a seed plus one fault probability
/// per seam. Pure data — clone it into however many workers need it
/// and derive per-worker injectors with [`FaultPlan::injector`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Base seed of the schedule; worker and site streams fork off it.
    pub seed: u64,
    rates: [f64; FAULT_SITES],
}

impl FaultPlan {
    /// An all-quiet plan (every rate zero) under `seed`.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, rates: [0.0; FAULT_SITES] }
    }

    /// Set one site's fault probability (clamped to [0, 1]).
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> FaultPlan {
        self.rates[site.index()] = rate.clamp(0.0, 1.0);
        self
    }

    /// Set every site's fault probability at once.
    pub fn with_uniform_rate(mut self, rate: f64) -> FaultPlan {
        self.rates = [rate.clamp(0.0, 1.0); FAULT_SITES];
        self
    }

    pub fn rate(&self, site: FaultSite) -> f64 {
        self.rates[site.index()]
    }

    /// Whether any seam can fire at all.
    pub fn is_active(&self) -> bool {
        self.rates.iter().any(|&r| r > 0.0)
    }

    /// Parse a `--chaos` spec: comma-separated `key=value` pairs where
    /// `seed=N` pins the schedule seed (default 0), `rate=P` sets every
    /// site's probability, and a site key (`dispatch`, `submit`,
    /// `collect`, `panic`, `snapshot`, `restore`, `prefix`, `park`,
    /// `resume`, `decode`) overrides one seam. Later pairs win, so
    /// `rate=0.02,panic=0` means "2% everywhere except stage panics".
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new(0);
        for pair in spec.split(',') {
            let pair = pair.trim();
            if pair.is_empty() {
                continue;
            }
            let Some((key, value)) = pair.split_once('=') else {
                bail!(
                    "chaos spec pair {pair:?} is not key=value (spec \
                     {spec:?})"
                );
            };
            let (key, value) = (key.trim(), value.trim());
            if key == "seed" {
                plan.seed = value.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "chaos seed {value:?} is not an integer"
                    )
                })?;
                continue;
            }
            let rate: f64 = value.parse().map_err(|_| {
                anyhow::anyhow!(
                    "chaos rate {value:?} for {key:?} is not a number"
                )
            })?;
            if !(0.0..=1.0).contains(&rate) {
                bail!(
                    "chaos rate {rate} for {key:?} is outside [0, 1]"
                );
            }
            if key == "rate" {
                plan = plan.with_uniform_rate(rate);
            } else if let Some(site) = FaultSite::parse(key) {
                plan = plan.with_rate(site, rate);
            } else {
                bail!(
                    "unknown chaos site {key:?} (sites: seed, rate, {})",
                    FaultSite::ALL
                        .iter()
                        .map(|s| s.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        Ok(plan)
    }

    /// The canonical spec string of this plan
    /// ([`FaultPlan::parse`]-compatible; only non-zero rates appear).
    pub fn spec(&self) -> String {
        let mut parts = vec![format!("seed={}", self.seed)];
        for site in FaultSite::ALL {
            let r = self.rate(site);
            if r > 0.0 {
                parts.push(format!("{}={}", site.as_str(), r));
            }
        }
        parts.join(",")
    }

    /// Derive worker `w`'s injector. Each worker gets an independent
    /// stream family, so the pool-wide schedule is deterministic no
    /// matter how the scheduler spreads requests across workers.
    pub fn injector(&self, worker: usize) -> FaultInjector {
        let base = Rng::new(self.seed).fork(worker as u64 + 1);
        FaultInjector {
            rates: self.rates,
            streams: std::array::from_fn(|i| base.fork(i as u64 + 1)),
            draws: [0; FAULT_SITES],
        }
    }
}

/// One worker's live fault schedule: per-site RNG streams drawn once
/// per injection opportunity. Decisions at one site never consume
/// another site's stream, so schedules are stable under refactors that
/// change seam visit order.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    rates: [f64; FAULT_SITES],
    streams: [Rng; FAULT_SITES],
    draws: [u64; FAULT_SITES],
}

impl FaultInjector {
    /// Consume one injection opportunity at `site`: `true` means the
    /// seam must fail now.
    pub fn fire(&mut self, site: FaultSite) -> bool {
        let i = site.index();
        self.draws[i] += 1;
        self.rates[i] > 0.0 && self.streams[i].uniform() < self.rates[i]
    }

    /// Deterministic auxiliary pick in [0, n) from `site`'s stream
    /// (e.g. which stage a [`FaultSite::StagePanic`] kills).
    pub fn pick(&mut self, site: FaultSite, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        self.streams[site.index()].below(n)
    }

    /// Injection opportunities consumed at `site` so far.
    pub fn draws(&self, site: FaultSite) -> u64 {
        self.draws[site.index()]
    }
}

/// The typed error an injected fault at `site` synthesizes. Every
/// message contains `"injected"` (the containment tests key on it) and
/// names its seam, so [`classify_failure`] round-trips it.
pub fn injected_error(site: FaultSite) -> anyhow::Error {
    anyhow::anyhow!(
        "injected fault: {}",
        match site {
            FaultSite::FusedDispatch => "fused lane dispatch failed",
            FaultSite::SubmitWindow => {
                "window submission failed during interleaved round"
            }
            FaultSite::CollectWindow => {
                "window collect failed during interleaved round"
            }
            FaultSite::StagePanic => "stage thread killed",
            FaultSite::Snapshot => "cache snapshot failed",
            FaultSite::Restore => "cache restore failed during recovery",
            FaultSite::PrefixRestore => {
                "prefix cache restore failed during admission"
            }
            FaultSite::Park => "cache snapshot failed during park",
            FaultSite::Resume => "cache restore failed during resume",
            FaultSite::Decode => "decode step failed",
        }
    )
}

/// Attribute a request failure to the seam it came from, by the typed
/// error's wording — used for per-site `observed` accounting, which
/// must work for organic failures as well as injected ones. Failures
/// with no better attribution land in the generic
/// [`FaultSite::Decode`] bucket.
pub fn classify_failure(error: &str) -> FaultSite {
    let e = error.to_ascii_lowercase();
    if e.contains("dispatch") || e.contains("lane") {
        FaultSite::FusedDispatch
    } else if e.contains("submission") || e.contains("submit") {
        FaultSite::SubmitWindow
    } else if e.contains("collect") {
        FaultSite::CollectWindow
    } else if e.contains("stage") || e.contains("watchdog") {
        // Chain-down errors ("stage N failed", "stage chain is down",
        // watchdog timeouts) all trace back to a dead or hung stage.
        FaultSite::StagePanic
    } else if e.contains("prefix") {
        FaultSite::PrefixRestore
    } else if e.contains("park") {
        FaultSite::Park
    } else if e.contains("resume") {
        FaultSite::Resume
    } else if e.contains("restore") {
        FaultSite::Restore
    } else if e.contains("snapshot") {
        FaultSite::Snapshot
    } else {
        FaultSite::Decode
    }
}

/// Exponential backoff before recovery attempt `retry` (1-based):
/// `base * 2^(retry-1)`, capped at 1024x base so the shift cannot
/// overflow and a deep retry chain cannot stall a worker for minutes.
pub fn recovery_backoff(base: Duration, retry: u32) -> Duration {
    base * (1u32 << retry.saturating_sub(1).min(10))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn parse_spec_round_trips() {
        let plan = FaultPlan::parse(
            "seed=7,dispatch=0.05,panic=0.01,restore=0.5",
        )
        .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.rate(FaultSite::FusedDispatch), 0.05);
        assert_eq!(plan.rate(FaultSite::StagePanic), 0.01);
        assert_eq!(plan.rate(FaultSite::Restore), 0.5);
        assert_eq!(plan.rate(FaultSite::Decode), 0.0);
        assert!(plan.is_active());
        // The canonical spec re-parses to the same plan.
        assert_eq!(FaultPlan::parse(&plan.spec()).unwrap(), plan);
        // `rate=` sets every site; later pairs override.
        let plan = FaultPlan::parse("rate=0.02,panic=0").unwrap();
        for site in FaultSite::ALL {
            let want =
                if site == FaultSite::StagePanic { 0.0 } else { 0.02 };
            assert_eq!(plan.rate(site), want, "{site:?}");
        }
        // Empty and whitespace specs are the quiet plan.
        assert!(!FaultPlan::parse("").unwrap().is_active());
        assert!(!FaultPlan::parse(" seed=3 ").unwrap().is_active());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "bogus=0.1",
            "dispatch",
            "dispatch=1.5",
            "dispatch=-0.1",
            "seed=abc",
            "rate=x",
        ] {
            assert!(
                FaultPlan::parse(bad).is_err(),
                "spec {bad:?} should be rejected"
            );
        }
    }

    /// The schedule is a pure function of (seed, worker, site, draw
    /// index): two injectors from the same plan agree draw-for-draw,
    /// regardless of how draws interleave across sites.
    #[test]
    fn prop_injection_schedule_is_deterministic() {
        proptest::check("fault schedule determinism", 64, |rng| {
            let mut plan = FaultPlan::new(rng.next_u64());
            for site in FaultSite::ALL {
                plan = plan.with_rate(site, rng.uniform());
            }
            let worker = rng.below(4);
            let mut a = plan.injector(worker);
            let mut b = plan.injector(worker);
            // Replay the same per-site draw sequence through different
            // global interleavings: decisions must match anyway.
            let mut sequence: Vec<FaultSite> = (0..rng.range(10, 120))
                .map(|_| FaultSite::ALL[rng.below(FAULT_SITES)])
                .collect();
            for &site in &sequence {
                if a.fire(site) != b.clone().fire(site) {
                    // (clone keeps b's stream unconsumed for the real
                    // draw below)
                }
                let _ = b.fire(site);
            }
            // Re-derive and replay per-site: same per-site decision
            // sequence as the interleaved run.
            let mut c = plan.injector(worker);
            let mut per_site: Vec<Vec<bool>> =
                vec![Vec::new(); FAULT_SITES];
            rng.shuffle(&mut sequence);
            for &site in &sequence {
                per_site[site.index()].push(c.fire(site));
            }
            let mut d = plan.injector(worker);
            let mut replay: Vec<Vec<bool>> = vec![Vec::new(); FAULT_SITES];
            for site in FaultSite::ALL {
                for _ in 0..per_site[site.index()].len() {
                    replay[site.index()].push(d.fire(site));
                }
            }
            if per_site != replay {
                return Err(
                    "per-site decisions depend on cross-site \
                     interleaving"
                        .into(),
                );
            }
            Ok(())
        });
    }

    /// Rates are honored empirically: a site at rate r fires close to
    /// r of its opportunities; rate-0 sites never fire and rate-1
    /// sites always fire.
    #[test]
    fn prop_fire_rates_track_plan_rates() {
        proptest::check("fault rates", 32, |rng| {
            let rate = [0.0, 0.1, 0.5, 1.0][rng.below(4)];
            let plan = FaultPlan::new(rng.next_u64())
                .with_rate(FaultSite::Decode, rate);
            let mut inj = plan.injector(rng.below(3));
            let n = 4000;
            let fired =
                (0..n).filter(|_| inj.fire(FaultSite::Decode)).count();
            assert_eq!(inj.draws(FaultSite::Decode), n as u64);
            let freq = fired as f64 / n as f64;
            if rate == 0.0 && fired != 0 {
                return Err("rate-0 site fired".into());
            }
            if rate == 1.0 && fired != n {
                return Err("rate-1 site skipped".into());
            }
            if (freq - rate).abs() > 0.05 {
                return Err(format!(
                    "rate {rate}: empirical {freq} off by more than 5%"
                ));
            }
            Ok(())
        });
    }

    /// Distinct workers get distinct schedules (no lockstep faults
    /// across the pool), and the stage pick is in range.
    #[test]
    fn workers_fork_independent_schedules() {
        let plan =
            FaultPlan::new(99).with_rate(FaultSite::Decode, 0.5);
        let mut w0 = plan.injector(0);
        let mut w1 = plan.injector(1);
        let a: Vec<bool> =
            (0..256).map(|_| w0.fire(FaultSite::Decode)).collect();
        let b: Vec<bool> =
            (0..256).map(|_| w1.fire(FaultSite::Decode)).collect();
        assert_ne!(a, b, "workers share a fault schedule");
        let mut inj = plan.injector(0);
        for n in [1usize, 2, 7] {
            for _ in 0..32 {
                assert!(inj.pick(FaultSite::StagePanic, n) < n);
            }
        }
        assert_eq!(inj.pick(FaultSite::StagePanic, 0), 0);
    }

    #[test]
    fn classification_round_trips_injected_errors() {
        for site in FaultSite::ALL {
            let msg = format!("{:#}", injected_error(site));
            assert!(
                msg.contains("injected"),
                "{site:?} error lacks the injected marker: {msg}"
            );
            assert_eq!(
                classify_failure(&msg),
                site,
                "classification of {msg:?}"
            );
        }
        // Organic errors land in sensible buckets.
        assert_eq!(
            classify_failure("pipelined stage chain is down: stage 2 failed"),
            FaultSite::StagePanic
        );
        assert_eq!(
            classify_failure("park failed: cache snapshot failed during park"),
            FaultSite::Park
        );
        assert_eq!(
            classify_failure("some opaque XLA error"),
            FaultSite::Decode
        );
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let base = Duration::from_millis(2);
        assert_eq!(recovery_backoff(base, 1), base);
        assert_eq!(recovery_backoff(base, 2), base * 2);
        assert_eq!(recovery_backoff(base, 3), base * 4);
        // Deep retries cap at 1024x instead of overflowing the shift.
        assert_eq!(recovery_backoff(base, 40), base * 1024);
        // retry 0 (defensive) behaves like retry 1.
        assert_eq!(recovery_backoff(base, 0), base);
    }
}
