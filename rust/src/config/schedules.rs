//! Hyperparameter schedules.
//!
//! - [`LrSchedule`]: linear warm-up + cosine decay, the paper's Section 5.1
//!   setting (max 3e-4).
//! - [`LossWeightSchedule`]: the paper's Appendix C.1 *non-constant
//!   early-exit loss weights* — `warmup` ramps early-exit weights from 0 to
//!   their configured values (learn the backbone first), `cooldown` decays
//!   them (deep supervision as pure regularisation). The final exit's
//!   weight is always held at its configured value.

#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub max_lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub min_lr_frac: f64,
}

impl LrSchedule {
    pub fn cosine(max_lr: f64, warmup: usize, total: usize) -> LrSchedule {
        LrSchedule {
            max_lr,
            warmup_steps: warmup,
            total_steps: total.max(1),
            min_lr_frac: 0.1,
        }
    }

    pub fn constant(lr: f64) -> LrSchedule {
        LrSchedule { max_lr: lr, warmup_steps: 0, total_steps: 1, min_lr_frac: 1.0 }
    }

    /// Learning rate at 0-based step `t`.
    pub fn at(&self, t: usize) -> f64 {
        if self.warmup_steps > 0 && t < self.warmup_steps {
            return self.max_lr * (t + 1) as f64 / self.warmup_steps as f64;
        }
        let span = self.total_steps.saturating_sub(self.warmup_steps).max(1);
        let p = ((t - self.warmup_steps.min(t)) as f64 / span as f64).min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * p).cos());
        let lo = self.max_lr * self.min_lr_frac;
        lo + (self.max_lr - lo) * cos
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum LossWeightSchedule {
    Constant,
    /// Ramp early-exit weights 0 -> configured over the first `ramp` steps.
    Warmup { ramp: usize },
    /// Decay early-exit weights configured -> `floor_frac`*configured over
    /// the whole run.
    Cooldown { floor_frac: f64 },
}

impl LossWeightSchedule {
    pub fn parse(s: &str, total_steps: usize) -> LossWeightSchedule {
        match s {
            "constant" => LossWeightSchedule::Constant,
            "warmup" => LossWeightSchedule::Warmup {
                ramp: (total_steps / 4).max(1),
            },
            "cooldown" => LossWeightSchedule::Cooldown { floor_frac: 0.1 },
            other => {
                if let Some(r) = other.strip_prefix("warmup:") {
                    LossWeightSchedule::Warmup {
                        ramp: r.parse().expect("warmup:<steps>"),
                    }
                } else if let Some(f) = other.strip_prefix("cooldown:") {
                    LossWeightSchedule::Cooldown {
                        floor_frac: f.parse().expect("cooldown:<frac>"),
                    }
                } else {
                    panic!("unknown loss-weight schedule {other:?}")
                }
            }
        }
    }

    /// Multiplier applied to *early* exit weights at step `t` (the final
    /// exit always keeps multiplier 1).
    pub fn multiplier(&self, t: usize, total_steps: usize) -> f32 {
        match self {
            LossWeightSchedule::Constant => 1.0,
            LossWeightSchedule::Warmup { ramp } => {
                ((t as f64 + 1.0) / *ramp as f64).min(1.0) as f32
            }
            LossWeightSchedule::Cooldown { floor_frac } => {
                let p = (t as f64 / total_steps.max(1) as f64).min(1.0);
                (1.0 - (1.0 - floor_frac) * p) as f32
            }
        }
    }

    /// Effective weights at step `t` given configured defaults; entry i is
    /// marked final via `is_final[i]`.
    pub fn weights_at(
        &self,
        t: usize,
        total_steps: usize,
        defaults: &[f32],
        is_final: &[bool],
    ) -> Vec<f32> {
        let m = self.multiplier(t, total_steps);
        defaults
            .iter()
            .zip(is_final)
            .map(|(&w, &f)| if f { w } else { w * m })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_warms_up_then_decays() {
        let s = LrSchedule::cosine(1.0, 10, 100);
        assert!(s.at(0) < s.at(9));
        assert!((s.at(9) - 1.0).abs() < 1e-9);
        assert!(s.at(50) < s.at(10));
        assert!(s.at(99) >= s.max_lr * s.min_lr_frac - 1e-9);
    }

    #[test]
    fn lr_is_monotone_decreasing_after_warmup() {
        let s = LrSchedule::cosine(3e-4, 5, 50);
        for t in 5..49 {
            assert!(s.at(t + 1) <= s.at(t) + 1e-12, "t={t}");
        }
    }

    #[test]
    fn warmup_schedule_ramps_early_exits_only() {
        let sch = LossWeightSchedule::Warmup { ramp: 10 };
        let w0 = sch.weights_at(0, 100, &[0.5, 1.0], &[false, true]);
        assert!(w0[0] < 0.06 && (w0[1] - 1.0).abs() < 1e-6);
        let w10 = sch.weights_at(9, 100, &[0.5, 1.0], &[false, true]);
        assert!((w10[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn cooldown_decays_to_floor() {
        let sch = LossWeightSchedule::Cooldown { floor_frac: 0.1 };
        let w = sch.weights_at(100, 100, &[0.5, 1.0], &[false, true]);
        assert!((w[0] - 0.05).abs() < 1e-6);
        assert!((w[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn parse_variants() {
        assert_eq!(
            LossWeightSchedule::parse("warmup:7", 100),
            LossWeightSchedule::Warmup { ramp: 7 }
        );
        assert_eq!(
            LossWeightSchedule::parse("constant", 10),
            LossWeightSchedule::Constant
        );
    }
}
