//! Run configuration: everything the launcher needs beyond the model
//! manifest — training hyperparameters (schedules included), inference
//! settings, and paths. Built from CLI args (util::cli); the model
//! architecture itself comes from `artifacts/<config>/manifest.json`.

use std::path::PathBuf;

use crate::inference::ExitPolicy;
use crate::util::cli::Args;

pub mod schedules;

pub use schedules::{LossWeightSchedule, LrSchedule};

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact config name (e.g. "ee-tiny", "ee-e2e").
    pub config: String,
    pub artifacts_dir: PathBuf,
    pub seed: u64,
    pub steps: usize,
    /// Microbatches per global batch (M in the paper's 1F1B notation).
    pub microbatches: usize,
    pub lr: LrSchedule,
    /// Global gradient-norm clip (0 disables).
    pub grad_clip: f64,
    /// Early-exit loss weight schedule (Appendix C.1).
    pub loss_weights: LossWeightSchedule,
    /// Fill explicit pipeline bubbles with partial microbatches
    /// (Appendix C.2). The value is K, the number of truncated-backward
    /// microbatches per iteration (0 disables).
    pub bubble_fill: usize,
    /// Estimated backward/forward time ratio used by the bubble-fill
    /// planner (the paper exposes the same knob).
    pub bf_ratio: f64,
    pub log_every: usize,
    pub eval_every: usize,
    pub checkpoint: Option<PathBuf>,
    pub resume: Option<PathBuf>,
    /// Emit loss curves as CSV here.
    pub curve_out: Option<PathBuf>,
}

impl TrainConfig {
    pub fn from_args(a: &Args) -> TrainConfig {
        TrainConfig {
            config: a.get_or("config", "ee-tiny"),
            artifacts_dir: PathBuf::from(a.get_or("artifacts", "artifacts")),
            seed: a.usize_or("seed", 42) as u64,
            steps: a.usize_or("steps", 100),
            microbatches: a.usize_or("microbatches", 8),
            lr: LrSchedule::cosine(
                a.f64_or("lr", 3e-4),
                a.usize_or("warmup", 20),
                a.usize_or("steps", 100),
            ),
            grad_clip: a.f64_or("grad-clip", 1.0),
            loss_weights: LossWeightSchedule::parse(
                &a.get_or("loss-weight-schedule", "constant"),
                a.usize_or("steps", 100),
            ),
            bubble_fill: a.usize_or("bubble-fill", 0),
            bf_ratio: a.f64_or("bf-ratio", 2.0),
            log_every: a.usize_or("log-every", 10),
            eval_every: a.usize_or("eval-every", 0),
            checkpoint: a.get("checkpoint").map(PathBuf::from),
            resume: a.get("resume").map(PathBuf::from),
            curve_out: a.get("curve-out").map(PathBuf::from),
        }
    }
}

#[derive(Debug, Clone)]
pub struct InferenceConfig {
    pub config: String,
    pub artifacts_dir: PathBuf,
    /// Exit-decision policy ([`ExitPolicy`]). Parsed from `--policy
    /// <spec>`; `--threshold F` is sugar for `--policy confidence:F`
    /// (1.0 disables early exits — the full-model baseline, the paper's
    /// speedup denominator).
    pub policy: ExitPolicy,
    pub max_new_tokens: usize,
    /// KV-recomputation deficit cap (forces a full pass when reached).
    pub recompute_cap: usize,
    pub checkpoint: Option<PathBuf>,
    pub seed: u64,
}

impl InferenceConfig {
    pub fn from_args(a: &Args) -> anyhow::Result<InferenceConfig> {
        let policy = ExitPolicy::from_args(a, 0.8)?;
        Ok(InferenceConfig {
            config: a.get_or("config", "ee-tiny"),
            artifacts_dir: PathBuf::from(a.get_or("artifacts", "artifacts")),
            policy,
            max_new_tokens: a.usize_or("max-new-tokens", 32),
            recompute_cap: a.usize_or("recompute-cap", 4),
            checkpoint: a.get("checkpoint").map(PathBuf::from),
            seed: a.usize_or("seed", 42) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    #[test]
    fn inference_config_policy_spec_and_threshold_sugar() {
        let parse = |argv: &[&str]| {
            let argv: Vec<String> =
                argv.iter().map(|s| s.to_string()).collect();
            InferenceConfig::from_args(&Args::parse(&argv, &[]))
        };
        // Default: the old 0.8 confidence threshold.
        assert_eq!(parse(&[]).unwrap().policy, ExitPolicy::confidence(0.8));
        // --threshold is sugar for confidence.
        assert_eq!(
            parse(&["--threshold", "0.5"]).unwrap().policy,
            ExitPolicy::confidence(0.5)
        );
        // --policy takes the full spec grammar and wins over --threshold.
        assert_eq!(
            parse(&["--threshold", "0.5", "--policy", "entropy:1.5"])
                .unwrap()
                .policy,
            ExitPolicy::Entropy { max_nats: 1.5 }
        );
        assert!(parse(&["--policy", "bogus:1"]).is_err());
    }

    #[test]
    fn train_config_defaults_and_overrides() {
        let argv: Vec<String> =
            ["--config", "ee-small", "--steps", "7", "--lr", "0.01"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let a = Args::parse(&argv, &[]);
        let c = TrainConfig::from_args(&a);
        assert_eq!(c.config, "ee-small");
        assert_eq!(c.steps, 7);
        assert!(c.grad_clip > 0.0);
    }
}
