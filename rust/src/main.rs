//! `ee-llm` — launcher for the EE-LLM reproduction.
//!
//! Subcommands:
//!   train       pipeline-parallel 1F1B training with early-exit losses
//!   generate    early-exit text generation (recompute | pipelined | full)
//!   eval        run the Figure-8 task suite against a checkpoint
//!   serve-bench multi-request serving throughput/latency vs pool size
//!   simulate    pipeline-schedule simulation (Figure 3/7/9, Table 1)
//!   probe       per-exit confidence table for a prompt (Table 4)
//!
//! Run `ee-llm help` for flags.

use anyhow::{bail, ensure, Context, Result};

use eellm::config::{InferenceConfig, TrainConfig};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{
    bursty_traffic, conversation_traffic, shared_prefix_prompts, ConvoSpec,
    ConvoTurn, Corpus, CorpusSpec, SharedPrefixSpec, TrafficSpec,
};
use eellm::data::tasks;
use eellm::eval::harness::evaluate_task;
use eellm::inference::{
    ExitPolicy, ModelState, PipelinedEngine, SequentialEngine,
};
use eellm::metrics::CurveWriter;
use eellm::runtime::artifacts::Manifest;
use eellm::schedule::costs::{CostModel, PAPER_MODELS};
use eellm::schedule::plan::{EeOptions, Plan};
use eellm::schedule::report::render_timeline;
use eellm::schedule::sim::Simulator;
use eellm::serve::{
    requests_from_tasks, ControlConfig, EngineKind, EnginePool, FaultPlan,
    FaultSite, HealConfig, Policy, PoolConfig, ServeMetrics, ServeRequest,
    ShedPolicy,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};
use eellm::util::cli::Args;
use eellm::util::json::Json;
use eellm::util::table::Table;

const USAGE: &str = "\
ee-llm: large-scale training and inference of early-exit LLMs (reproduction)

USAGE: ee-llm <train|generate|eval|serve-bench|simulate|probe> [--flags]

COMMON FLAGS
  --config <name>        artifact config (default ee-tiny)
  --artifacts <dir>      artifacts root (default artifacts)
  --seed <n>             RNG seed (default 42)

train:     --steps N --microbatches M --lr F --grad-clip F
           --loss-weight-schedule constant|warmup[:N]|cooldown[:F]
           --bubble-fill K --bf-ratio F --checkpoint PATH --resume PATH
           --curve-out PATH --log-every N --eval-every N
generate:  --prompt STR --engine recompute|pipelined|full --policy SPEC
           --max-new-tokens N --checkpoint PATH
eval:      --policy SPEC --checkpoint PATH --examples-per-task N
serve-bench: --requests N --pool-sizes 1,2,4 --engine recompute|pipelined
           --sched fifo|spf|priority (queue scheduling) --concurrent N
           (live sessions per worker, continuous batching) --policy SPEC
           --checkpoint PATH
           --prefix-cache POSITIONS (pool-wide shared-prefix KV-cache
           budget, one store shared by all workers; as a bare trailing
           flag the budget defaults to 8 * max_seq, but mid-line it must
           carry a value)
           --workload tasks|shared-prefix|bursty|convo (request set;
           defaults to shared-prefix when the prefix cache is on, tasks
           otherwise; bursty = diurnal multi-tenant deadline traffic;
           convo = multi-turn chat: --requests conversations x --turns
           turns served round-by-round with end-of-turn KV snapshots,
           reported warm vs cold)
           --turns N (convo workload: turns per conversation, default 3)
           --device-tier POSITIONS (pinned device-resident tier of the
           snapshot store: entries hit twice are promoted and stay on
           device within the budget; default 0 = host-only)
           --convo-ttl-ms N (expire conversations idle this long and
           release their stored history, default 300000)
           --preempt (SLO control plane: a full worker parks its
           lowest-value live session to admit a queued request about to
           blow its deadline; parked sessions resume when a slot frees)
           --park-capacity N (pool-wide bound on parked session
           snapshots, default 2)
           --preempt-horizon-ms N (a queued deadline within this window
           counts as urgent, default 25)
           --shed DEPTH (admission control: shed incoming requests while
           the queue holds at least DEPTH)
           --shed-ttft-ms N (also shed when predicted TTFT — queue
           depth x the observed service-time EMA — exceeds N ms)
           --tenants W1,W2,... (weighted fair dispatch: requests tagged
           tenant i get share W_i of service; the bursty workload draws
           tenant traffic with the same weights)
           --no-lanes (disable lane-fused batched decode; by default
           same-policy live sessions are stepped through the manifest's
           decode_lanes executables, one batched XLA call per stage)
           --no-resident (keep lane fusion but drop device residency:
           every fused step pays the per-stage cache gather/scatter
           round-trip instead of stepping a device-resident lane group)
           --chaos SPEC (deterministic fault injection: a seeded
           per-worker schedule firing at every serving seam; SPEC is
           seed[:rate] or seed:site=rate,site=rate with sites
           fused-dispatch|submit-window|collect-window|stage-panic|
           snapshot|restore|prefix-restore|park|resume|decode;
           enables recovery with 3 retries unless --heal-retries says
           otherwise)
           --checkpoint-interval N (decode-time micro-checkpoints: live
           sessions snapshot every N generated tokens so recovery
           re-decodes only the tail; default 4 under --chaos, else 0)
           --checkpoint-capacity N (bound on stored micro-checkpoints
           pool-wide, default 8)
           --heal-retries N (recovery re-admissions per request before
           giving up; 0 disables self-healing, default 3 under --chaos)
           --json-out PATH (metrics JSON)
simulate:  --model 1.3B|7B|13B|30B --pp N --tp N --microbatches M
           --exits s0,s1,... --no-defer --gpipe --fill K
probe:     --prompt STR --checkpoint PATH --max-new-tokens N
           --calibrate TARGET (fit a per-layer exit policy from the probe
           at the given final-exit agreement rate; prints a --policy spec)

EXIT POLICY SPECS (--policy; --threshold F stays as sugar for
confidence:F):
  never               full-model baseline (no early exits)
  confidence:0.8      the paper's rule: exit iff max prob >= 0.8
                      (a bare float means the same; 1.0 = baseline)
  per-layer:2=0.7,4=0.9   per-exit-layer confidence thresholds
  margin:0.3          exit iff top-1/top-2 probability gap >= 0.3
  entropy:1.5         exit iff softmax entropy <= 1.5 nats
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        println!("{USAGE}");
        return;
    }
    let cmd = argv[0].clone();
    let args =
        Args::parse(
            &argv[1..],
            &[
                "no-defer", "gpipe", "verbose", "no-lanes",
                "no-resident", "preempt",
            ],
        );
    let result = match cmd.as_str() {
        "train" => cmd_train(&args),
        "generate" => cmd_generate(&args),
        "eval" => cmd_eval(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "simulate" => cmd_simulate(&args),
        "probe" => cmd_probe(&args),
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_manifest(cfg_name: &str, artifacts: &std::path::Path) -> Result<Manifest> {
    Manifest::load_config(artifacts, cfg_name).with_context(|| {
        format!(
            "loading {cfg_name:?} from {} (run `make artifacts`)",
            artifacts.display()
        )
    })
}

/// The synthetic world shared by train, eval, and serve-bench — one spec
/// so their corpora (and thus results) stay comparable.
fn standard_corpus(seed: u64) -> Corpus {
    Corpus::build(&CorpusSpec {
        seed,
        n_entities: 24,
        target_bytes: 1 << 21,
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args);
    let man = load_manifest(&cfg.config, &cfg.artifacts_dir)?;
    println!(
        "[train] {} (~{} params, P={}), {} steps x {} microbatches",
        man.name,
        man.approx_param_count,
        man.model.pipeline_stages,
        cfg.steps,
        cfg.microbatches
    );

    let corpus = standard_corpus(cfg.seed);
    let mut ds = Dataset::from_corpus(
        &corpus,
        man.model.seq,
        man.model.microbatch,
        cfg.seed,
    );
    println!("[train] corpus: {} examples of seq {}", ds.n_examples(), ds.seq);

    let mut trainer = PipelineTrainer::new(
        man,
        TrainerOptions {
            seed: cfg.seed,
            lr: cfg.lr.clone(),
            grad_clip: cfg.grad_clip,
            loss_weights: cfg.loss_weights.clone(),
            total_steps: cfg.steps,
            bubble_fill: cfg.bubble_fill,
            bf_ratio: cfg.bf_ratio,
        },
    )?;
    if let Some(resume) = &cfg.resume {
        trainer.load_checkpoint(resume)?;
        println!("[train] resumed from {}", resume.display());
    }

    let names = trainer.exit_names();
    let mut curve = cfg.curve_out.as_ref().map(|p| {
        let mut hdr = vec!["step".to_string(), "lr".to_string()];
        hdr.extend(names.iter().cloned());
        CurveWriter::new(p, &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>())
    });

    let val = ds.validation_batches(4);
    for step in 0..cfg.steps {
        let batches: Vec<TrainBatch> =
            (0..cfg.microbatches).map(|_| ds.next_microbatch()).collect();
        let fills: Vec<TrainBatch> =
            (0..cfg.bubble_fill).map(|_| ds.next_microbatch()).collect();
        let stats = trainer.train_step(&batches, &fills)?;
        if let Some(c) = &mut curve {
            let mut row = vec![stats.step as f64, stats.lr];
            row.extend(stats.losses.iter());
            c.push(row);
        }
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            let ls: Vec<String> = names
                .iter()
                .zip(&stats.losses)
                .map(|(n, l)| format!("{n}={l:.4}"))
                .collect();
            println!(
                "step {:>5} | {} | gnorm {:.3} | lr {:.2e} | {:.2}s",
                stats.step,
                ls.join(" "),
                stats.grad_norm,
                stats.lr,
                stats.wall_seconds
            );
        }
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let v = trainer.validate(&val)?;
            let ls: Vec<String> = names
                .iter()
                .zip(&v)
                .map(|(n, l)| format!("{n}={l:.4}"))
                .collect();
            println!("  [val] {}", ls.join(" "));
        }
    }
    if let Some(c) = &curve {
        c.flush()?;
        println!("[train] loss curve written to {:?}", cfg.curve_out);
    }
    if let Some(ckpt) = &cfg.checkpoint {
        trainer.save_checkpoint(ckpt)?;
        println!("[train] checkpoint saved to {}", ckpt.display());
    }
    trainer.shutdown();
    Ok(())
}

fn model_state(args: &Args) -> Result<ModelState> {
    let icfg = InferenceConfig::from_args(args)?;
    let man = load_manifest(&icfg.config, &icfg.artifacts_dir)?;
    match &icfg.checkpoint {
        Some(p) => ModelState::from_checkpoint(man, p),
        None => {
            eprintln!(
                "[warn] no --checkpoint given; using random weights (seed {})",
                icfg.seed
            );
            Ok(ModelState::init(man, icfg.seed))
        }
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let icfg = InferenceConfig::from_args(args)?;
    let prompt = args.get_or("prompt", "the capital of ");
    let engine = args.get_or("engine", "recompute");
    let state = model_state(args)?;
    let n_layers = state.man.model.n_layers;
    let out = match engine.as_str() {
        "recompute" | "full" => {
            let policy = if engine == "full" {
                ExitPolicy::Never
            } else {
                icfg.policy.clone()
            };
            let mut eng = SequentialEngine::new(state, policy)?;
            eng.generate_text(&prompt, icfg.max_new_tokens)?
        }
        "pipelined" => {
            let mut eng = PipelinedEngine::new(state, icfg.policy.clone())?;
            let out = eng.generate_text(&prompt, icfg.max_new_tokens)?;
            eng.shutdown();
            out
        }
        other => bail!("unknown engine {other:?}"),
    };
    println!("prompt:    {prompt:?}");
    println!("generated: {:?}", out.text);
    println!(
        "tokens: {} | {:.3}s | {:.1} tok/s | early-exit fraction {:.1}%",
        out.tokens.len(),
        out.seconds,
        out.tokens.len() as f64 / out.seconds.max(1e-9),
        100.0 * out.stats.early_fraction(n_layers)
    );
    println!("exit histogram: {:?}", out.stats.counts);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let icfg = InferenceConfig::from_args(args)?;
    let n_per = args.usize_or("examples-per-task", 20);
    let state = model_state(args)?;
    let corpus = standard_corpus(icfg.seed);
    let suite = tasks::all_tasks(&corpus, n_per, icfg.seed);
    let mut eng = SequentialEngine::new(state, icfg.policy.clone())?;
    let mut table = Table::new(
        &format!("Task scores under exit policy {}", icfg.policy),
        &["task", "metric", "score", "mean latency"],
    );
    for task in &suite {
        let score = evaluate_task(task, &mut eng);
        table.row(vec![
            score.task.to_string(),
            format!("{:?}", score.metric),
            format!("{:.3}", score.score),
            format!("{:.1}ms", score.mean_seconds * 1e3),
        ]);
    }
    table.emit("eval");
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    // `--policy` used to be the *scheduling* policy; it now takes an
    // exit-policy spec. Catch the old spelling with a pointer at --sched
    // before the spec parser produces a less helpful error.
    if let Some(p) = args.get("policy") {
        if Policy::parse(p).is_ok() {
            bail!(
                "--policy now takes an exit-policy spec (e.g. \
                 confidence:0.8); the queue scheduling policy moved to \
                 --sched {p}"
            );
        }
    }
    let icfg = InferenceConfig::from_args(args)?;
    let n_req = args.usize_or("requests", 16);
    let pool_sizes: Vec<usize> = args
        .get_or("pool-sizes", "1,2,4")
        .split(',')
        .map(|s| s.trim().parse::<usize>().context("bad --pool-sizes"))
        .collect::<Result<_>>()?;
    let sched = Policy::parse(&args.get_or("sched", "fifo"))?;
    let kind = EngineKind::parse(&args.get_or("engine", "recompute"))?;
    let concurrent = args.usize_or("concurrent", 4);
    let state = model_state(args)?;
    let n_layers = state.man.model.n_layers;
    let max_seq = state.man.model.max_seq;
    // `--prefix-cache` takes a pool-wide position budget (one store
    // shared by all workers); passed as a bare trailing flag it gets a
    // generous default.
    let prefix_positions = match args.get("prefix-cache") {
        Some(v) => v
            .parse::<usize>()
            .context("--prefix-cache wants a position budget")?,
        None if args.flag("prefix-cache") => 8 * max_seq,
        None => 0,
    };
    // Workload and cache budget are orthogonal: the default workload
    // follows the cache flag (shared prefixes are what the cache is
    // for), but --workload lets a cache-off run decode the *same*
    // shared-prefix request set, so on-vs-off deltas are attributable.
    let workload = args.get_or(
        "workload",
        if prefix_positions > 0 { "shared-prefix" } else { "tasks" },
    );
    // Tiered snapshot store: positions the device-resident tier may pin.
    let device_tier = args.usize_or("device-tier", 0);
    let convo_ttl_ms = args.usize_or("convo-ttl-ms", 300_000) as u64;
    let lane_fusion = !args.flag("no-lanes");
    // `--no-resident` keeps lane fusion but drops device residency:
    // every fused step pays the per-stage gather/scatter round-trip
    // (the PR-5 baseline the resident path is judged against).
    let lane_residency = !args.flag("no-resident");
    // Self-healing serving: a pinned-seed chaos schedule plus
    // micro-checkpoint recovery. `--chaos` alone turns recovery on
    // (faults without healing would just fail the batch), while
    // explicit `--heal-retries 0` keeps injected faults terminal.
    let chaos = match args.get("chaos") {
        Some(spec) => Some(FaultPlan::parse(spec)?),
        None => None,
    };
    let checkpoint_interval = match args.get("checkpoint-interval") {
        Some(v) => v
            .parse::<usize>()
            .context("--checkpoint-interval wants a token count")?,
        None if chaos.is_some() => 4,
        None => 0,
    };
    let heal_retries = match args.get("heal-retries") {
        Some(v) => {
            v.parse::<u32>().context("--heal-retries wants a count")?
        }
        None if chaos.is_some() => 3,
        None => 0,
    };
    let heal = HealConfig {
        checkpoint_interval,
        checkpoint_capacity: args.usize_or("checkpoint-capacity", 8),
        max_retries: heal_retries,
        chaos: chaos.clone(),
        ..HealConfig::default()
    };
    // SLO control plane: deadline-driven preemption, admission control
    // / load shedding, weighted tenant fairness.
    let preempt = args.flag("preempt");
    let park_capacity = args.usize_or("park-capacity", 2);
    let horizon_ms = args.usize_or("preempt-horizon-ms", 25);
    let shed_depth = match args.get("shed") {
        Some(v) => Some(
            v.parse::<usize>().context("--shed wants a queue depth")?,
        ),
        None => None,
    };
    let shed_ttft_ms = match args.get("shed-ttft-ms") {
        Some(v) => Some(
            v.parse::<u64>().context("--shed-ttft-ms wants milliseconds")?,
        ),
        None => None,
    };
    let shed = if shed_depth.is_some() || shed_ttft_ms.is_some() {
        Some(ShedPolicy {
            max_queue_depth: shed_depth.unwrap_or(0),
            max_predicted_ttft: shed_ttft_ms
                .map(std::time::Duration::from_millis),
            ..ShedPolicy::default()
        })
    } else {
        None
    };
    let mut tenant_weights: Vec<f64> = match args.get("tenants") {
        Some(v) => v
            .split(',')
            .map(|s| s.trim().parse::<f64>().context("bad --tenants"))
            .collect::<Result<_>>()?,
        None => Vec::new(),
    };
    // The bursty and convo workloads are multi-tenant by construction;
    // give them the default 3:1 split when --tenants is not spelled out
    // so fairness accounting has something to do.
    if tenant_weights.is_empty()
        && (workload == "bursty" || workload == "convo")
    {
        tenant_weights = vec![3.0, 1.0];
    }
    let corpus = standard_corpus(icfg.seed);
    if workload == "convo" {
        // Multi-turn conversations need their own driver: turn N+1's
        // prompt embeds turn N's actual response, so each round is one
        // batch over a pool whose snapshot store persists between them.
        return cmd_serve_bench_convo(
            args,
            &icfg,
            state,
            &corpus,
            ConvoBenchOpts {
                n_conversations: n_req.max(1),
                turns: args.usize_or("turns", 3),
                pool_sizes,
                prefix_positions,
                device_tier,
                convo_ttl_ms,
                lane_fusion,
                lane_residency,
                tenant_weights,
                engine: kind,
                sched,
                concurrent,
            },
        );
    }
    let reqs = match workload.as_str() {
        "shared-prefix" => {
            // Shared-system-prompt workload: the templated traffic
            // shape prefix KV reuse exists for.
            let n_groups = 3.min(n_req.max(1));
            let spec = SharedPrefixSpec {
                seed: icfg.seed,
                n_groups,
                requests_per_group: n_req.div_ceil(n_groups),
                prefix_bytes: max_seq / 2,
            };
            shared_prefix_prompts(&spec, &corpus.facts)
                .into_iter()
                .take(n_req)
                .enumerate()
                .map(|(i, p)| ServeRequest::new(i as u64, p, 8))
                .collect()
        }
        "tasks" => {
            let suite = tasks::all_tasks(&corpus, n_req, icfg.seed);
            requests_from_tasks(&suite, n_req, max_seq)
        }
        "bursty" => {
            // Bursty, diurnal, multi-tenant deadline traffic: the
            // workload the SLO control plane is judged against.
            let spec = TrafficSpec {
                seed: icfg.seed,
                n_requests: n_req,
                tenants: tenant_weights.clone(),
                prompt_bytes: (32, (max_seq / 2).max(48)),
                ..TrafficSpec::default()
            };
            bursty_traffic(&spec, &corpus.facts)
                .into_iter()
                .enumerate()
                .map(|(i, t)| {
                    let mut r =
                        ServeRequest::new(i as u64, t.prompt, t.max_new)
                            .with_priority(t.priority)
                            .with_tenant(t.tenant);
                    if let Some(ms) = t.deadline_ms {
                        r = r.with_deadline(
                            std::time::Duration::from_millis(ms),
                        );
                    }
                    r
                })
                .collect()
        }
        other => {
            bail!(
                "unknown --workload {other:?} \
                 (tasks|shared-prefix|bursty|convo)"
            )
        }
    };
    println!(
        "[serve-bench] {n_req} requests ({workload} workload), engine \
         {kind:?}, sched {sched:?}, exit policy {}, {concurrent} live \
         sessions/worker, prefix cache {}, lane fusion {}, lane \
         residency {}",
        icfg.policy,
        if prefix_positions > 0 {
            format!("{prefix_positions} positions (pool-wide shared store)")
        } else {
            "off".to_string()
        },
        if lane_fusion { "on" } else { "off" },
        if lane_residency { "on" } else { "off (round-trip)" }
    );
    if preempt || shed.is_some() || !tenant_weights.is_empty() {
        println!(
            "[serve-bench] control plane: preempt {} (horizon \
             {horizon_ms} ms, park capacity {park_capacity}), shed \
             {}, tenant weights {tenant_weights:?}",
            if preempt { "on" } else { "off" },
            match &shed {
                Some(s) => format!(
                    "depth>={} ttft<={:?}",
                    s.max_queue_depth, s.max_predicted_ttft
                ),
                None => "off".to_string(),
            }
        );
    }
    if heal.enabled() || heal.chaos.is_some() {
        println!(
            "[serve-bench] self-healing: chaos {}, micro-checkpoint \
             every {} tokens (capacity {}), {} retries, backoff {:?}, \
             quarantine after {} flaps",
            heal.chaos
                .as_ref()
                .map(|p| p.spec())
                .unwrap_or_else(|| "off".to_string()),
            heal.checkpoint_interval,
            heal.checkpoint_capacity,
            heal.max_retries,
            heal.backoff,
            heal.quarantine_after
        );
    }
    let mut table = Table::new(
        &format!(
            "Serving throughput under exit policy {} ({sched:?})",
            icfg.policy
        ),
        &["pool", "requests", "tok/s", "p50 latency", "p95 latency",
          "p50 TTFT", "p95 TTFT", "p50 tok gap", "mean queue", "early%"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    for &workers in &pool_sizes {
        let mut pool = EnginePool::new(
            state.clone(),
            PoolConfig {
                workers,
                engine: kind,
                policy: icfg.policy.clone(),
                sched,
                max_concurrent: concurrent,
                prefix_cache_positions: prefix_positions,
                device_tier_positions: device_tier,
                convo_idle_ttl: std::time::Duration::from_millis(
                    convo_ttl_ms,
                ),
                lane_fusion,
                lane_residency,
                control: ControlConfig {
                    preempt,
                    preempt_horizon: std::time::Duration::from_millis(
                        horizon_ms as u64,
                    ),
                    park_capacity,
                    shed: shed.clone(),
                    tenant_weights: tenant_weights.clone(),
                    fault: None,
                    heal: heal.clone(),
                },
            },
        );
        let out = pool.run_batch(reqs.clone())?;
        pool.shutdown()?;
        for f in &out.failures {
            eprintln!("[serve-bench] {f}");
        }
        for s in &out.sheds {
            eprintln!(
                "[serve-bench] request {} (tenant {}) shed: {}",
                s.id, s.tenant, s.reason
            );
        }
        let m = &out.metrics;
        table.row(vec![
            format!("{workers}"),
            format!("{}", m.requests),
            format!("{:.1}", m.throughput_tps()),
            format!("{:.0}ms", m.p50_latency_seconds * 1e3),
            format!("{:.0}ms", m.p95_latency_seconds * 1e3),
            format!("{:.0}ms", m.p50_ttft_seconds * 1e3),
            format!("{:.0}ms", m.p95_ttft_seconds * 1e3),
            format!("{:.1}ms", m.p50_token_gap_seconds * 1e3),
            format!("{:.0}ms", m.mean_queue_seconds * 1e3),
            format!("{:.0}%", 100.0 * m.early_fraction(n_layers)),
        ]);
        if prefix_positions > 0 {
            let p = &m.prefix;
            println!(
                "[serve-bench] pool {workers}: prefix hit rate {:.0}% \
                 ({}/{} lookups), prefill positions saved {}, \
                 {} insertions, {} evictions",
                100.0 * p.hit_rate(),
                p.hits,
                p.lookups(),
                p.saved_positions,
                p.insertions,
                p.evictions
            );
        }
        if device_tier > 0 {
            let t = &m.tier;
            println!(
                "[serve-bench] pool {workers}: device tier {:.0}% of \
                 hits on device ({} device / {} host), {} promotions, \
                 {} demotions",
                100.0 * t.device_hit_rate(),
                t.device_hits,
                t.host_hits,
                t.promotions,
                t.demotions
            );
        }
        if prefix_positions > 0 || preempt {
            let sm = &m.snapshot_memory;
            println!(
                "[serve-bench] pool {workers}: snapshot memory {} \
                 cached ({} pos, {} KiB) + {} device-pinned ({} pos, \
                 {} KiB) + {} parked ({} KiB) = {} KiB",
                sm.cached_entries,
                sm.cached_positions,
                sm.cached_bytes / 1024,
                sm.device_entries,
                sm.device_positions,
                sm.device_bytes / 1024,
                sm.parked_entries,
                sm.parked_bytes / 1024,
                sm.total_bytes() / 1024
            );
        }
        if m.deadline_misses > 0 {
            println!(
                "[serve-bench] pool {workers}: {} deadline misses \
                 ({:.0}% of {} deadlined)",
                m.deadline_misses,
                100.0 * m.deadline_miss_rate(),
                m.deadlined
            );
        }
        let f = &m.faults;
        if f.injected_total() + f.observed_total() + f.checkpoints > 0 {
            println!(
                "[serve-bench] pool {workers}: {} faults injected / {} \
                 observed, {} checkpoints ({} refused), {} recovery \
                 attempts, {} recovered / {} failed, {} tokens \
                 re-decoded, {} engine restarts, {} quarantined",
                f.injected_total(),
                f.observed_total(),
                f.checkpoints,
                f.checkpoint_failures,
                f.retries,
                f.recoveries,
                f.recovery_failures,
                f.redecoded_tokens,
                f.restarts,
                f.quarantines
            );
        }
        let s = &m.slo;
        if s.preemptions + s.resumes + s.shed + s.degraded > 0 {
            println!(
                "[serve-bench] pool {workers}: {} preemptions / {} \
                 resumes (parked peak {}, {} park faults, {} resume \
                 faults), {} shed, {} degraded",
                s.preemptions,
                s.resumes,
                s.parked_peak,
                s.park_failures,
                s.resume_failures,
                s.shed,
                s.degraded
            );
        }
        for t in &m.tenants {
            println!(
                "[serve-bench] pool {workers}: tenant {} served {} \
                 requests, {} tokens ({:.0}% share)",
                t.tenant,
                t.requests,
                t.tokens,
                100.0 * t.share
            );
        }
        if lane_fusion {
            let l = &m.lanes;
            println!(
                "[serve-bench] pool {workers}: {:.2} decode steps/dispatch \
                 ({} fused calls x occupancy {:?}, {} solo steps, {} stages \
                 skipped all-fired, {} policy swaps)",
                l.steps_per_dispatch(),
                l.fused_calls,
                l.occupancy,
                l.solo_steps,
                l.stages_skipped,
                l.policy_applies
            );
            println!(
                "[serve-bench] pool {workers}: lane-cache traffic {} \
                 gathers ({} KiB) / {} scatters ({} KiB), {} warm group \
                 hits, {} cold forms",
                l.cache_gathers,
                l.cache_gather_bytes / 1024,
                l.cache_scatters,
                l.cache_scatter_bytes / 1024,
                l.warm_group_hits,
                l.cold_group_forms
            );
        }
        if m.interleave.rounds > 0 {
            let il = &m.interleave;
            println!(
                "[serve-bench] pool {workers}: {:.2} mean sessions in \
                 flight per interleaved round ({} rounds x occupancy \
                 {:?}, max {} in flight)",
                il.mean_in_flight(),
                il.rounds,
                il.occupancy,
                il.max_in_flight()
            );
        }
        json_rows.push(serve_metrics_json(workers, m, n_layers));
    }
    table.emit("serve-bench");
    if let Some(path) = args.get("json-out") {
        let mut obj = std::collections::BTreeMap::new();
        // Bump when emitted keys change shape or meaning; consumers
        // should check it (see docs/serve-bench-json.md).
        obj.insert("schema_version".to_string(), Json::Num(3.0));
        obj.insert("requests".to_string(), Json::Num(n_req as f64));
        obj.insert(
            "engine".to_string(),
            Json::Str(format!("{kind:?}").to_lowercase()),
        );
        obj.insert(
            "sched".to_string(),
            Json::Str(format!("{sched:?}").to_lowercase()),
        );
        obj.insert("policy".to_string(), Json::Str(icfg.policy.spec()));
        obj.insert(
            "concurrent".to_string(),
            Json::Num(concurrent as f64),
        );
        obj.insert(
            "prefix_cache_positions".to_string(),
            Json::Num(prefix_positions as f64),
        );
        obj.insert(
            "device_tier_positions".to_string(),
            Json::Num(device_tier as f64),
        );
        obj.insert(
            "convo_idle_ttl_ms".to_string(),
            Json::Num(convo_ttl_ms as f64),
        );
        obj.insert(
            "lane_fusion".to_string(),
            Json::Num(if lane_fusion { 1.0 } else { 0.0 }),
        );
        obj.insert(
            "lane_residency".to_string(),
            Json::Num(if lane_residency { 1.0 } else { 0.0 }),
        );
        obj.insert("workload".to_string(), Json::Str(workload.clone()));
        obj.insert(
            "preempt".to_string(),
            Json::Num(if preempt { 1.0 } else { 0.0 }),
        );
        obj.insert(
            "shed_enabled".to_string(),
            Json::Num(if shed.is_some() { 1.0 } else { 0.0 }),
        );
        obj.insert(
            "chaos".to_string(),
            match &heal.chaos {
                Some(p) => Json::Str(p.spec()),
                None => Json::Str(String::new()),
            },
        );
        obj.insert(
            "heal_retries".to_string(),
            Json::Num(heal.max_retries as f64),
        );
        obj.insert(
            "checkpoint_interval".to_string(),
            Json::Num(heal.checkpoint_interval as f64),
        );
        obj.insert(
            "tenant_weights".to_string(),
            Json::Arr(tenant_weights.iter().map(|&w| Json::Num(w)).collect()),
        );
        obj.insert("pools".to_string(), Json::Arr(json_rows));
        std::fs::write(path, Json::Obj(obj).to_string_pretty())
            .with_context(|| format!("writing --json-out {path}"))?;
        println!("[serve-bench] metrics JSON written to {path}");
    }
    Ok(())
}

/// One pool size's metrics as a JSON row for `--json-out`.
fn serve_metrics_json(
    workers: usize,
    m: &eellm::serve::ServeMetrics,
    n_layers: usize,
) -> Json {
    let mut o = std::collections::BTreeMap::new();
    let mut num = |k: &str, v: f64| {
        o.insert(k.to_string(), Json::Num(v));
    };
    num("workers", workers as f64);
    num("requests", m.requests as f64);
    num("total_tokens", m.total_tokens as f64);
    num("wall_seconds", m.wall_seconds);
    num("throughput_tps", m.throughput_tps());
    num("p50_latency_seconds", m.p50_latency_seconds);
    num("p95_latency_seconds", m.p95_latency_seconds);
    num("p50_ttft_seconds", m.p50_ttft_seconds);
    num("p95_ttft_seconds", m.p95_ttft_seconds);
    num("p50_token_gap_seconds", m.p50_token_gap_seconds);
    num("p95_token_gap_seconds", m.p95_token_gap_seconds);
    num("mean_queue_seconds", m.mean_queue_seconds);
    num("p99_ttft_seconds", m.p99_ttft_seconds);
    num("early_fraction", m.early_fraction(n_layers));
    num("deadline_misses", m.deadline_misses as f64);
    num("deadlined", m.deadlined as f64);
    num("deadline_miss_rate", m.deadline_miss_rate());
    num("preemptions", m.slo.preemptions as f64);
    num("resumes", m.slo.resumes as f64);
    num("park_failures", m.slo.park_failures as f64);
    num("resume_failures", m.slo.resume_failures as f64);
    num("shed", m.slo.shed as f64);
    num("degraded", m.slo.degraded as f64);
    num("parked_peak", m.slo.parked_peak as f64);
    num("prefix_hits", m.prefix.hits as f64);
    num("prefix_misses", m.prefix.misses as f64);
    num("prefix_hit_rate", m.prefix_hit_rate());
    num("prefill_positions_saved", m.prefill_positions_saved() as f64);
    num("prefix_insertions", m.prefix.insertions as f64);
    num("prefix_evictions", m.prefix.evictions as f64);
    num("fused_calls", m.lanes.fused_calls as f64);
    num("fused_steps", m.lanes.fused_steps as f64);
    num("solo_steps", m.lanes.solo_steps as f64);
    num("decode_steps_per_dispatch", m.lanes.steps_per_dispatch());
    num("stages_skipped_all_fired", m.lanes.stages_skipped as f64);
    num("policy_applies", m.lanes.policy_applies as f64);
    num("lane_cache_gathers", m.lanes.cache_gathers as f64);
    num("lane_cache_scatters", m.lanes.cache_scatters as f64);
    num("lane_cache_gather_bytes", m.lanes.cache_gather_bytes as f64);
    num("lane_cache_scatter_bytes", m.lanes.cache_scatter_bytes as f64);
    num("warm_group_hits", m.lanes.warm_group_hits as f64);
    num("cold_group_forms", m.lanes.cold_group_forms as f64);
    num("interleaved_rounds", m.interleave.rounds as f64);
    num("interleaved_steps", m.interleave.steps as f64);
    num("mean_sessions_in_flight", m.interleave.mean_in_flight());
    num("max_sessions_in_flight", m.interleave.max_in_flight() as f64);
    num("convo_turns", m.convo.turns as f64);
    num("convo_first_turns", m.convo.first_turns as f64);
    num("convo_restore_hits", m.convo.restore_hits as f64);
    num("convo_restore_misses", m.convo.restore_misses as f64);
    num("convo_restore_hit_rate", m.convo.restore_hit_rate());
    num("convo_saved_positions", m.convo.saved_positions as f64);
    num("convo_snapshots", m.convo.snapshots as f64);
    num("convo_snapshots_rejected", m.convo.snapshots_rejected as f64);
    num("convo_snapshot_failures", m.convo.snapshot_failures as f64);
    num("convo_expired", m.convo.expired as f64);
    num("tier_device_hits", m.tier.device_hits as f64);
    num("tier_host_hits", m.tier.host_hits as f64);
    num("tier_misses", m.tier.misses as f64);
    num("tier_promotions", m.tier.promotions as f64);
    num("tier_demotions", m.tier.demotions as f64);
    num("tier_device_hit_rate", m.tier.device_hit_rate());
    let occupancy = m
        .lanes
        .occupancy
        .iter()
        .map(|&(w, c)| (w.to_string(), Json::Num(c as f64)))
        .collect();
    o.insert("lane_occupancy".to_string(), Json::Obj(occupancy));
    let in_flight = m
        .interleave
        .occupancy
        .iter()
        .map(|&(n, c)| (n.to_string(), Json::Num(c as f64)))
        .collect();
    o.insert("interleave_occupancy".to_string(), Json::Obj(in_flight));
    let sm = &m.snapshot_memory;
    let mut mem = std::collections::BTreeMap::new();
    for (k, v) in [
        ("cached_entries", sm.cached_entries),
        ("cached_positions", sm.cached_positions),
        ("cached_bytes", sm.cached_bytes),
        ("device_entries", sm.device_entries),
        ("device_positions", sm.device_positions),
        ("device_bytes", sm.device_bytes),
        ("parked_entries", sm.parked_entries),
        ("parked_bytes", sm.parked_bytes),
        ("checkpoint_entries", sm.checkpoint_entries),
        ("checkpoint_bytes", sm.checkpoint_bytes),
        ("total_bytes", sm.total_bytes()),
    ] {
        mem.insert(k.to_string(), Json::Num(v as f64));
    }
    o.insert("snapshot_memory".to_string(), Json::Obj(mem));
    let f = &m.faults;
    let mut faults = std::collections::BTreeMap::new();
    let mut injected = std::collections::BTreeMap::new();
    let mut observed = std::collections::BTreeMap::new();
    for site in FaultSite::ALL {
        injected.insert(
            site.as_str().to_string(),
            Json::Num(f.injected[site.index()] as f64),
        );
        observed.insert(
            site.as_str().to_string(),
            Json::Num(f.observed[site.index()] as f64),
        );
    }
    faults.insert("injected".to_string(), Json::Obj(injected));
    faults.insert("observed".to_string(), Json::Obj(observed));
    for (k, v) in [
        ("injected_total", f.injected_total()),
        ("observed_total", f.observed_total()),
        ("checkpoints", f.checkpoints),
        ("checkpoint_failures", f.checkpoint_failures),
        ("retries", f.retries),
        ("recoveries", f.recoveries),
        ("recovery_failures", f.recovery_failures),
        ("redecoded_tokens", f.redecoded_tokens),
        ("engine_restarts", f.restarts),
        ("quarantines", f.quarantines),
    ] {
        faults.insert(k.to_string(), Json::Num(v as f64));
    }
    o.insert("faults".to_string(), Json::Obj(faults));
    let tenants = m
        .tenants
        .iter()
        .map(|t| {
            let mut row = std::collections::BTreeMap::new();
            row.insert("tenant".to_string(), Json::Num(t.tenant as f64));
            row.insert("requests".to_string(), Json::Num(t.requests as f64));
            row.insert("tokens".to_string(), Json::Num(t.tokens as f64));
            row.insert("share".to_string(), Json::Num(t.share));
            Json::Obj(row)
        })
        .collect();
    o.insert("tenants".to_string(), Json::Arr(tenants));
    Json::Obj(o)
}

/// Options for the conversational serving bench (`--workload convo`).
struct ConvoBenchOpts {
    n_conversations: usize,
    turns: usize,
    pool_sizes: Vec<usize>,
    /// Host-tier position budget; 0 picks the convo default.
    prefix_positions: usize,
    device_tier: usize,
    convo_ttl_ms: u64,
    lane_fusion: bool,
    lane_residency: bool,
    tenant_weights: Vec<f64>,
    engine: EngineKind,
    sched: Policy,
    concurrent: usize,
}

/// Per-conversation token streams: one inner entry per served turn.
type ConvoStreams = Vec<Vec<Vec<i32>>>;

/// One turn as actually served: the stitched prompt (history ⧺ new
/// text) plus the request attributes, recorded by the warm run so the
/// cold comparison replays byte-identical prompts.
struct PlannedTurn {
    id: u64,
    conversation: u64,
    prompt: String,
    max_new: usize,
    tenant: usize,
    think_ms: u64,
}

/// Fold one round's batch metrics into a multi-round aggregate:
/// counters sum; gauges, percentiles, and tenant shares keep the latest
/// round (the deepest-history one).
fn merge_round(agg: &mut ServeMetrics, m: &ServeMetrics) {
    agg.requests += m.requests;
    agg.total_tokens += m.total_tokens;
    agg.wall_seconds += m.wall_seconds;
    agg.p50_latency_seconds = m.p50_latency_seconds;
    agg.p95_latency_seconds = m.p95_latency_seconds;
    agg.p50_ttft_seconds = m.p50_ttft_seconds;
    agg.p95_ttft_seconds = m.p95_ttft_seconds;
    agg.p99_ttft_seconds = m.p99_ttft_seconds;
    agg.p50_token_gap_seconds = m.p50_token_gap_seconds;
    agg.p95_token_gap_seconds = m.p95_token_gap_seconds;
    agg.mean_queue_seconds = m.mean_queue_seconds;
    agg.deadline_misses += m.deadline_misses;
    agg.deadlined += m.deadlined;
    agg.exits.merge(&m.exits);
    agg.prefix.merge(&m.prefix);
    agg.lanes.merge(&m.lanes);
    agg.interleave.merge(&m.interleave);
    agg.slo.merge(&m.slo);
    agg.convo.merge(&m.convo);
    agg.tier.merge(&m.tier);
    agg.faults.merge(&m.faults);
    agg.snapshot_memory = m.snapshot_memory;
    agg.tenants = m.tenants.clone();
}

/// Serve the conversations round by round over one pool (turn `r` of
/// every conversation is one batch), stitching each turn's prompt from
/// the history plus the model's actual responses. Returns aggregated
/// metrics, the plan of served turns (for the cold replay), and the
/// per-conversation token streams.
fn drive_convo_warm(
    pool: &mut EnginePool,
    convos: &[Vec<ConvoTurn>],
    max_seq: usize,
) -> Result<(ServeMetrics, Vec<Vec<PlannedTurn>>, ConvoStreams)> {
    let n = convos.len();
    let rounds = convos.iter().map(|c| c.len()).max().unwrap_or(0);
    let mut history: Vec<String> = vec![String::new(); n];
    let mut capped = vec![false; n];
    let mut plan: Vec<Vec<PlannedTurn>> = Vec::new();
    let mut streams: ConvoStreams = vec![Vec::new(); n];
    let mut agg = ServeMetrics::default();
    for r in 0..rounds {
        let mut round: Vec<PlannedTurn> = Vec::new();
        for (c, turns) in convos.iter().enumerate() {
            let Some(t) = turns.get(r) else { continue };
            if capped[c] {
                continue;
            }
            let prompt = format!("{}{}", history[c], t.user_text);
            // Byte tokenizer: prompt + generation budget + BOS/slack
            // must fit the KV-cache capacity; a conversation that has
            // outgrown it simply ends (its turns stop, nothing fails).
            if prompt.len() + t.max_new + 4 >= max_seq {
                capped[c] = true;
                continue;
            }
            round.push(PlannedTurn {
                id: (r * n + c) as u64,
                conversation: t.conversation,
                prompt,
                max_new: t.max_new,
                tenant: t.tenant,
                think_ms: t.think_ms,
            });
        }
        if round.is_empty() {
            break;
        }
        let reqs: Vec<ServeRequest> = round
            .iter()
            .map(|p| {
                ServeRequest::new(p.id, p.prompt.as_str(), p.max_new)
                    .with_conversation(p.conversation)
                    .with_tenant(p.tenant)
                    .with_start_after(std::time::Duration::from_millis(
                        p.think_ms,
                    ))
            })
            .collect();
        let out = pool.run_batch(reqs)?;
        for f in &out.failures {
            eprintln!("[serve-bench] {f}");
        }
        for p in &round {
            let c = p.conversation as usize;
            match out.responses.iter().find(|resp| resp.id == p.id) {
                Some(resp) => {
                    history[c] =
                        format!("{}{}", p.prompt, resp.output.text);
                    streams[c].push(resp.output.tokens.clone());
                }
                // A failed turn ends its conversation: later turns
                // would stitch a history the model never generated.
                None => capped[c] = true,
            }
        }
        merge_round(&mut agg, &out.metrics);
        plan.push(round);
    }
    Ok((agg, plan, streams))
}

/// Replay the warm run's plan — byte-identical prompts — without
/// conversation tags on a snapshot-free pool: the cold baseline that
/// re-prefills each turn's whole history.
fn drive_convo_cold(
    pool: &mut EnginePool,
    plan: &[Vec<PlannedTurn>],
    n_conversations: usize,
) -> Result<(ServeMetrics, ConvoStreams)> {
    let mut streams: ConvoStreams = vec![Vec::new(); n_conversations];
    let mut agg = ServeMetrics::default();
    for round in plan {
        let reqs: Vec<ServeRequest> = round
            .iter()
            .map(|p| {
                ServeRequest::new(p.id, p.prompt.as_str(), p.max_new)
                    .with_tenant(p.tenant)
                    .with_start_after(std::time::Duration::from_millis(
                        p.think_ms,
                    ))
            })
            .collect();
        let out = pool.run_batch(reqs)?;
        for f in &out.failures {
            eprintln!("[serve-bench] {f}");
        }
        for p in round {
            if let Some(resp) =
                out.responses.iter().find(|resp| resp.id == p.id)
            {
                streams[p.conversation as usize]
                    .push(resp.output.tokens.clone());
            }
        }
        merge_round(&mut agg, &out.metrics);
    }
    Ok((agg, streams))
}

/// `serve-bench --workload convo`: multi-turn conversations served
/// round by round (turn N+1's prompt embeds turn N's actual response),
/// warm (end-of-turn snapshots + tiered store) vs cold (no snapshot
/// store, full-history prefill) per pool size. The warm streams must be
/// token-identical to the cold ones, and follow-up turns must restore
/// history.
fn cmd_serve_bench_convo(
    args: &Args,
    icfg: &InferenceConfig,
    state: ModelState,
    corpus: &Corpus,
    o: ConvoBenchOpts,
) -> Result<()> {
    let n_layers = state.man.model.n_layers;
    let max_seq = state.man.model.max_seq;
    // The snapshot store is the point of this workload; give it the
    // generous default when --prefix-cache was not spelled out.
    let positions = if o.prefix_positions > 0 {
        o.prefix_positions
    } else {
        8 * max_seq
    };
    let spec = ConvoSpec {
        seed: icfg.seed,
        n_conversations: o.n_conversations,
        turns: o.turns,
        n_system: 2.min(o.n_conversations),
        system_bytes: 48,
        tenants: o.tenant_weights.clone(),
        max_new: (2, 5),
        think_ms: (1, 4),
    };
    let convos = conversation_traffic(&spec, &corpus.facts);
    println!(
        "[serve-bench] convo workload: {} conversations x {} turns, \
         store {positions} positions (device tier {}), idle TTL {} ms",
        o.n_conversations, o.turns, o.device_tier, o.convo_ttl_ms
    );
    let mut table = Table::new(
        "Conversational serving: end-of-turn snapshots (warm) vs \
         full-history prefill (cold)",
        &["pool", "mode", "turns", "tok/s", "restore rate",
          "prefill saved", "snapshots", "p50 TTFT"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    for &workers in &o.pool_sizes {
        let warm_cfg = PoolConfig {
            workers,
            engine: o.engine,
            policy: icfg.policy.clone(),
            sched: o.sched,
            max_concurrent: o.concurrent,
            prefix_cache_positions: positions,
            device_tier_positions: o.device_tier,
            convo_idle_ttl: std::time::Duration::from_millis(
                o.convo_ttl_ms,
            ),
            lane_fusion: o.lane_fusion,
            lane_residency: o.lane_residency,
            control: ControlConfig {
                tenant_weights: o.tenant_weights.clone(),
                ..ControlConfig::default()
            },
        };
        let mut pool = EnginePool::new(state.clone(), warm_cfg.clone());
        let (warm, plan, warm_streams) =
            drive_convo_warm(&mut pool, &convos, max_seq)?;
        pool.shutdown()?;
        let cold_cfg = PoolConfig {
            prefix_cache_positions: 0,
            device_tier_positions: 0,
            ..warm_cfg
        };
        let mut pool = EnginePool::new(state.clone(), cold_cfg);
        let (cold, cold_streams) =
            drive_convo_cold(&mut pool, &plan, o.n_conversations)?;
        pool.shutdown()?;
        ensure!(
            warm_streams == cold_streams,
            "conversation snapshots changed generated tokens (pool \
             {workers})"
        );
        let followups: usize =
            plan.iter().skip(1).map(|r| r.len()).sum();
        if followups > 0 {
            ensure!(
                warm.convo.restore_hits > 0,
                "no follow-up turn restored its history (pool {workers})"
            );
        }
        for (mode, m) in [("warm", &warm), ("cold", &cold)] {
            table.row(vec![
                format!("{workers}"),
                mode.to_string(),
                format!("{}", m.requests),
                format!("{:.1}", m.throughput_tps()),
                format!("{:.0}%", 100.0 * m.convo.restore_hit_rate()),
                format!("{} pos", m.convo.saved_positions),
                format!("{}", m.convo.snapshots),
                format!("{:.0}ms", m.p50_ttft_seconds * 1e3),
            ]);
        }
        println!(
            "[serve-bench] pool {workers}: {} turns ({} opening), \
             restore rate {:.0}% ({}/{} follow-ups), {} prefill \
             positions saved ({:.1}/turn), {} snapshots ({} rejected, \
             {} failed), {} expired",
            warm.convo.turns,
            warm.convo.first_turns,
            100.0 * warm.convo.restore_hit_rate(),
            warm.convo.restore_hits,
            warm.convo.restore_hits + warm.convo.restore_misses,
            warm.convo.saved_positions,
            warm.convo.saved_per_turn(),
            warm.convo.snapshots,
            warm.convo.snapshots_rejected,
            warm.convo.snapshot_failures,
            warm.convo.expired
        );
        if o.device_tier > 0 {
            let t = &warm.tier;
            println!(
                "[serve-bench] pool {workers}: device tier {:.0}% of \
                 hits on device ({} device / {} host), {} promotions, \
                 {} demotions",
                100.0 * t.device_hit_rate(),
                t.device_hits,
                t.host_hits,
                t.promotions,
                t.demotions
            );
        }
        let sm = &warm.snapshot_memory;
        println!(
            "[serve-bench] pool {workers}: snapshot memory {} cached \
             ({} pos, {} KiB) + {} device-pinned ({} pos, {} KiB) + {} \
             parked ({} KiB) = {} KiB",
            sm.cached_entries,
            sm.cached_positions,
            sm.cached_bytes / 1024,
            sm.device_entries,
            sm.device_positions,
            sm.device_bytes / 1024,
            sm.parked_entries,
            sm.parked_bytes / 1024,
            sm.total_bytes() / 1024
        );
        println!(
            "[serve-bench] pool {workers}: warm/cold throughput ratio \
             {:.2}x",
            warm.throughput_tps() / cold.throughput_tps().max(1e-9)
        );
        for (mode, m) in [("warm", &warm), ("cold", &cold)] {
            let mut row = serve_metrics_json(workers, m, n_layers);
            if let Json::Obj(map) = &mut row {
                map.insert(
                    "mode".to_string(),
                    Json::Str(mode.to_string()),
                );
            }
            json_rows.push(row);
        }
    }
    table.emit("serve-bench");
    if let Some(path) = args.get("json-out") {
        let mut obj = std::collections::BTreeMap::new();
        // Bump when emitted keys change shape or meaning; consumers
        // should check it (see docs/serve-bench-json.md).
        obj.insert("schema_version".to_string(), Json::Num(3.0));
        obj.insert("workload".to_string(), Json::Str("convo".into()));
        obj.insert(
            "conversations".to_string(),
            Json::Num(o.n_conversations as f64),
        );
        obj.insert("turns".to_string(), Json::Num(o.turns as f64));
        obj.insert("policy".to_string(), Json::Str(icfg.policy.spec()));
        obj.insert(
            "engine".to_string(),
            Json::Str(format!("{:?}", o.engine).to_lowercase()),
        );
        obj.insert(
            "prefix_cache_positions".to_string(),
            Json::Num(positions as f64),
        );
        obj.insert(
            "device_tier_positions".to_string(),
            Json::Num(o.device_tier as f64),
        );
        obj.insert(
            "convo_idle_ttl_ms".to_string(),
            Json::Num(o.convo_ttl_ms as f64),
        );
        obj.insert(
            "tenant_weights".to_string(),
            Json::Arr(
                o.tenant_weights.iter().map(|&w| Json::Num(w)).collect(),
            ),
        );
        obj.insert("pools".to_string(), Json::Arr(json_rows));
        std::fs::write(path, Json::Obj(obj).to_string_pretty())
            .with_context(|| format!("writing --json-out {path}"))?;
        println!("[serve-bench] metrics JSON written to {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "7B");
    let dims = PAPER_MODELS
        .iter()
        .find(|d| d.name == model)
        .with_context(|| format!("unknown model {model:?}"))?;
    let pp = args.usize_or("pp", 4);
    let tp = args.usize_or("tp", 1);
    let m = args.usize_or("microbatches", 2 * pp);
    let exits: Vec<usize> = match args.get("exits") {
        Some(s) => s
            .split(',')
            .map(|x| x.parse().context("bad --exits"))
            .collect::<Result<_>>()?,
        None => vec![0; pp],
    };
    if exits.len() != pp {
        bail!("--exits must list {pp} stage counts");
    }
    let cm = CostModel::a100(dims, pp, tp);
    let opts = EeOptions::with_exits(exits.clone(), !args.flag("no-defer"));
    let mut plan = if args.flag("gpipe") {
        Plan::gpipe(pp, m, opts)
    } else {
        Plan::one_f_one_b(pp, m, opts)
    };
    let fill = args.usize_or("fill", 0);
    if fill > 0 {
        plan.add_bubble_fill(fill, fill, 2.0);
    }
    let r = Simulator::new(&cm).run(&plan);
    println!(
        "{model} pp={pp} tp={tp} M={m} exits={exits:?} defer={} gpipe={}",
        !args.flag("no-defer"),
        args.flag("gpipe")
    );
    println!("{}", render_timeline(&r, 100));
    for (s, tl) in r.timelines.iter().enumerate() {
        println!(
            "stage {s}: busy {:8.1}ms  peak mem {:7.2} GiB (act {:.2} GiB)",
            tl.busy * 1e3,
            r.peak_memory(cm.alpha, s) / (1u64 << 30) as f64,
            tl.peak_activation_bytes / (1u64 << 30) as f64,
        );
    }
    Ok(())
}

fn cmd_probe(args: &Args) -> Result<()> {
    let icfg = InferenceConfig::from_args(args)?;
    let prompt = args.get_or("prompt", "the capital of ");
    let state = model_state(args)?;
    let report = eellm::inference::probe::probe_generation(
        state,
        &prompt,
        icfg.max_new_tokens,
    )?;
    println!("generated: {:?}", report.generated);
    println!("{}", report.to_table().to_markdown());
    println!(
        "cross-exit agreement on confident (>=0.8) tokens: {:.1}%",
        100.0 * report.agreement_at(0.8)
    );
    // Calibration workflow: fit per-layer confidence thresholds from
    // this probe so each exit only fires where it agrees with the final
    // exit at the target rate, and print the ready-to-use spec.
    if let Some(target) = args.get("calibrate") {
        let target: f64 = target
            .parse()
            .context("--calibrate wants an agreement rate in [0, 1]")?;
        let policy = ExitPolicy::calibrated(&report, target);
        println!(
            "calibrated exit policy (target agreement {target}): \
             --policy {}",
            policy.spec()
        );
        if !policy.may_exit() {
            println!(
                "(no exit reaches the target on this probe; the fitted \
                 policy never exits early)"
            );
        }
    }
    Ok(())
}
