//! Pipeline-parallel training runtime — the paper's core contribution (C1)
//! as a real multi-threaded system.
//!
//! Topology: one OS thread per pipeline stage (its own PJRT client and
//! compiled executables — the `xla` types are thread-local by design), with
//! point-to-point channels carrying hidden states forward and gradient
//! tensors backward, exactly the communication pattern of Megatron pipeline
//! parallelism. The leader thread only dispatches iterations and performs
//! the scalar reductions (global grad-norm clip, tied-embedding gradient
//! all-reduce, loss aggregation).
//!
//! Each stage executes the classical 1F1B op order; the backward executable
//! is the AOT-lowered auxiliary-loss function of Eq. (2):
//!
//! ```text
//! (losses, g_in, grads) = d/d(theta_i, x_i-1) [ sum_e w_e CE_e + <g_out, x_out> ]
//! ```
//!
//! so the wire protocol is identical to standard pipeline training — only
//! the local backward objective differs, which is precisely the paper's
//! claim. Bubble filling (Appendix C.2) runs partial microbatches
//! opportunistically while a worker would otherwise block on its P2P
//! receive.

pub mod channel;
pub mod reference;
pub mod trainer;
pub mod worker;

pub use trainer::{PipelineTrainer, StepStats, TrainerOptions};
