//! Tagged P2P channels between pipeline stages.
//!
//! Stages execute their op lists in their own order (bubble filling makes
//! the order stage-dependent), so the receiver buffers out-of-order
//! messages and callers ask for a specific tag — messages never block each
//! other.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};

use crate::runtime::tensor::HostTensor;

/// Message tags on the forward/backward P2P wires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tag {
    /// Forward activation of main microbatch m.
    Fwd(usize),
    /// Backward gradient of main microbatch m.
    Bwd(usize),
    /// Forward activation of fill microbatch j.
    FillFwd(usize),
    /// Backward gradient of fill microbatch j.
    FillBwd(usize),
}

#[derive(Clone)]
pub struct TaggedSender {
    tx: Sender<(Tag, HostTensor)>,
}

impl TaggedSender {
    pub fn send(&self, tag: Tag, t: HostTensor) {
        // A send failure means the peer worker panicked; propagate.
        self.tx.send((tag, t)).expect("peer stage worker is gone");
    }
}

pub struct TaggedReceiver {
    rx: Receiver<(Tag, HostTensor)>,
    pending: HashMap<Tag, HostTensor>,
}

impl TaggedReceiver {
    /// Blocking receive of a specific tag.
    pub fn recv(&mut self, tag: Tag) -> HostTensor {
        if let Some(t) = self.pending.remove(&tag) {
            return t;
        }
        loop {
            let (got, t) =
                self.rx.recv().expect("peer stage worker is gone");
            if got == tag {
                return t;
            }
            self.pending.insert(got, t);
        }
    }

    /// Non-blocking probe: true iff `tag` is available right now.
    pub fn ready(&mut self, tag: Tag) -> bool {
        self.drain();
        self.pending.contains_key(&tag)
    }

    /// Pull everything currently queued into the pending buffer.
    pub fn drain(&mut self) {
        loop {
            match self.rx.try_recv() {
                Ok((tag, t)) => {
                    self.pending.insert(tag, t);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                    break;
                }
            }
        }
    }

    /// Block until *some* message arrives (used while waiting with fill
    /// work unavailable), buffering it.
    pub fn recv_any(&mut self) {
        if let Ok((tag, t)) = self.rx.recv() {
            self.pending.insert(tag, t);
        }
    }
}

pub fn tagged_channel() -> (TaggedSender, TaggedReceiver) {
    let (tx, rx) = std::sync::mpsc::channel();
    (TaggedSender { tx }, TaggedReceiver { rx, pending: HashMap::new() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: f32) -> HostTensor {
        HostTensor::scalar(v)
    }

    #[test]
    fn out_of_order_delivery() {
        let (tx, mut rx) = tagged_channel();
        tx.send(Tag::Fwd(1), t(1.0));
        tx.send(Tag::Fwd(0), t(0.0));
        tx.send(Tag::Bwd(0), t(9.0));
        assert_eq!(rx.recv(Tag::Fwd(0)).data[0], 0.0);
        assert_eq!(rx.recv(Tag::Bwd(0)).data[0], 9.0);
        assert_eq!(rx.recv(Tag::Fwd(1)).data[0], 1.0);
    }

    #[test]
    fn ready_probe() {
        let (tx, mut rx) = tagged_channel();
        assert!(!rx.ready(Tag::FillFwd(0)));
        tx.send(Tag::FillFwd(0), t(2.0));
        assert!(rx.ready(Tag::FillFwd(0)));
        assert_eq!(rx.recv(Tag::FillFwd(0)).data[0], 2.0);
    }

    #[test]
    fn cross_thread() {
        let (tx, mut rx) = tagged_channel();
        let h = std::thread::spawn(move || {
            for i in (0..10).rev() {
                tx.send(Tag::Fwd(i), t(i as f32));
            }
        });
        for i in 0..10 {
            assert_eq!(rx.recv(Tag::Fwd(i)).data[0], i as f32);
        }
        h.join().unwrap();
    }
}
