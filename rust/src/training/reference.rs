//! The monolithic (single-executable) reference model: loss + gradients of
//! the whole early-exit LLM in one AOT module.
//!
//! This is the ground truth the integration tests compare the
//! pipeline-parallel trainer against (Proposition 3.1: they must agree
//! exactly), and the workhorse for small-scale experiments that don't need
//! the multi-thread pipeline.

use anyhow::{Context, Result};

use crate::data::dataset::TrainBatch;
use crate::runtime::artifacts::Manifest;
use crate::runtime::client::StageRuntime;
use crate::runtime::params;
use crate::runtime::tensor::HostTensor;

pub struct ReferenceModel {
    pub man: Manifest,
    rt: StageRuntime,
    pub params: Vec<HostTensor>,
}

impl ReferenceModel {
    pub fn new(man: Manifest, seed: u64) -> Result<ReferenceModel> {
        let reference = man
            .reference
            .clone()
            .context("manifest has no reference executables (emit_reference=False)")?;
        let mut rt = StageRuntime::cpu()?;
        rt.load("loss_grads", &man.exec_path(&reference.loss_grads))?;
        rt.load("eval", &man.exec_path(&reference.eval))?;
        let params = params::init_full(seed, &man);
        Ok(ReferenceModel { man, rt, params })
    }

    fn arg_literals(
        &self,
        batch: &TrainBatch,
        weights: &[f32],
    ) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(self.params.len() + 3);
        for p in &self.params {
            lits.push(p.to_literal()?);
        }
        lits.push(batch.tokens.to_literal()?);
        lits.push(batch.targets.to_literal()?);
        lits.push(
            HostTensor::new(vec![weights.len()], weights.to_vec())
                .to_literal()?,
        );
        Ok(lits)
    }

    /// (per-exit losses, gradients in full param order).
    pub fn loss_grads(
        &self,
        batch: &TrainBatch,
        weights: &[f32],
    ) -> Result<(Vec<f64>, Vec<HostTensor>)> {
        let lits = self.arg_literals(batch, weights)?;
        let out = self.rt.get("loss_grads")?.run(
            &lits.iter().collect::<Vec<_>>(),
        )?;
        let losses = HostTensor::from_literal(&out[0])?
            .data
            .iter()
            .map(|&x| x as f64)
            .collect();
        let grads = out[1..]
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok((losses, grads))
    }

    /// (weighted total loss, per-exit losses).
    pub fn eval(
        &self,
        batch: &TrainBatch,
        weights: &[f32],
    ) -> Result<(f64, Vec<f64>)> {
        let lits = self.arg_literals(batch, weights)?;
        let out =
            self.rt.get("eval")?.run(&lits.iter().collect::<Vec<_>>())?;
        let total = HostTensor::from_literal(&out[0])?.data[0] as f64;
        let losses = HostTensor::from_literal(&out[1])?
            .data
            .iter()
            .map(|&x| x as f64)
            .collect();
        Ok((total, losses))
    }

    /// Default exit weights from the manifest (stage-major).
    pub fn default_weights(&self) -> Vec<f32> {
        self.man.exit_order().iter().map(|&(_, _, w)| w).collect()
    }
}
