//! The pipeline-training leader: spawns stage workers, dispatches
//! iterations, and performs the cross-stage scalar reductions (global
//! gradient-norm clipping, tied-embedding gradient all-reduce, loss
//! aggregation) plus checkpointing and loss-weight/LR schedules.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{LossWeightSchedule, LrSchedule};
use crate::data::dataset::TrainBatch;
use crate::runtime::artifacts::Manifest;
use crate::runtime::params as ckpt;
use crate::runtime::tensor::HostTensor;
use crate::schedule::fill::FillPlan;

use super::channel::tagged_channel;
use super::worker::{
    Cmd, FillSpec, IterationCmd, MicrobatchData, Reply, Worker, WorkerConfig,
};

#[derive(Debug, Clone)]
pub struct TrainerOptions {
    pub seed: u64,
    pub lr: LrSchedule,
    pub grad_clip: f64,
    pub loss_weights: LossWeightSchedule,
    pub total_steps: usize,
    /// Requested bubble-fill microbatches per iteration (Appendix C.2
    /// Part 2; capped by the schedule capacity).
    pub bubble_fill: usize,
    pub bf_ratio: f64,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-4, 10, 100),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: 100,
            bubble_fill: 0,
            bf_ratio: 2.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: usize,
    /// Mean loss per exit, stage-major order (final exit last).
    pub losses: Vec<f64>,
    pub grad_norm: f64,
    pub lr: f64,
    pub wall_seconds: f64,
    /// Fill microbatches that contributed gradients this step.
    pub fill_contributions: usize,
}

struct WorkerHandle {
    cmds: Sender<Cmd>,
    join: Option<std::thread::JoinHandle<Result<()>>>,
}

pub struct PipelineTrainer {
    pub man: Manifest,
    opts: TrainerOptions,
    workers: Vec<WorkerHandle>,
    replies: Receiver<Reply>,
    /// Default exit weights (stage-major) and finality flags.
    weight_defaults: Vec<f32>,
    weight_final: Vec<bool>,
    exits_per_stage: Vec<usize>,
    step: usize,
}

impl PipelineTrainer {
    pub fn new(man: Manifest, opts: TrainerOptions) -> Result<PipelineTrainer> {
        let p = man.model.pipeline_stages;
        let (reply_tx, replies) = channel::<Reply>();

        // P2P wiring: worker s's inbox receives from s-1 (forward tags)
        // and s+1 (backward tags); TaggedSender is Clone so both
        // neighbours hold a handle to the same inbox.
        let mut txs = Vec::with_capacity(p);
        let mut rxs = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = tagged_channel();
            txs.push(tx);
            rxs.push(Some(rx));
        }

        let mut workers = Vec::with_capacity(p);
        for (s, rx) in rxs.iter_mut().enumerate() {
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            let to_prev = (s > 0).then(|| txs[s - 1].clone());
            let to_next = (s + 1 < p).then(|| txs[s + 1].clone());
            let join = Worker::spawn(
                man.clone(),
                WorkerConfig { stage: s, stages: p, seed: opts.seed },
                rx.take().unwrap(),
                to_prev,
                to_next,
                cmd_rx,
                reply_tx.clone(),
            );
            workers.push(WorkerHandle { cmds: cmd_tx, join: Some(join) });
        }
        drop(txs);

        let mut weight_defaults = Vec::new();
        let mut weight_final = Vec::new();
        let mut exits_per_stage = Vec::new();
        for st in &man.stages {
            exits_per_stage.push(st.exits.len());
            for e in &st.exits {
                weight_defaults.push(e.weight);
                weight_final.push(e.is_final);
            }
        }

        Ok(PipelineTrainer {
            man,
            opts,
            workers,
            replies,
            weight_defaults,
            weight_final,
            exits_per_stage,
            step: 0,
        })
    }

    pub fn exit_names(&self) -> Vec<String> {
        self.man
            .exit_order()
            .iter()
            .map(|(s, l, _)| format!("exit{l}@s{s}"))
            .collect()
    }

    /// Current schedule-adjusted loss weights (all exits, stage-major).
    pub fn current_weights(&self) -> Vec<f32> {
        self.opts.loss_weights.weights_at(
            self.step,
            self.opts.total_steps,
            &self.weight_defaults,
            &self.weight_final,
        )
    }

    /// One training step over `microbatches` (+ optional bubble fills).
    pub fn train_step(
        &mut self,
        microbatches: &[TrainBatch],
        fill_batches: &[TrainBatch],
    ) -> Result<StepStats> {
        let t0 = Instant::now();
        let p = self.man.model.pipeline_stages;
        let m = microbatches.len();
        let weights = self.current_weights();
        let lr = self.opts.lr.at(self.step) as f32;
        self.step += 1;

        // Fill plan (Part 2 of Appendix C.2): full forward + truncated
        // backward over the last `depth_j` stages.
        let plan = FillPlan::plan(p, self.opts.bf_ratio, self.opts.bubble_fill);
        let fills: Vec<(FillSpec, MicrobatchData)> = fill_batches
            .iter()
            .take(plan.k2)
            .enumerate()
            .map(|(j, b)| {
                (
                    FillSpec {
                        fwd_stages: p,
                        bwd_stages: plan.part2_bwd_depth(p, j).max(1),
                    },
                    MicrobatchData {
                        tokens: b.tokens.clone(),
                        targets: b.targets.clone(),
                    },
                )
            })
            .collect();

        // Dispatch the iteration to every worker.
        let mut woff = 0usize;
        for (s, w) in self.workers.iter().enumerate() {
            let n_e = self.exits_per_stage[s];
            let cmd = IterationCmd {
                step: self.step,
                lr,
                weights: weights[woff..woff + n_e].to_vec(),
                microbatches: microbatches
                    .iter()
                    .map(|b| MicrobatchData {
                        tokens: b.tokens.clone(),
                        targets: b.targets.clone(),
                    })
                    .collect(),
                fills: fills.clone(),
            };
            woff += n_e;
            w.cmds.send(Cmd::Iteration(cmd)).context("worker send")?;
        }

        // Collect IterDone from all stages.
        let mut loss_sums = vec![0f64; self.weight_defaults.len()];
        let mut sq_sum = 0f64;
        let mut tied: std::collections::BTreeMap<String, HostTensor> =
            Default::default();
        let mut contributions = vec![0usize; p];
        for _ in 0..p {
            match self.replies.recv().context("worker reply")? {
                Reply::IterDone {
                    stage,
                    loss_sums: ls,
                    grad_sqsum,
                    tied_grads,
                    contributions: c,
                } => {
                    let off: usize =
                        self.exits_per_stage[..stage].iter().sum();
                    for (i, l) in ls.iter().enumerate() {
                        loss_sums[off + i] += l;
                    }
                    sq_sum += grad_sqsum;
                    contributions[stage] = c;
                    for (g, t) in tied_grads {
                        tied.entry(g)
                            .and_modify(|acc| acc.axpy(1.0, &t))
                            .or_insert(t);
                    }
                }
                other => anyhow::bail!("unexpected reply {other:?}"),
            }
        }

        // Gradients are sums over contributions; normalise per stage and
        // clip by the global norm of the *averaged* gradient.
        // Note: stages may have different contribution counts when fills
        // are active; we use each stage's own average (the Appendix C.2
        // B/(B+K) rescale falls out of this normalisation).
        let grad_norm = (sq_sum).sqrt() / m as f64;
        let clip_scale = if self.opts.grad_clip > 0.0 && grad_norm > self.opts.grad_clip {
            self.opts.grad_clip / grad_norm
        } else {
            1.0
        };

        // Optimize phase.
        for (s, w) in self.workers.iter().enumerate() {
            let scale = clip_scale as f32 / contributions[s] as f32;
            let tied_vec: Vec<(String, HostTensor)> =
                tied.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
            w.cmds
                .send(Cmd::Optimize {
                    step: self.step,
                    lr,
                    scale,
                    tied: tied_vec,
                })
                .context("optimize send")?;
        }
        for _ in 0..p {
            match self.replies.recv().context("optimize reply")? {
                Reply::Ack => {}
                other => anyhow::bail!("unexpected reply {other:?}"),
            }
        }

        Ok(StepStats {
            step: self.step,
            losses: loss_sums.iter().map(|l| l / m as f64).collect(),
            grad_norm,
            lr: lr as f64,
            wall_seconds: t0.elapsed().as_secs_f64(),
            fill_contributions: fills.len(),
        })
    }

    /// Validation: mean per-exit losses over the given batches.
    pub fn validate(&mut self, batches: &[TrainBatch]) -> Result<Vec<f64>> {
        let p = self.man.model.pipeline_stages;
        let mut sums = vec![0f64; self.weight_defaults.len()];
        for b in batches {
            for w in &self.workers {
                w.cmds
                    .send(Cmd::Eval(MicrobatchData {
                        tokens: b.tokens.clone(),
                        targets: b.targets.clone(),
                    }))
                    .context("eval send")?;
            }
            for _ in 0..p {
                match self.replies.recv().context("eval reply")? {
                    Reply::EvalDone { stage, losses } => {
                        let off: usize =
                            self.exits_per_stage[..stage].iter().sum();
                        for (i, l) in losses.iter().enumerate() {
                            sums[off + i] += l;
                        }
                    }
                    other => anyhow::bail!("unexpected reply {other:?}"),
                }
            }
        }
        let n = batches.len().max(1) as f64;
        Ok(sums.iter().map(|s| s / n).collect())
    }

    /// Fetch all parameters (stage-major).
    pub fn params(&mut self) -> Result<Vec<Vec<HostTensor>>> {
        let p = self.man.model.pipeline_stages;
        for w in &self.workers {
            w.cmds.send(Cmd::GetParams).context("params send")?;
        }
        let mut out: Vec<Option<Vec<HostTensor>>> = vec![None; p];
        for _ in 0..p {
            match self.replies.recv().context("params reply")? {
                Reply::Params { stage, params } => out[stage] = Some(params),
                other => anyhow::bail!("unexpected reply {other:?}"),
            }
        }
        Ok(out.into_iter().map(|o| o.unwrap()).collect())
    }

    pub fn set_params(&mut self, params: Vec<Vec<HostTensor>>) -> Result<()> {
        for (w, ps) in self.workers.iter().zip(params) {
            w.cmds.send(Cmd::SetParams(ps)).context("set params")?;
        }
        for _ in 0..self.workers.len() {
            match self.replies.recv().context("ack")? {
                Reply::Ack => {}
                other => anyhow::bail!("unexpected reply {other:?}"),
            }
        }
        Ok(())
    }

    pub fn save_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let params = self.params()?;
        ckpt::save_stage_params(path, &self.man, &params)
    }

    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let params = ckpt::load_stage_params(path, &self.man)?;
        self.set_params(params)
    }

    /// Per-stage executable profile: (stage, exec name, calls, total ms).
    pub fn profile(&mut self) -> Result<Vec<(usize, String, u64, f64)>> {
        let mut out = Vec::new();
        for w in &self.workers {
            w.cmds.send(Cmd::Profile).context("profile send")?;
        }
        for _ in 0..self.workers.len() {
            match self.replies.recv().context("profile reply")? {
                Reply::ProfileData { stage, rows } => {
                    for (name, calls, ms) in rows {
                        out.push((stage, name, calls, ms));
                    }
                }
                other => anyhow::bail!("unexpected reply {other:?}"),
            }
        }
        out.sort_by(|a, b| (a.0, a.1.clone()).cmp(&(b.0, b.1.clone())));
        Ok(out)
    }

    pub fn shutdown(mut self) {
        for w in &self.workers {
            let _ = w.cmds.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                match j.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => eprintln!("worker error: {e:#}"),
                    Err(_) => eprintln!("worker panicked"),
                }
            }
        }
    }
}

impl Drop for PipelineTrainer {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmds.send(Cmd::Shutdown);
        }
    }
}
