//! The per-stage training worker thread.
//!
//! Owns its PJRT client, compiled executables, parameters and optimizer
//! state; executes the 1F1B op order with the auxiliary-loss backward
//! (Eq. 2), accumulates gradients across microbatches, and participates in
//! the two-phase optimize step (local sq-sum -> leader reduce -> Adam with
//! the leader's scale). Fill microbatches (Appendix C.2) run
//! opportunistically while the worker would otherwise block on a P2P
//! receive.

use std::sync::mpsc::{Receiver, Sender};

use anyhow::Result;

use crate::runtime::artifacts::Manifest;
use crate::runtime::client::StageRuntime;
use crate::runtime::params;
use crate::runtime::tensor::{HostTensor, IntTensor};

use super::channel::{Tag, TaggedReceiver, TaggedSender};

#[derive(Debug, Clone)]
pub struct MicrobatchData {
    /// Input tokens — consumed by stage 0 only.
    pub tokens: IntTensor,
    /// Next-token targets — needed by every stage that owns exits.
    pub targets: IntTensor,
}

/// Stage coverage of one fill microbatch (Appendix C.2): forward through
/// stages [0, fwd_stages), backward through the last `bwd_stages` of those.
#[derive(Debug, Clone, Copy)]
pub struct FillSpec {
    pub fwd_stages: usize,
    pub bwd_stages: usize,
}

impl FillSpec {
    pub fn turnaround(&self) -> usize {
        self.fwd_stages - 1
    }

    pub fn bwd_covers(&self, stage: usize) -> bool {
        stage < self.fwd_stages
            && stage + self.bwd_stages >= self.fwd_stages
    }

    pub fn fwd_covers(&self, stage: usize) -> bool {
        stage < self.fwd_stages
    }
}

#[derive(Debug)]
pub struct IterationCmd {
    /// 1-based optimizer step (Adam bias correction).
    pub step: usize,
    pub lr: f32,
    /// This stage's exit loss weights (schedule-adjusted).
    pub weights: Vec<f32>,
    pub microbatches: Vec<MicrobatchData>,
    pub fills: Vec<(FillSpec, MicrobatchData)>,
}

#[derive(Debug)]
pub enum Cmd {
    Iteration(IterationCmd),
    /// Second phase of a step: apply Adam with the leader-computed scale;
    /// tied-group gradients (summed across stages) override local ones.
    Optimize {
        step: usize,
        lr: f32,
        scale: f32,
        tied: Vec<(String, HostTensor)>,
    },
    /// Forward one batch through eval executables (validation losses).
    Eval(MicrobatchData),
    GetParams,
    SetParams(Vec<HostTensor>),
    Profile,
    Shutdown,
}

#[derive(Debug)]
pub enum Reply {
    IterDone {
        stage: usize,
        /// Per-exit loss sums over main microbatches.
        loss_sums: Vec<f64>,
        grad_sqsum: f64,
        /// (group name, local gradient sum) for each tied group member set
        /// on this stage.
        tied_grads: Vec<(String, HostTensor)>,
        /// Microbatch contributions to this stage's gradient (main + fill).
        contributions: usize,
    },
    EvalDone { stage: usize, losses: Vec<f64> },
    Params { stage: usize, params: Vec<HostTensor> },
    ProfileData { stage: usize, rows: Vec<(String, u64, f64)> },
    Ack,
}

pub struct WorkerConfig {
    pub stage: usize,
    pub stages: usize,
    pub seed: u64,
}

enum Stash {
    Tokens(IntTensor),
    Hidden(HostTensor),
}

pub struct Worker {
    cfg: WorkerConfig,
    man: Manifest,
    rt: StageRuntime,
    params: Vec<HostTensor>,
    adam_m: Vec<HostTensor>,
    adam_v: Vec<HostTensor>,
    grads: Vec<HostTensor>,
    inbox: TaggedReceiver,
    to_prev: Option<TaggedSender>,
    to_next: Option<TaggedSender>,
    cmds: Receiver<Cmd>,
    replies: Sender<Reply>,
    n_exits: usize,
    has_losses_output: bool,
}

impl Worker {
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        man: Manifest,
        cfg: WorkerConfig,
        inbox: TaggedReceiver,
        to_prev: Option<TaggedSender>,
        to_next: Option<TaggedSender>,
        cmds: Receiver<Cmd>,
        replies: Sender<Reply>,
    ) -> std::thread::JoinHandle<Result<()>> {
        std::thread::Builder::new()
            .name(format!("stage-{}", cfg.stage))
            .spawn(move || {
                let mut w = Worker::new(
                    man, cfg, inbox, to_prev, to_next, cmds, replies,
                )?;
                w.run()
            })
            .expect("spawning stage worker")
    }

    #[allow(clippy::too_many_arguments)]
    fn new(
        man: Manifest,
        cfg: WorkerConfig,
        inbox: TaggedReceiver,
        to_prev: Option<TaggedSender>,
        to_next: Option<TaggedSender>,
        cmds: Receiver<Cmd>,
        replies: Sender<Reply>,
    ) -> Result<Worker> {
        let s = cfg.stage;
        let mut rt = StageRuntime::cpu()?;
        rt.load_stage_training(&man, &man.stages[s])?;
        let params = params::init_stage(cfg.seed, &man, s);
        let zeros: Vec<HostTensor> = man.stages[s]
            .params
            .iter()
            .map(|p| HostTensor::zeros(&p.shape))
            .collect();
        let n_exits = man.stages[s].exits.len();
        Ok(Worker {
            cfg,
            rt,
            params,
            adam_m: zeros.clone(),
            adam_v: zeros.clone(),
            grads: zeros,
            inbox,
            to_prev,
            to_next,
            cmds,
            replies,
            n_exits,
            has_losses_output: n_exits > 0,
            man,
        })
    }

    fn stage(&self) -> usize {
        self.cfg.stage
    }

    fn is_first(&self) -> bool {
        self.cfg.stage == 0
    }

    fn is_last(&self) -> bool {
        self.cfg.stage == self.cfg.stages - 1
    }

    fn run(&mut self) -> Result<()> {
        loop {
            match self.cmds.recv() {
                Ok(Cmd::Iteration(cmd)) => self.iteration(cmd)?,
                Ok(Cmd::Optimize { step, lr, scale, tied }) => {
                    self.optimize(step, lr, scale, tied)?;
                    self.replies.send(Reply::Ack).ok();
                }
                Ok(Cmd::Eval(mb)) => self.eval(mb)?,
                Ok(Cmd::GetParams) => {
                    self.replies
                        .send(Reply::Params {
                            stage: self.stage(),
                            params: self.params.clone(),
                        })
                        .ok();
                }
                Ok(Cmd::SetParams(p)) => {
                    assert_eq!(p.len(), self.params.len());
                    self.params = p;
                    self.replies.send(Reply::Ack).ok();
                }
                Ok(Cmd::Profile) => {
                    self.replies
                        .send(Reply::ProfileData {
                            stage: self.stage(),
                            rows: self.rt.profile(),
                        })
                        .ok();
                }
                Ok(Cmd::Shutdown) | Err(_) => return Ok(()),
            }
        }
    }

    fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        self.params.iter().map(|p| p.to_literal()).collect()
    }

    /// Forward one input through the stage; returns x_out and sends it on.
    fn exec_fwd(
        &self,
        plits: &[xla::Literal],
        stash: &Stash,
        tag: Tag,
    ) -> Result<HostTensor> {
        let in_lit = match stash {
            Stash::Tokens(t) => t.to_literal()?,
            Stash::Hidden(h) => h.to_literal()?,
        };
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&in_lit);
        let out = self.rt.get("fwd")?.run(&args)?;
        let x_out = HostTensor::from_literal(&out[0])?;
        if let Some(next) = &self.to_next {
            next.send(tag, x_out.clone());
        }
        Ok(x_out)
    }

    /// Backward with the auxiliary loss; accumulates grads, returns
    /// per-exit losses, and sends g_in to the previous stage (when asked).
    #[allow(clippy::too_many_arguments)]
    fn exec_bwd(
        &mut self,
        plits: &[xla::Literal],
        stash: Stash,
        targets: &IntTensor,
        weights: &[f32],
        g_out: &HostTensor,
        send_down: bool,
        tag: Tag,
    ) -> Result<Vec<f64>> {
        let in_lit = match &stash {
            Stash::Tokens(t) => t.to_literal()?,
            Stash::Hidden(h) => h.to_literal()?,
        };
        let t_lit = targets.to_literal()?;
        let w_lit = HostTensor::new(vec![weights.len()], weights.to_vec())
            .to_literal()?;
        let g_lit = g_out.to_literal()?;
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&in_lit);
        args.push(&t_lit);
        if self.has_losses_output {
            args.push(&w_lit);
        }
        args.push(&g_lit);
        let out = self.rt.get("bwd")?.run(&args)?;

        // Output layout: (losses?, g_in?, *param_grads).
        let mut idx = 0;
        let losses = if self.has_losses_output {
            let l = HostTensor::from_literal(&out[idx])?;
            idx += 1;
            l.data.iter().map(|&x| x as f64).collect()
        } else {
            Vec::new()
        };
        if !self.is_first() {
            let g_in = HostTensor::from_literal(&out[idx])?;
            idx += 1;
            if send_down {
                if let Some(prev) = &self.to_prev {
                    prev.send(tag, g_in);
                }
            }
        }
        for (i, g) in out[idx..].iter().enumerate() {
            let gt = HostTensor::from_literal(g)?;
            self.grads[i].axpy(1.0, &gt);
        }
        Ok(losses)
    }

    /// Try to run one pending fill op; returns true if progress was made.
    fn try_fill(
        &mut self,
        plits: &[xla::Literal],
        cmd: &IterationCmd,
        fill_stage: &mut FillState,
    ) -> Result<bool> {
        let s = self.stage();
        // Next fill forward.
        if let Some(j) = fill_stage.next_fwd(cmd, s) {
            let ready = self.is_first() || self.inbox.ready(Tag::FillFwd(j));
            if ready {
                let stash = if self.is_first() {
                    Stash::Tokens(cmd.fills[j].1.tokens.clone())
                } else {
                    Stash::Hidden(self.inbox.recv(Tag::FillFwd(j)))
                };
                let spec = cmd.fills[j].0;
                if s < spec.turnaround() {
                    // Forward and send on.
                    let in_lit = match &stash {
                        Stash::Tokens(t) => t.to_literal()?,
                        Stash::Hidden(h) => h.to_literal()?,
                    };
                    let mut args: Vec<&xla::Literal> = plits.iter().collect();
                    args.push(&in_lit);
                    let out = self.rt.get("fwd")?.run(&args)?;
                    let x_out = HostTensor::from_literal(&out[0])?;
                    if let Some(next) = &self.to_next {
                        next.send(Tag::FillFwd(j), x_out);
                    }
                } // Turnaround stage: its backward recomputes the forward.
                fill_stage.stash.push((j, stash));
                fill_stage.fwd_done += 1;
                return Ok(true);
            }
        }
        // Next fill backward.
        if let Some(j) = fill_stage.next_bwd(cmd, s) {
            let spec = cmd.fills[j].0;
            let at_turnaround = s == spec.turnaround();
            let ready = at_turnaround || self.inbox.ready(Tag::FillBwd(j));
            if ready {
                let pos = fill_stage
                    .stash
                    .iter()
                    .position(|(id, _)| *id == j)
                    .expect("fill backward before forward");
                let (_, stash) = fill_stage.stash.remove(pos);
                let g_out = if at_turnaround {
                    let tsh = &cmd.fills[j].1.targets.shape;
                    HostTensor::zeros(&[
                        tsh[0],
                        tsh[1],
                        self.man.model.hidden,
                    ])
                } else {
                    self.inbox.recv(Tag::FillBwd(j))
                };
                // Send further down only while the next-lower stage is
                // still inside the backward cover.
                let send_down = !self.is_first()
                    && spec.bwd_covers(s - 1);
                self.exec_bwd(
                    plits,
                    stash,
                    &cmd.fills[j].1.targets,
                    &cmd.weights,
                    &g_out,
                    send_down,
                    Tag::FillBwd(j),
                )?;
                fill_stage.bwd_done += 1;
                fill_stage.contributions += 1;
                return Ok(true);
            }
        }
        Ok(false)
    }

    fn iteration(&mut self, cmd: IterationCmd) -> Result<()> {
        let s = self.stage();
        let p = self.cfg.stages;
        let m = cmd.microbatches.len();
        assert!(m >= p, "1F1B requires microbatches >= stages");
        let plits = self.param_literals()?;

        // Reset accumulators.
        for g in &mut self.grads {
            g.data.iter_mut().for_each(|x| *x = 0.0);
        }
        let mut loss_sums = vec![0f64; self.n_exits];
        let mut stash: Vec<Option<Stash>> = (0..m).map(|_| None).collect();
        let mut fill_state = FillState::default();

        // 1F1B main op order for this stage.
        let warmup = (p - 1 - s).min(m);
        let mut ops: Vec<(bool, usize)> = Vec::new(); // (is_fwd, mb)
        for mb in 0..warmup {
            ops.push((true, mb));
        }
        let mut next_f = warmup;
        let mut next_b = 0;
        while next_b < m {
            if next_f < m {
                ops.push((true, next_f));
                next_f += 1;
            }
            ops.push((false, next_b));
            next_b += 1;
        }

        for (is_fwd, mb) in ops {
            if is_fwd {
                let input = if self.is_first() {
                    Stash::Tokens(cmd.microbatches[mb].tokens.clone())
                } else {
                    // Opportunistic bubble filling: run fill work while the
                    // forward activation has not arrived yet.
                    while !self.inbox.ready(Tag::Fwd(mb)) {
                        if !self.try_fill(&plits, &cmd, &mut fill_state)? {
                            self.inbox.recv_any();
                        }
                    }
                    Stash::Hidden(self.inbox.recv(Tag::Fwd(mb)))
                };
                // The last stage has no consumer for x_out, and its
                // backward recomputes the stage forward from x_in anyway:
                // skip the redundant forward execution entirely.
                if !self.is_last() {
                    self.exec_fwd(&plits, &input, Tag::Fwd(mb))?;
                }
                stash[mb] = Some(input);
            } else {
                let g_out = if self.is_last() {
                    let b = cmd.microbatches[mb].targets.shape[0];
                    let seq = cmd.microbatches[mb].targets.shape[1];
                    HostTensor::zeros(&[b, seq, self.man.model.hidden])
                } else {
                    while !self.inbox.ready(Tag::Bwd(mb)) {
                        if !self.try_fill(&plits, &cmd, &mut fill_state)? {
                            self.inbox.recv_any();
                        }
                    }
                    self.inbox.recv(Tag::Bwd(mb))
                };
                let input = stash[mb].take().expect("bwd before fwd");
                let losses = self.exec_bwd(
                    &plits,
                    input,
                    &cmd.microbatches[mb].targets,
                    &cmd.weights,
                    &g_out,
                    true,
                    Tag::Bwd(mb),
                )?;
                for (i, l) in losses.iter().enumerate() {
                    loss_sums[i] += l;
                }
            }
        }

        // Finish remaining fill work (blocking).
        while !fill_state.finished(&cmd, s) {
            if !self.try_fill(&plits, &cmd, &mut fill_state)? {
                self.inbox.recv_any();
            }
        }

        // Local reductions for the leader.
        let grad_sqsum: f64 = self.grads.iter().map(|g| g.sq_sum()).sum();
        let mut tied_grads = Vec::new();
        for (i, sp) in self.man.stages[s].params.iter().enumerate() {
            if let Some(g) = &sp.tie_group {
                tied_grads.push((g.clone(), self.grads[i].clone()));
            }
        }
        self.replies
            .send(Reply::IterDone {
                stage: s,
                loss_sums,
                grad_sqsum,
                tied_grads,
                contributions: m + fill_state.contributions,
            })
            .ok();
        Ok(())
    }

    fn optimize(
        &mut self,
        step: usize,
        lr: f32,
        scale: f32,
        tied: Vec<(String, HostTensor)>,
    ) -> Result<()> {
        // Tied-group all-reduce: overwrite local grads with the global sum.
        let s = self.stage();
        for (i, sp) in self.man.stages[s].params.iter().enumerate() {
            if let Some(g) = &sp.tie_group {
                if let Some((_, sum)) = tied.iter().find(|(n, _)| n == g) {
                    self.grads[i] = sum.clone();
                }
            }
        }
        let exe = self.rt.get("adam")?;
        let step = HostTensor::scalar(step as f32).to_literal()?;
        let lr = HostTensor::scalar(lr).to_literal()?;
        let sc = HostTensor::scalar(scale).to_literal()?;
        let mut lits: Vec<xla::Literal> = Vec::new();
        for t in self.params.iter().chain(&self.grads).chain(&self.adam_m).chain(&self.adam_v) {
            lits.push(t.to_literal()?);
        }
        let mut args: Vec<&xla::Literal> = vec![&step, &lr, &sc];
        args.extend(lits.iter());
        let out = exe.run(&args)?;
        let n = self.params.len();
        for i in 0..n {
            self.params[i] = HostTensor::from_literal(&out[i])?;
            self.adam_m[i] = HostTensor::from_literal(&out[n + i])?;
            self.adam_v[i] = HostTensor::from_literal(&out[2 * n + i])?;
        }
        Ok(())
    }

    fn eval(&mut self, mb: MicrobatchData) -> Result<()> {
        let plits = self.param_literals()?;
        let in_lit = if self.is_first() {
            mb.tokens.to_literal()?
        } else {
            self.inbox.recv(Tag::Fwd(usize::MAX - 1)).to_literal()?
        };
        let t_lit = mb.targets.to_literal()?;
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&in_lit);
        args.push(&t_lit);
        let out = self.rt.get("eval")?.run(&args)?;
        let x_out = HostTensor::from_literal(&out[0])?;
        if let Some(next) = &self.to_next {
            next.send(Tag::Fwd(usize::MAX - 1), x_out);
        }
        let losses = if self.has_losses_output {
            HostTensor::from_literal(&out[1])?
                .data
                .iter()
                .map(|&x| x as f64)
                .collect()
        } else {
            Vec::new()
        };
        self.replies
            .send(Reply::EvalDone { stage: self.stage(), losses })
            .ok();
        Ok(())
    }
}

/// Tracking for fill-microbatch progress within one iteration.
#[derive(Default)]
struct FillState {
    fwd_done: usize,
    bwd_done: usize,
    contributions: usize,
    stash: Vec<(usize, Stash)>,
}

impl FillState {
    /// Index of the next fill microbatch whose forward this stage still
    /// owes, in order.
    fn next_fwd(&self, cmd: &IterationCmd, s: usize) -> Option<usize> {
        cmd.fills
            .iter()
            .enumerate()
            .filter(|(_, (spec, _))| spec.fwd_covers(s))
            .map(|(j, _)| j)
            .nth(self.fwd_done)
    }

    fn next_bwd(&self, cmd: &IterationCmd, s: usize) -> Option<usize> {
        // Backward only for fills whose forward is already done locally.
        let candidate = cmd
            .fills
            .iter()
            .enumerate()
            .filter(|(_, (spec, _))| spec.bwd_covers(s))
            .map(|(j, _)| j)
            .nth(self.bwd_done)?;
        if self.stash.iter().any(|(id, _)| *id == candidate) {
            Some(candidate)
        } else {
            None
        }
    }

    fn finished(&self, cmd: &IterationCmd, s: usize) -> bool {
        let fwds = cmd
            .fills
            .iter()
            .filter(|(spec, _)| spec.fwd_covers(s))
            .count();
        let bwds = cmd
            .fills
            .iter()
            .filter(|(spec, _)| spec.bwd_covers(s))
            .count();
        self.fwd_done == fwds && self.bwd_done == bwds && self.stash.len()
            == fwds - bwds
    }
}
