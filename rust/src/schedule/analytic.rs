//! Appendix A.3 closed forms: iteration-time upper bound and per-stage
//! peak-memory estimate, in the paper's exact notation. Tests pin the
//! discrete-event simulator to these formulas.

use super::costs::CostModel;

/// Appendix A.3.1: upper bound on time per iteration for a 1F1B schedule
/// with `exits[i]` early exits on stage i and M microbatches.
pub fn time_upper_bound(c: &CostModel, exits: &[usize], m: usize) -> f64 {
    let p = c.stages;
    assert_eq!(exits.len(), p);
    // Parts 1 & 3: f_IN + b_IN + (P-1)(f_BB + b_BB) + sum_{i<P-1} N_i (f_EE+b_EE)
    let mut t = c.f_in + c.b_in + (p as f64 - 1.0) * (c.f_bb + c.b_bb);
    for (i, &n) in exits.iter().enumerate() {
        if i < p - 1 {
            t += n as f64 * (c.f_ee + c.b_ee);
        }
    }
    // Part 2: M * max_i { stage fwd+bwd incl. IN/FE/EE terms }.
    let mut worst: f64 = 0.0;
    for (i, &n) in exits.iter().enumerate() {
        let mut s = c.f_bb + c.b_bb + n as f64 * (c.f_ee + c.b_ee);
        if i == 0 {
            s += c.f_in + c.b_in;
        }
        if i == p - 1 {
            s += c.f_fe + c.b_fe;
        }
        worst = worst.max(s);
    }
    t + m as f64 * worst
}

/// Appendix A.3.2 (Eq. 4-6): estimated peak memory of stage i (0-based),
/// with Optimization 1 applied (exit activations counted once).
pub fn stage_memory(c: &CostModel, exits: &[usize], i: usize) -> f64 {
    let p = c.stages;
    let n_i = exits[i] as f64;
    let first = (i == 0) as u8 as f64;
    let last = (i == p - 1) as u8 as f64;
    let params = c.m_bb + first * c.m_in + last * c.m_fe + n_i * c.m_ee;
    let acts = (p - i) as f64 * c.a_bb
        + first * p as f64 * c.a_in
        + last * c.a_fe
        + n_i * c.a_ee;
    c.alpha * params + acts
}

/// Peak over stages.
pub fn peak_memory(c: &CostModel, exits: &[usize]) -> f64 {
    (0..c.stages)
        .map(|i| stage_memory(c, exits, i))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::costs::{CostModel, PAPER_MODELS};
    use crate::schedule::plan::{EeOptions, Plan};
    use crate::schedule::sim::Simulator;
    use crate::util::proptest::check;

    fn sim_time(c: &CostModel, exits: Vec<usize>, m: usize) -> f64 {
        let plan =
            Plan::one_f_one_b(c.stages, m, EeOptions::with_exits(exits, true));
        Simulator::new(c).run(&plan).iteration_time
    }

    #[test]
    fn analytic_time_bound_holds_and_is_tight_for_paper_models() {
        for dims in &PAPER_MODELS {
            for pp in [2usize, 4, 8] {
                let c = CostModel::a100(dims, pp, 1);
                for exits in [vec![0; pp], {
                    let mut e = vec![0; pp];
                    if pp > 2 {
                        e[1] = 1;
                    }
                    e
                }] {
                    let m = 2 * pp;
                    let bound = time_upper_bound(&c, &exits, m);
                    let sim = sim_time(&c, exits.clone(), m);
                    assert!(
                        sim <= bound * (1.0 + 1e-9),
                        "{} pp={pp}: sim {sim} > bound {bound}",
                        dims.name
                    );
                    // For these (last-stage-bottleneck) settings the bound
                    // is exact.
                    assert!(
                        (sim - bound).abs() / bound < 1e-9,
                        "{} pp={pp}: sim {sim} != bound {bound}",
                        dims.name
                    );
                }
            }
        }
    }

    #[test]
    fn analytic_time_bound_property() {
        // Random cost models + exit layouts: simulator never exceeds the
        // Appendix A.3 bound.
        check("sim <= analytic bound", 64, |rng| {
            let p = 2 + rng.below(5);
            let m = p + rng.below(8);
            let mut c = CostModel::a100(&PAPER_MODELS[0], 4, 1);
            // Perturb costs to break the f_IN < f_FE regularities.
            c.stages = p;
            c.f_in = rng.uniform() * 0.01;
            c.b_in = rng.uniform() * 0.02;
            c.f_bb = 0.01 + rng.uniform() * 0.05;
            c.b_bb = 2.0 * c.f_bb;
            c.f_ee = rng.uniform() * 0.02;
            c.b_ee = 2.0 * c.f_ee;
            c.f_fe = rng.uniform() * 0.02;
            c.b_fe = 2.0 * c.f_fe;
            let exits: Vec<usize> = (0..p).map(|_| rng.below(3)).collect();
            let bound = time_upper_bound(&c, &exits, m);
            let sim = sim_time(&c, exits.clone(), m);
            if sim > bound * (1.0 + 1e-9) {
                return Err(format!(
                    "sim {sim} > bound {bound} (p={p}, m={m}, exits={exits:?})"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn analytic_memory_matches_simulator() {
        let c = CostModel::a100(&PAPER_MODELS[1], 4, 1);
        for exits in [vec![0, 0, 0, 0], vec![0, 1, 1, 0], vec![1, 2, 0, 1]] {
            let plan = Plan::one_f_one_b(
                4,
                8,
                EeOptions::with_exits(exits.clone(), true),
            );
            let r = Simulator::new(&c).run(&plan);
            for i in 0..4 {
                let want = stage_memory(&c, &exits, i);
                let got = r.peak_memory(c.alpha, i);
                assert!(
                    (got - want).abs() / want < 1e-9,
                    "stage {i} exits {exits:?}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn memory_condition_of_section_3_2() {
        // Peak memory is unchanged by a middle exit as long as s*b*V <
        // activation memory of all backbone layers in one stage (paper's
        // mild condition) and no exit sits on stage 0.
        let c = CostModel::a100(&PAPER_MODELS[1], 4, 1);
        assert!(c.a_ee < c.a_bb, "condition violated for 7B/4pp");
        let base = peak_memory(&c, &[0, 0, 0, 0]);
        let mid = peak_memory(&c, &[0, 1, 0, 0]);
        assert_eq!(base, mid);
        // An exit on stage 0 *does* move the peak.
        let first = peak_memory(&c, &[1, 0, 0, 0]);
        assert!(first > base);
    }
}
