//! Schedule plans: explicit per-stage op lists for 1F1B and GPipe, with the
//! paper's early-exit options.
//!
//! A [`Plan`] is, per stage, an in-order *main* op queue (the classical
//! schedule) plus an optional *fill* queue (Appendix C.2 partial
//! microbatches) that the simulator runs opportunistically inside bubbles.

use super::costs::ExitLayout;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    OneFOneB,
    GPipe,
}

/// Early-exit scheduling options under study (Table 1 ablation).
#[derive(Debug, Clone)]
pub struct EeOptions {
    pub exits: ExitLayout,
    /// Optimization 1 (Appendix A.2): run exit-layer forwards inside the
    /// backward step, so exit logits never persist across in-flight
    /// microbatches.
    pub defer_exit_fwd: bool,
}

impl EeOptions {
    pub fn none(stages: usize) -> EeOptions {
        EeOptions { exits: ExitLayout::none(stages), defer_exit_fwd: true }
    }

    pub fn with_exits(exits_per_stage: Vec<usize>, defer: bool) -> EeOptions {
        EeOptions {
            exits: ExitLayout { exits_per_stage },
            defer_exit_fwd: defer,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Forward of microbatch m.
    Fwd(usize),
    /// Backward of microbatch m.
    Bwd(usize),
    /// Bubble-fill forward of fill-microbatch j (Appendix C.2).
    FillFwd(usize),
    /// Bubble-fill (possibly truncated) backward of fill-microbatch j.
    FillBwd(usize),
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Op {
    pub kind: OpKind,
}

/// A fill microbatch's stage coverage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FillSpec {
    /// Forward runs on stages [0, fwd_stages).
    pub fwd_stages: usize,
    /// Backward runs on stages [fwd_stages - bwd_stages, fwd_stages).
    pub bwd_stages: usize,
}

#[derive(Debug, Clone)]
pub struct Plan {
    pub schedule: Schedule,
    pub stages: usize,
    pub microbatches: usize,
    pub opts: EeOptions,
    /// Main (classical) op queue per stage, in execution order.
    pub main: Vec<Vec<Op>>,
    /// Opportunistic fill queue per stage, in execution order.
    pub fill: Vec<Vec<Op>>,
    /// Stage coverage of each fill microbatch.
    pub fill_specs: Vec<FillSpec>,
}

impl Plan {
    /// The classical 1F1B (PipeDream-Flush) plan: stage s performs
    /// `min(M, P-1-s)` warm-up forwards, a steady 1F1B phase, and a
    /// cool-down of trailing backwards (paper Figure 3).
    pub fn one_f_one_b(stages: usize, microbatches: usize, opts: EeOptions) -> Plan {
        assert!(stages >= 1 && microbatches >= 1);
        assert!(
            microbatches >= stages,
            "1F1B requires M >= P for a steady phase (paper setting)"
        );
        let mut main = Vec::with_capacity(stages);
        for s in 0..stages {
            let warmup = (stages - 1 - s).min(microbatches);
            let mut ops = Vec::new();
            for m in 0..warmup {
                ops.push(Op { kind: OpKind::Fwd(m) });
            }
            let mut next_f = warmup;
            let mut next_b = 0;
            while next_b < microbatches {
                if next_f < microbatches {
                    ops.push(Op { kind: OpKind::Fwd(next_f) });
                    next_f += 1;
                }
                ops.push(Op { kind: OpKind::Bwd(next_b) });
                next_b += 1;
            }
            main.push(ops);
        }
        Plan {
            schedule: Schedule::OneFOneB,
            stages,
            microbatches,
            opts,
            main,
            fill: vec![Vec::new(); stages],
            fill_specs: Vec::new(),
        }
    }

    /// GPipe baseline: all forwards, then all backwards.
    pub fn gpipe(stages: usize, microbatches: usize, opts: EeOptions) -> Plan {
        let mut main = Vec::with_capacity(stages);
        for _ in 0..stages {
            let mut ops = Vec::new();
            for m in 0..microbatches {
                ops.push(Op { kind: OpKind::Fwd(m) });
            }
            for m in 0..microbatches {
                ops.push(Op { kind: OpKind::Bwd(m) });
            }
            main.push(ops);
        }
        Plan {
            schedule: Schedule::GPipe,
            stages,
            microbatches,
            opts,
            main,
            fill: vec![Vec::new(); stages],
            fill_specs: Vec::new(),
        }
    }

    /// Add Appendix C.2 bubble-fill microbatches.
    ///
    /// Part 1 (warm-up bubble): `k1` microbatches; the j-th (0-based) runs
    /// forward through the first `k1 - j` stages, then backward through
    /// them (early-exit losses only).
    /// Part 2 (cool-down bubble): `k2` microbatches; each runs the full
    /// forward, then a truncated backward over the last
    /// `floor(P - (j+1)*(fb_ratio+1))` stages.
    pub fn add_bubble_fill(&mut self, k1: usize, k2: usize, fb_ratio: f64) {
        let p = self.stages;
        for j in 0..k1 {
            let cover = p.min(k1 - j);
            if cover == 0 {
                continue;
            }
            let id = self.fill_specs.len();
            self.fill_specs.push(FillSpec { fwd_stages: cover, bwd_stages: cover });
            for s in 0..cover {
                self.fill[s].push(Op { kind: OpKind::FillFwd(id) });
            }
            for s in (0..cover).rev() {
                self.fill[s].push(Op { kind: OpKind::FillBwd(id) });
            }
        }
        for j in 0..k2 {
            let depth_f = p as f64 - (j as f64 + 1.0) * (1.0 / fb_ratio + 1.0);
            let bwd = depth_f.floor().max(0.0) as usize;
            let id = self.fill_specs.len();
            self.fill_specs.push(FillSpec { fwd_stages: p, bwd_stages: bwd });
            for s in 0..p {
                self.fill[s].push(Op { kind: OpKind::FillFwd(id) });
            }
            for s in (p - bwd..p).rev() {
                self.fill[s].push(Op { kind: OpKind::FillBwd(id) });
            }
        }
    }

    /// Maximum fill microbatches per bubble part without delaying the
    /// iteration: floor((P-1) / (f/b + 1)) — Appendix C.2.
    pub fn max_fill(stages: usize, fb_ratio: f64) -> usize {
        // fb_ratio = b/f; the paper states (p-1)*b / (f+b) = (p-1)/(f/b+1).
        (((stages - 1) as f64) / (1.0 / fb_ratio + 1.0)).floor() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count(ops: &[Op], pred: impl Fn(&OpKind) -> bool) -> usize {
        ops.iter().filter(|o| pred(&o.kind)).count()
    }

    #[test]
    fn one_f_one_b_structure() {
        let p = Plan::one_f_one_b(4, 6, EeOptions::none(4));
        for s in 0..4 {
            assert_eq!(count(&p.main[s], |k| matches!(k, OpKind::Fwd(_))), 6);
            assert_eq!(count(&p.main[s], |k| matches!(k, OpKind::Bwd(_))), 6);
        }
        // Stage 0 warm-up is P-1 = 3 forwards.
        let heads: Vec<_> = p.main[0][..3].iter().map(|o| o.kind).collect();
        assert_eq!(
            heads,
            vec![OpKind::Fwd(0), OpKind::Fwd(1), OpKind::Fwd(2)]
        );
        // Last stage alternates F,B from the start.
        assert_eq!(p.main[3][0].kind, OpKind::Fwd(0));
        assert_eq!(p.main[3][1].kind, OpKind::Bwd(0));
    }

    #[test]
    fn one_f_one_b_in_flight_bound() {
        // At any prefix of stage s's op list, (#fwd - #bwd) <= P - s:
        // the 1F1B memory bound (P - i + 1 in-flight, 1-based).
        let stages = 4;
        let p = Plan::one_f_one_b(stages, 8, EeOptions::none(stages));
        for s in 0..stages {
            let mut inflight: i64 = 0;
            for op in &p.main[s] {
                match op.kind {
                    OpKind::Fwd(_) => inflight += 1,
                    OpKind::Bwd(_) => inflight -= 1,
                    _ => {}
                }
                assert!(inflight <= (stages - s) as i64, "stage {s}");
                assert!(inflight >= 0);
            }
            assert_eq!(inflight, 0);
        }
    }

    #[test]
    fn bwd_follows_fwd_per_microbatch() {
        let p = Plan::one_f_one_b(3, 5, EeOptions::none(3));
        for s in 0..3 {
            for m in 0..5 {
                let fi = p.main[s]
                    .iter()
                    .position(|o| o.kind == OpKind::Fwd(m))
                    .unwrap();
                let bi = p.main[s]
                    .iter()
                    .position(|o| o.kind == OpKind::Bwd(m))
                    .unwrap();
                assert!(fi < bi);
            }
        }
    }

    #[test]
    #[should_panic(expected = "M >= P")]
    fn rejects_too_few_microbatches() {
        Plan::one_f_one_b(4, 2, EeOptions::none(4));
    }

    #[test]
    fn gpipe_runs_all_fwds_first() {
        let p = Plan::gpipe(2, 3, EeOptions::none(2));
        let kinds: Vec<_> = p.main[0].iter().map(|o| o.kind).collect();
        assert_eq!(
            kinds,
            vec![
                OpKind::Fwd(0),
                OpKind::Fwd(1),
                OpKind::Fwd(2),
                OpKind::Bwd(0),
                OpKind::Bwd(1),
                OpKind::Bwd(2)
            ]
        );
    }

    #[test]
    fn fill_part1_covers_decreasing_prefixes() {
        let mut p = Plan::one_f_one_b(4, 6, EeOptions::none(4));
        p.add_bubble_fill(2, 0, 2.0);
        assert_eq!(p.fill_specs.len(), 2);
        assert_eq!(p.fill_specs[0].fwd_stages, 2);
        assert_eq!(p.fill_specs[1].fwd_stages, 1);
        // Stage 0 sees both fills; stage 2 sees none.
        assert_eq!(p.fill[0].len(), 4); // 2 fwd + 2 bwd
        assert_eq!(p.fill[2].len(), 0);
    }

    #[test]
    fn fill_part2_truncates_backward() {
        let mut p = Plan::one_f_one_b(4, 6, EeOptions::none(4));
        p.add_bubble_fill(0, 1, 2.0);
        let spec = p.fill_specs[0];
        assert_eq!(spec.fwd_stages, 4);
        // floor(4 - 1*(0.5+1)) = floor(2.5) = 2 backward stages.
        assert_eq!(spec.bwd_stages, 2);
        assert_eq!(p.fill[0].len(), 1); // fwd only
        assert_eq!(p.fill[3].len(), 2); // fwd + bwd
    }

    #[test]
    fn max_fill_matches_paper_formula() {
        // P=4, f/b = 0.5 -> floor(3 / 1.5) = 2.
        assert_eq!(Plan::max_fill(4, 2.0), 2);
        assert_eq!(Plan::max_fill(8, 2.0), 4);
        assert_eq!(Plan::max_fill(2, 2.0), 0);
    }
}
