//! Per-op cost model in the paper's Appendix A notation (Table 2).
//!
//! For each op class o in {IN, BB, EE, FE} we carry:
//!   f_o / b_o      — forward / backward seconds per microbatch,
//!   m_o            — parameter bytes,
//!   a_o            — activation bytes stashed per in-flight microbatch.
//!
//! Values derive from GPT dimensions by FLOP counting against an effective
//! device throughput (A100-class by default, so the Figure 7/9/Table 1
//! *shapes* land in the paper's regime; absolute seconds are not the
//! claim). Tensor parallelism divides compute and per-device parameters —
//! it is orthogonal to every early-exit contribution and is modelled only
//! here, exactly as the paper treats it.

/// GPT model dimensions (paper Section 5.1 sizes are presets below).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GptDims {
    pub name: &'static str,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub vocab: usize,
    pub seq: usize,
    /// Microbatch size.
    pub mb: usize,
}

/// The model sizes of the paper's training-efficiency study (Figure 7):
/// 1.3B / 7B / 13B / 30B GPT variants (GPT-3-family shapes), with the
/// paper's sequence length 2048 and microbatch sizes (2 for 1.3B/7B, 1 for
/// 13B/30B) and a 50k vocabulary.
pub const PAPER_MODELS: [GptDims; 4] = [
    GptDims { name: "1.3B", hidden: 2048, layers: 24, heads: 16, vocab: 50304, seq: 2048, mb: 2 },
    GptDims { name: "7B", hidden: 4096, layers: 32, heads: 32, vocab: 50304, seq: 2048, mb: 2 },
    GptDims { name: "13B", hidden: 5120, layers: 40, heads: 40, vocab: 50304, seq: 2048, mb: 1 },
    GptDims { name: "30B", hidden: 7168, layers: 48, heads: 56, vocab: 50304, seq: 2048, mb: 1 },
];

impl GptDims {
    pub fn param_count(&self) -> usize {
        let h = self.hidden;
        self.vocab * h + self.seq * h + self.layers * (12 * h * h + 13 * h)
            + 2 * h + h * self.vocab
    }
}

/// Where an early exit's compute lands, per stage (derived from a config's
/// exit list + placement option).
#[derive(Debug, Clone, PartialEq)]
pub struct ExitLayout {
    /// Number of early exits whose compute runs on each stage.
    pub exits_per_stage: Vec<usize>,
}

impl ExitLayout {
    pub fn none(stages: usize) -> ExitLayout {
        ExitLayout { exits_per_stage: vec![0; stages] }
    }

    pub fn total(&self) -> usize {
        self.exits_per_stage.iter().sum()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    pub stages: usize,
    /// Forward/backward seconds per microbatch.
    pub f_in: f64,
    pub b_in: f64,
    pub f_bb: f64,
    pub b_bb: f64,
    pub f_ee: f64,
    pub b_ee: f64,
    pub f_fe: f64,
    pub b_fe: f64,
    /// Parameter bytes per op.
    pub m_in: f64,
    pub m_bb: f64,
    pub m_ee: f64,
    pub m_fe: f64,
    /// Activation bytes stashed per in-flight microbatch.
    pub a_in: f64,
    pub a_bb: f64,
    /// Early-exit logits bytes (s*b*V*4): the Appendix A.2 quantity.
    pub a_ee: f64,
    pub a_fe: f64,
    /// Optimizer multiplier: bytes(params+grads+opt state)/bytes(params).
    pub alpha: f64,
    /// P2P latency between adjacent stages per tensor (0 in the paper's
    /// analysis; exposed for sensitivity studies).
    pub p2p: f64,
}

impl CostModel {
    /// Build from GPT dims for a (pipeline, tensor)-parallel layout.
    ///
    /// `eff_flops` is the effective per-device throughput in FLOP/s
    /// (compute-bound ops); `mem_bw` the effective HBM bandwidth used for
    /// the (bandwidth-bound) embedding input layer.
    pub fn from_gpt(dims: &GptDims, pp: usize, tp: usize, eff_flops: f64) -> CostModel {
        assert!(pp >= 1 && tp >= 1);
        assert_eq!(dims.layers % pp, 0, "layers must divide stages");
        let h = dims.hidden as f64;
        let s = dims.seq as f64;
        let b = dims.mb as f64;
        let v = dims.vocab as f64;
        let lps = (dims.layers / pp) as f64;
        let tpf = tp as f64;

        // FLOPs per microbatch (forward): one transformer layer is
        // 24*s*b*h^2 GEMM FLOPs + 4*s^2*b*h attention-score FLOPs.
        let layer_f = (24.0 * s * b * h * h + 4.0 * s * s * b * h) / tpf;
        // Exit / final head: unembedding GEMM 2*s*b*h*V (+ fused CE, minor).
        let head_f = 2.0 * s * b * h * v / tpf;
        // Input layer: embedding gather + pos add — bandwidth-ish; model as
        // a small fraction of a head (the paper's f_IN < f_FE assumption).
        let in_f = 0.1 * head_f;

        let to_t = |flops: f64| flops / eff_flops;
        let f_bb = to_t(lps * layer_f);
        let f_fe = to_t(head_f);
        let f_in = to_t(in_f);
        let f_ee = f_fe; // minimalistic exit == final head structure

        // Parameter bytes (fp16/bf16 weights -> 2 bytes in Megatron; we use
        // 4-byte f32 to match our runtime; only ratios matter).
        let bytes = 4.0;
        let m_bb = lps * (12.0 * h * h + 13.0 * h) / tpf * bytes;
        let m_fe = (h * v / tpf + 2.0 * h) * bytes;
        let m_ee = m_fe;
        let m_in = (v * h + s * h) / tpf * bytes;

        // Activation bytes stashed per microbatch (no recomputation,
        // Korthikanti-style per-layer footprint ~ s*b*h*(34 + 5*s*a/h)
        // per layer; we keep the GEMM-dominant 34*s*b*h term).
        let a_bb = lps * 34.0 * s * b * h / tpf * bytes;
        let a_ee = s * b * v / tpf * bytes; // the s*b*V logits of App. A.2
        let a_fe = a_ee;
        let a_in = s * b * h * bytes;

        CostModel {
            stages: pp,
            f_in,
            b_in: 2.0 * f_in,
            f_bb,
            b_bb: 2.0 * f_bb,
            f_ee,
            b_ee: 2.0 * f_ee,
            f_fe,
            b_fe: 2.0 * f_fe,
            m_in,
            m_bb,
            m_ee,
            m_fe,
            a_in,
            a_bb,
            a_ee,
            a_fe,
            // Adam fp32 states + grads + params (Megatron mixed precision
            // uses ~20 bytes/param; with uniform f32 it is 4x params).
            alpha: 4.0,
            p2p: 0.0,
        }
    }

    /// A100-class default throughput (312 TFLOP/s bf16 at ~45% MFU).
    pub fn a100(dims: &GptDims, pp: usize, tp: usize) -> CostModel {
        CostModel::from_gpt(dims, pp, tp, 140e12)
    }

    /// Forward seconds of one microbatch on `stage`, with `n_exits` early
    /// exits computed eagerly on it (0 when deferred — Optimization 1).
    pub fn stage_fwd(&self, stage: usize, eager_exits: usize) -> f64 {
        let mut t = self.f_bb + eager_exits as f64 * self.f_ee;
        if stage == 0 {
            t += self.f_in;
        }
        if stage == self.stages - 1 {
            t += self.f_fe;
        }
        t
    }

    /// Backward seconds of one microbatch on `stage`; `exits` early exits
    /// live on it; `deferred_exits` of them also run their *forward* here
    /// (Optimization 1 moves exit forwards into the backward step).
    pub fn stage_bwd(&self, stage: usize, exits: usize, deferred_exits: usize) -> f64 {
        let mut t = self.b_bb
            + exits as f64 * self.b_ee
            + deferred_exits as f64 * self.f_ee;
        if stage == 0 {
            t += self.b_in;
        }
        if stage == self.stages - 1 {
            t += self.b_fe;
        }
        t
    }

    /// Parameter bytes on `stage` with `n_exits` early exits.
    pub fn stage_param_bytes(&self, stage: usize, n_exits: usize) -> f64 {
        let mut m = self.m_bb + n_exits as f64 * self.m_ee;
        if stage == 0 {
            m += self.m_in;
        }
        if stage == self.stages - 1 {
            m += self.m_fe;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m7b() -> GptDims {
        PAPER_MODELS[1]
    }

    #[test]
    fn paper_param_counts_are_plausible() {
        // Within 15% of the nominal sizes.
        for (dims, nominal) in PAPER_MODELS.iter().zip([1.3e9, 7e9, 13e9, 30e9])
        {
            let n = dims.param_count() as f64;
            assert!(
                (n / nominal - 1.0).abs() < 0.30,
                "{}: {n:.3e} vs {nominal:.1e}",
                dims.name
            );
        }
    }

    #[test]
    fn last_stage_is_slowest_without_exits() {
        let cm = CostModel::a100(&m7b(), 4, 1);
        let f_last = cm.stage_fwd(3, 0);
        for s in 0..3 {
            assert!(cm.stage_fwd(s, 0) < f_last);
        }
        // The paper's f_IN < f_FE assumption.
        assert!(cm.f_in < cm.f_fe);
    }

    #[test]
    fn one_exit_balances_middle_stage_to_last() {
        // Adding one minimalistic exit to a middle stage makes its compute
        // match the last stage's (implicit-bubble utilisation, Section 3.2).
        let cm = CostModel::a100(&m7b(), 4, 1);
        let mid = cm.stage_fwd(1, 1);
        let last = cm.stage_fwd(3, 0);
        assert!((mid - last).abs() / last < 0.01, "{mid} vs {last}");
    }

    #[test]
    fn tp_divides_compute() {
        let cm1 = CostModel::a100(&m7b(), 4, 1);
        let cm4 = CostModel::a100(&m7b(), 4, 4);
        assert!((cm1.f_bb / cm4.f_bb - 4.0).abs() < 1e-9);
        assert!((cm1.m_fe / cm4.m_fe - 4.0).abs() < 0.2);
    }

    #[test]
    fn exit_logits_memory_matches_formula() {
        let d = m7b();
        let cm = CostModel::a100(&d, 4, 1);
        let want = (d.seq * d.mb * d.vocab * 4) as f64;
        assert_eq!(cm.a_ee, want);
    }

    #[test]
    fn backward_is_twice_forward() {
        let cm = CostModel::a100(&m7b(), 4, 1);
        assert!((cm.b_bb / cm.f_bb - 2.0).abs() < 1e-12);
        assert!((cm.b_ee / cm.f_ee - 2.0).abs() < 1e-12);
    }
}
