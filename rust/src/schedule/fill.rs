//! Bubble-fill planning and the Proposition C.2 statistics.
//!
//! Planning: how many partial microbatches fit in the warm-up (Part 1) and
//! cool-down (Part 2) bubbles without delaying the iteration, and how deep
//! each truncated backward reaches — the Appendix C.2 formulas, used both
//! by the simulator ablation (figc bench) and the real training runtime.
//!
//! Statistics: the paper proves the extra truncated-backward gradients
//! leave the estimator unbiased (after a B/(B+1) rescale) with variance
//! reduced by var(a)/(N(N+1)) + 2cov(a,b)/(N(N+1)) (Prop. C.2). We expose
//! the closed form and verify it by Monte-Carlo in the tests.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FillPlan {
    /// Microbatches inserted into the warm-up bubble (Part 1).
    pub k1: usize,
    /// Microbatches inserted into the cool-down bubble (Part 2).
    pub k2: usize,
    /// backward/forward time ratio the plan assumed.
    pub bf_ratio: f64,
}

impl FillPlan {
    /// The Appendix C.2 capacity: floor((P-1)*b/(f+b)) per bubble part.
    pub fn plan(stages: usize, bf_ratio: f64, requested: usize) -> FillPlan {
        let cap = (((stages.saturating_sub(1)) as f64)
            / (1.0 / bf_ratio + 1.0))
            .floor() as usize;
        FillPlan { k1: requested.min(cap), k2: requested.min(cap), bf_ratio }
    }

    /// Backward depth (stages) of the j-th (0-based) Part-2 microbatch.
    pub fn part2_bwd_depth(&self, stages: usize, j: usize) -> usize {
        let d = stages as f64 - (j as f64 + 1.0) * (1.0 / self.bf_ratio + 1.0);
        d.floor().max(0.0) as usize
    }

    /// The gradient rescale restoring unbiasedness when `extra` additional
    /// microbatches contribute to a parameter group that normally sees
    /// `base` microbatches: scale = base / (base + extra) applied on top of
    /// the usual 1/base averaging (Appendix C.2.2).
    pub fn unbias_scale(base: usize, extra: usize) -> f64 {
        base as f64 / (base + extra) as f64
    }
}

/// Closed-form variance reduction of Proposition C.2:
/// var(e_hat) - var(e_hat_plus) = var(a)/(N(N+1)) + 2 cov(a,b)/(N(N+1)).
pub fn prop_c2_variance_reduction(var_a: f64, cov_ab: f64, n: usize) -> f64 {
    let nn = (n * (n + 1)) as f64;
    var_a / nn + 2.0 * cov_ab / nn
}

/// Monte-Carlo estimate of (var(e_hat), var(e_hat_plus)) for correlated
/// Gaussian (a, b) pairs — used to validate the closed form and to power
/// the figc bench.
pub fn monte_carlo_variance_reduction(
    rng: &mut Rng,
    n: usize,
    rho: f64,
    trials: usize,
) -> (f64, f64) {
    let mut e = Vec::with_capacity(trials);
    let mut ep = Vec::with_capacity(trials);
    for _ in 0..trials {
        let mut asum = 0.0;
        let mut bsum = 0.0;
        for _ in 0..n {
            let (a, b) = corr_pair(rng, rho);
            asum += a;
            bsum += b;
        }
        let (a_extra, _) = corr_pair(rng, rho);
        e.push(asum / n as f64 + bsum / n as f64);
        ep.push((asum + a_extra) / (n + 1) as f64 + bsum / n as f64);
    }
    (variance(&e), variance(&ep))
}

fn corr_pair(rng: &mut Rng, rho: f64) -> (f64, f64) {
    let x = rng.normal();
    let y = rng.normal();
    (x, rho * x + (1.0 - rho * rho).sqrt() * y)
}

fn variance(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_respects_capacity() {
        // P=4, b/f = 2 -> cap = floor(3/1.5) = 2.
        let p = FillPlan::plan(4, 2.0, 10);
        assert_eq!((p.k1, p.k2), (2, 2));
        let p = FillPlan::plan(4, 2.0, 1);
        assert_eq!((p.k1, p.k2), (1, 1));
        let p = FillPlan::plan(1, 2.0, 5);
        assert_eq!((p.k1, p.k2), (0, 0));
    }

    #[test]
    fn part2_depths_match_paper_example() {
        let p = FillPlan::plan(4, 2.0, 2);
        // floor(4 - 1*1.5) = 2; floor(4 - 2*1.5) = 1.
        assert_eq!(p.part2_bwd_depth(4, 0), 2);
        assert_eq!(p.part2_bwd_depth(4, 1), 1);
    }

    #[test]
    fn unbias_scale() {
        assert!((FillPlan::unbias_scale(8, 1) - 8.0 / 9.0).abs() < 1e-12);
        assert_eq!(FillPlan::unbias_scale(8, 0), 1.0);
    }

    #[test]
    fn prop_c2_closed_form_matches_monte_carlo() {
        let mut rng = Rng::new(11);
        let n = 8;
        for rho in [0.0, 0.5, -0.3] {
            let (v, vp) =
                monte_carlo_variance_reduction(&mut rng, n, rho, 200_000);
            let got = v - vp;
            // var(a)=1, cov(a,b)=rho for standardised pairs.
            let want = prop_c2_variance_reduction(1.0, rho, n);
            assert!(
                (got - want).abs() < 0.02,
                "rho={rho}: mc {got} vs closed {want}"
            );
        }
    }

    #[test]
    fn variance_increases_only_under_strong_negative_correlation() {
        // The paper's caveat: reduction is negative iff cov < -var(a)/2.
        assert!(prop_c2_variance_reduction(1.0, -0.6, 4) < 0.0);
        assert!(prop_c2_variance_reduction(1.0, -0.4, 4) > 0.0);
    }
}
