//! Pipeline-schedule analysis: the quantitative half of the paper's
//! training-efficiency claims (Section 3.2, Appendix A, Figures 3/7/9,
//! Table 1), as a discrete-event simulator over explicit per-stage op
//! lists plus the closed-form formulas of Appendix A.3.
//!
//! - [`costs`] — per-op cost model (Table 2 notation: f/b/m/m-dagger for
//!   IN, BB, EE, FE) derived from GPT dimensions, with the paper's model
//!   sizes (1.3B/7B/13B/30B) as presets.
//! - [`plan`] — op-list builders: 1F1B (PipeDream-Flush) and GPipe, with
//!   the early-exit options under study: exit placement (Optimization 2),
//!   deferred exit-forward (Optimization 1), bubble filling (Appendix C.2).
//! - [`sim`] — the discrete-event executor: computes per-stage timelines,
//!   iteration time, bubble fractions, and peak-memory profiles.
//! - [`analytic`] — Appendix A.3 closed forms; property tests pin the
//!   simulator to them.
//! - [`fill`] — bubble-fill planning (how many extra microbatches fit) and
//!   the Proposition C.2 variance analysis.
//! - [`report`] — ASCII timeline rendering (Figure 3-style).

pub mod analytic;
pub mod costs;
pub mod fill;
pub mod plan;
pub mod report;
pub mod sim;

pub use costs::{CostModel, GptDims, PAPER_MODELS};
pub use plan::{EeOptions, Plan, Schedule};
pub use sim::{SimResult, Simulator};
