//! ASCII rendering of simulated schedules — the Figure 3 visualisation.
//!
//! Each stage becomes one row; time is discretised into character cells.
//! Forward blocks render as the microbatch digit, backward blocks as
//! letters (A = microbatch 0); fills render as 'f'/'b'.

use super::plan::OpKind;
use super::sim::SimResult;

/// Render the timeline with roughly `width` character columns.
pub fn render_timeline(result: &SimResult, width: usize) -> String {
    let t_end = result.iteration_time.max(1e-12);
    let scale = width as f64 / t_end;
    let mut out = String::new();
    for (s, tl) in result.timelines.iter().enumerate() {
        let mut row = vec![' '; width + 1];
        for p in &tl.ops {
            let a = (p.start * scale).round() as usize;
            let b = ((p.end * scale).round() as usize).max(a + 1);
            let ch = match p.op.kind {
                OpKind::Fwd(m) => (b'0' + (m % 10) as u8) as char,
                OpKind::Bwd(m) => (b'A' + (m % 26) as u8) as char,
                OpKind::FillFwd(_) => 'f',
                OpKind::FillBwd(_) => 'b',
            };
            for cell in row.iter_mut().take(b.min(width)).skip(a) {
                *cell = ch;
            }
        }
        out.push_str(&format!("stage {s} |"));
        out.push_str(&row.into_iter().collect::<String>());
        out.push_str("|\n");
    }
    out.push_str(&format!(
        "iteration = {:.3}ms, bubble fraction = {:.1}%\n",
        result.iteration_time * 1e3,
        result.bubble_fraction() * 100.0
    ));
    out
}

#[cfg(test)]
mod tests {
    use crate::schedule::costs::{CostModel, PAPER_MODELS};
    use crate::schedule::plan::{EeOptions, Plan};
    use crate::schedule::sim::Simulator;

    #[test]
    fn renders_all_stages() {
        let c = CostModel::a100(&PAPER_MODELS[0], 4, 1);
        let plan = Plan::one_f_one_b(4, 6, EeOptions::none(4));
        let r = Simulator::new(&c).run(&plan);
        let txt = super::render_timeline(&r, 80);
        assert_eq!(txt.matches("stage ").count(), 4);
        assert!(txt.contains("bubble fraction"));
        // Forward microbatch 0 appears on every stage.
        for line in txt.lines().take(4) {
            assert!(line.contains('0'), "{line}");
        }
    }

    #[test]
    fn fills_render_distinctly() {
        let c = CostModel::a100(&PAPER_MODELS[0], 4, 1);
        let mut plan = Plan::one_f_one_b(4, 8, EeOptions::none(4));
        plan.add_bubble_fill(2, 2, 2.0);
        let r = Simulator::new(&c).run(&plan);
        let txt = super::render_timeline(&r, 100);
        assert!(txt.contains('f'), "{txt}");
    }
}
