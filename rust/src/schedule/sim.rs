//! Discrete-event execution of a [`Plan`] under a [`CostModel`].
//!
//! Each stage executes its main op queue strictly in order; fill ops run
//! opportunistically *only when provably harmless*: a fill op starts iff
//! its dependencies are met and it finishes before the stage's next main
//! op could start anyway (the Appendix C.2 guarantee of "no time
//! overhead"). Dependencies are the pipeline's P2P edges:
//!
//!   Fwd(m)@s  needs Fwd(m)@s-1;   Bwd(m)@s needs Bwd(m)@s+1
//!   (last stage's Bwd(m) needs its own Fwd(m))
//!
//! The simulator also tracks per-stage activation memory through time
//! (stash on forward, release on backward, transient exit logits per
//! Optimization 1) and reports peaks — the Figure 7/9/Table 1 quantities.

use std::collections::BTreeMap;

use super::costs::CostModel;
use super::plan::{Op, OpKind, Plan};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placed {
    pub op: Op,
    pub start: f64,
    pub end: f64,
}

#[derive(Debug, Clone)]
pub struct StageTimeline {
    pub ops: Vec<Placed>,
    pub busy: f64,
    pub peak_activation_bytes: f64,
    pub param_bytes: f64,
}

#[derive(Debug, Clone)]
pub struct SimResult {
    /// Wall-clock of one training iteration (max op end).
    pub iteration_time: f64,
    pub timelines: Vec<StageTimeline>,
}

impl SimResult {
    pub fn bubble_fraction(&self) -> f64 {
        let total_busy: f64 = self.timelines.iter().map(|t| t.busy).sum();
        let capacity = self.iteration_time * self.timelines.len() as f64;
        1.0 - total_busy / capacity
    }

    /// Peak memory of stage s: optimizer-scaled params + activations.
    pub fn peak_memory(&self, alpha: f64, s: usize) -> f64 {
        let t = &self.timelines[s];
        alpha * t.param_bytes + t.peak_activation_bytes
    }

    pub fn peak_memory_overall(&self, alpha: f64) -> f64 {
        (0..self.timelines.len())
            .map(|s| self.peak_memory(alpha, s))
            .fold(0.0, f64::max)
    }

    pub fn bottleneck_stage(&self, alpha: f64) -> usize {
        (0..self.timelines.len())
            .max_by(|&a, &b| {
                self.peak_memory(alpha, a)
                    .partial_cmp(&self.peak_memory(alpha, b))
                    .unwrap()
            })
            .unwrap()
    }
}

pub struct Simulator<'a> {
    pub cost: &'a CostModel,
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
enum Key {
    Fwd(usize, usize),     // (stage, microbatch)
    Bwd(usize, usize),
    FillFwd(usize, usize), // (stage, fill id)
    FillBwd(usize, usize),
}

impl<'a> Simulator<'a> {
    pub fn new(cost: &'a CostModel) -> Simulator<'a> {
        Simulator { cost }
    }

    fn duration(&self, plan: &Plan, s: usize, kind: OpKind) -> f64 {
        let exits = plan.opts.exits.exits_per_stage[s];
        let (eager, deferred) = if plan.opts.defer_exit_fwd {
            (0, exits)
        } else {
            (exits, 0)
        };
        match kind {
            OpKind::Fwd(_) => self.cost.stage_fwd(s, eager),
            OpKind::Bwd(_) => self.cost.stage_bwd(s, exits, deferred),
            // Fill forwards run the backbone (+ eager exits) like a normal
            // forward but skip the final-exit head unless they reach the
            // last stage with a backward planned there.
            OpKind::FillFwd(_) => self.cost.stage_fwd(s, eager),
            OpKind::FillBwd(_) => self.cost.stage_bwd(s, exits, deferred),
        }
    }

    fn deps(plan: &Plan, s: usize, kind: OpKind) -> Vec<Key> {
        let last = plan.stages - 1;
        match kind {
            OpKind::Fwd(m) => {
                if s == 0 {
                    vec![]
                } else {
                    vec![Key::Fwd(s - 1, m)]
                }
            }
            OpKind::Bwd(m) => {
                if s == last {
                    vec![Key::Fwd(s, m)]
                } else {
                    vec![Key::Bwd(s + 1, m), Key::Fwd(s, m)]
                }
            }
            OpKind::FillFwd(j) => {
                if s == 0 {
                    vec![]
                } else {
                    vec![Key::FillFwd(s - 1, j)]
                }
            }
            OpKind::FillBwd(j) => {
                let spec = plan.fill_specs[j];
                let turnaround = spec.fwd_stages - 1;
                if s == turnaround {
                    vec![Key::FillFwd(s, j)]
                } else {
                    vec![Key::FillBwd(s + 1, j), Key::FillFwd(s, j)]
                }
            }
        }
    }

    fn key(s: usize, kind: OpKind) -> Key {
        match kind {
            OpKind::Fwd(m) => Key::Fwd(s, m),
            OpKind::Bwd(m) => Key::Bwd(s, m),
            OpKind::FillFwd(j) => Key::FillFwd(s, j),
            OpKind::FillBwd(j) => Key::FillBwd(s, j),
        }
    }

    /// Run the plan; panics on a malformed (deadlocking) plan.
    pub fn run(&self, plan: &Plan) -> SimResult {
        // With fill ops present, first simulate the main schedule alone to
        // obtain the iteration deadline fills must respect (Appendix C.2's
        // "no overhead" contract).
        let deadline = if plan.fill_specs.is_empty() {
            f64::INFINITY
        } else {
            let mut bare = plan.clone();
            bare.fill = vec![Vec::new(); plan.stages];
            bare.fill_specs.clear();
            self.run(&bare).iteration_time
        };
        self.run_with_deadline(plan, deadline)
    }

    fn run_with_deadline(&self, plan: &Plan, deadline: f64) -> SimResult {
        let p = plan.stages;
        let mut done: BTreeMap<Key, f64> = BTreeMap::new();
        let mut main_idx = vec![0usize; p];
        let mut fill_idx = vec![0usize; p];
        let mut free_at = vec![0f64; p];
        let mut placed: Vec<Vec<Placed>> = vec![Vec::new(); p];

        let ready = |done: &BTreeMap<Key, f64>, plan: &Plan, s: usize, kind: OpKind| -> Option<f64> {
            let mut t: f64 = 0.0;
            for d in Self::deps(plan, s, kind) {
                // Same-stage dependencies carry no P2P latency.
                let same_stage = matches!(
                    (d, kind),
                    (Key::Fwd(ds, _), OpKind::Bwd(_)) if ds == s
                ) || matches!(
                    (d, kind),
                    (Key::FillFwd(ds, _), OpKind::FillBwd(_)) if ds == s
                );
                let lat = if same_stage { 0.0 } else { self.cost.p2p };
                match done.get(&d) {
                    Some(&e) => t = t.max(e + lat),
                    None => return None,
                }
            }
            Some(t)
        };

        loop {
            let mut progressed = false;
            let mut all_done = true;
            for s in 0..p {
                let main_op = plan.main[s].get(main_idx[s]).copied();
                let fill_op = plan.fill[s].get(fill_idx[s]).copied();
                if main_op.is_some() || fill_op.is_some() {
                    all_done = false;
                }

                // Candidate start of the next main op (None if deps unknown).
                let main_ready =
                    main_op.and_then(|op| ready(&done, plan, s, op.kind));

                // Try a harmless fill first.
                if let (Some(fop), Some(fready)) = (
                    fill_op,
                    fill_op.and_then(|op| ready(&done, plan, s, op.kind)),
                ) {
                    let fstart = free_at[s].max(fready);
                    let fend = fstart + self.duration(plan, s, fop.kind);
                    let harmless = fend <= deadline * (1.0 + 1e-12)
                        && match (main_op, main_ready) {
                            (None, _) => true,
                            (Some(_), Some(mr)) => fend <= free_at[s].max(mr),
                            (Some(_), None) => false,
                        };
                    if harmless {
                        done.insert(Self::key(s, fop.kind), fend);
                        placed[s].push(Placed { op: fop, start: fstart, end: fend });
                        free_at[s] = fend;
                        fill_idx[s] += 1;
                        progressed = true;
                        continue;
                    }
                }

                if let (Some(mop), Some(mready)) = (main_op, main_ready) {
                    let start = free_at[s].max(mready);
                    let end = start + self.duration(plan, s, mop.kind);
                    done.insert(Self::key(s, mop.kind), end);
                    placed[s].push(Placed { op: mop, start, end });
                    free_at[s] = end;
                    main_idx[s] += 1;
                    progressed = true;
                }
            }
            if all_done {
                break;
            }
            if !progressed {
                // Remaining fill ops that can never run harmlessly are
                // dropped (the planner over-provisioned) unless main ops
                // remain, which would be a real deadlock.
                let mains_left: usize =
                    (0..p).map(|s| plan.main[s].len() - main_idx[s]).sum();
                if mains_left > 0 {
                    panic!("schedule deadlock: {mains_left} main ops stuck");
                }
                break;
            }
        }

        // Memory replay: walk each stage's placed ops in time order.
        let mut timelines = Vec::with_capacity(p);
        for s in 0..p {
            let exits = plan.opts.exits.exits_per_stage[s];
            let c = self.cost;
            let mut cur = 0.0f64;
            let mut peak = 0.0f64;
            let mut busy = 0.0;
            for pl in &placed[s] {
                busy += pl.end - pl.start;
                match pl.op.kind {
                    OpKind::Fwd(_) | OpKind::FillFwd(_) => {
                        cur += c.a_bb;
                        if s == 0 {
                            cur += c.a_in;
                        }
                        if s == p - 1 {
                            cur += c.a_fe;
                        }
                        if !plan.opts.defer_exit_fwd {
                            // Eager exit logits persist until backward.
                            cur += exits as f64 * c.a_ee;
                        }
                        peak = peak.max(cur);
                    }
                    OpKind::Bwd(_) | OpKind::FillBwd(_) => {
                        if plan.opts.defer_exit_fwd {
                            // Transient logits live only inside the
                            // backward step (Optimization 1).
                            peak = peak.max(cur + exits as f64 * c.a_ee);
                        }
                        cur -= c.a_bb;
                        if s == 0 {
                            cur -= c.a_in;
                        }
                        if s == p - 1 {
                            cur -= c.a_fe;
                        }
                        if !plan.opts.defer_exit_fwd {
                            cur -= exits as f64 * c.a_ee;
                        }
                        cur = cur.max(0.0);
                    }
                }
            }
            timelines.push(StageTimeline {
                ops: placed[s].clone(),
                busy,
                peak_activation_bytes: peak,
                param_bytes: c.stage_param_bytes(s, exits),
            });
        }

        let iteration_time = timelines
            .iter()
            .flat_map(|t| t.ops.iter())
            // Fill ops by construction never extend the iteration; still
            // include them (they are <= the last main op's end).
            .map(|o| o.end)
            .fold(0.0, f64::max);

        SimResult { iteration_time, timelines }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::costs::{CostModel, PAPER_MODELS};
    use crate::schedule::plan::{EeOptions, Plan};

    fn cm(pp: usize) -> CostModel {
        CostModel::a100(&PAPER_MODELS[1], pp, 1)
    }

    #[test]
    fn simple_1f1b_matches_closed_form_without_heads() {
        // With uniform per-stage cost f, b and no IN/FE/EE terms, the 1F1B
        // iteration time is (P-1+M)*(f+b).
        let mut c = cm(4);
        c.f_in = 0.0;
        c.b_in = 0.0;
        c.f_fe = 0.0;
        c.b_fe = 0.0;
        let plan = Plan::one_f_one_b(4, 6, EeOptions::none(4));
        let r = Simulator::new(&c).run(&plan);
        let want = (4.0 - 1.0 + 6.0) * (c.f_bb + c.b_bb);
        assert!(
            (r.iteration_time - want).abs() / want < 1e-9,
            "{} vs {want}",
            r.iteration_time
        );
    }

    #[test]
    fn gpipe_is_slower_or_equal_to_1f1b_in_time_and_memory() {
        let c = cm(4);
        let p1 = Plan::one_f_one_b(4, 8, EeOptions::none(4));
        let pg = Plan::gpipe(4, 8, EeOptions::none(4));
        let s = Simulator::new(&c);
        let r1 = s.run(&p1);
        let rg = s.run(&pg);
        // Same compute: iteration times equal under no contention...
        assert!(rg.iteration_time >= r1.iteration_time - 1e-9);
        // ...but GPipe stashes all M microbatches -> strictly more memory.
        assert!(
            rg.timelines[0].peak_activation_bytes
                > r1.timelines[0].peak_activation_bytes * 1.5
        );
    }

    #[test]
    fn middle_exits_cost_exactly_k_times_fee_plus_bee() {
        // The Section 3.2 claim: k middle-stage exits increase iteration
        // time by exactly k*(f_EE + b_EE) when implicit bubbles absorb the
        // steady-phase work.
        let c = cm(4);
        let s = Simulator::new(&c);
        let base = s
            .run(&Plan::one_f_one_b(4, 8, EeOptions::none(4)))
            .iteration_time;
        for k in 1..=2usize {
            let mut exits = vec![0; 4];
            for i in 0..k {
                exits[1 + i] = 1; // middle stages
            }
            let t = s
                .run(&Plan::one_f_one_b(4, 8, EeOptions::with_exits(exits, true)))
                .iteration_time;
            let want = base + k as f64 * (c.f_ee + c.b_ee);
            assert!(
                (t - want).abs() / want < 1e-9,
                "k={k}: {t} vs {want}"
            );
        }
    }

    #[test]
    fn first_stage_is_memory_bottleneck() {
        let c = cm(4);
        let plan = Plan::one_f_one_b(4, 8, EeOptions::none(4));
        let r = Simulator::new(&c).run(&plan);
        assert_eq!(r.bottleneck_stage(c.alpha), 0);
    }

    #[test]
    fn deferral_shrinks_exit_logit_memory() {
        let c = cm(4);
        let s = Simulator::new(&c);
        let eager = s.run(&Plan::one_f_one_b(
            4,
            8,
            EeOptions::with_exits(vec![0, 1, 0, 0], false),
        ));
        let deferred = s.run(&Plan::one_f_one_b(
            4,
            8,
            EeOptions::with_exits(vec![0, 1, 0, 0], true),
        ));
        // Stage 1 holds P-1 = 3 in-flight microbatches: eager stashes
        // 3 copies of the exit logits, deferral keeps only 1 (transient).
        let diff = eager.timelines[1].peak_activation_bytes
            - deferred.timelines[1].peak_activation_bytes;
        assert!(
            (diff - 2.0 * c.a_ee).abs() / c.a_ee < 1e-9,
            "diff {diff}, a_ee {}",
            c.a_ee
        );
    }

    #[test]
    fn deferred_middle_exit_keeps_peak_memory_unchanged() {
        // The headline memory claim (Section 3.2): with deferral and a
        // middle-stage exit, the *overall* peak (stage 0) is unchanged.
        let c = cm(4);
        let s = Simulator::new(&c);
        let base = s.run(&Plan::one_f_one_b(4, 8, EeOptions::none(4)));
        let ee = s.run(&Plan::one_f_one_b(
            4,
            8,
            EeOptions::with_exits(vec![0, 1, 1, 0], true),
        ));
        assert_eq!(base.bottleneck_stage(c.alpha), 0);
        assert_eq!(ee.bottleneck_stage(c.alpha), 0);
        assert!(
            (base.peak_memory_overall(c.alpha)
                - ee.peak_memory_overall(c.alpha))
            .abs()
                < 1.0
        );
    }

    #[test]
    fn bubble_fill_adds_no_iteration_time() {
        let c = cm(4);
        let s = Simulator::new(&c);
        let base = s
            .run(&Plan::one_f_one_b(4, 8, EeOptions::none(4)))
            .iteration_time;
        let mut plan = Plan::one_f_one_b(4, 8, EeOptions::none(4));
        let k = Plan::max_fill(4, 2.0);
        plan.add_bubble_fill(k, k, 2.0);
        let r = s.run(&plan);
        assert!(
            r.iteration_time <= base + 1e-9,
            "{} vs {base}",
            r.iteration_time
        );
        // And fill ops actually ran somewhere.
        let fills: usize = r
            .timelines
            .iter()
            .flat_map(|t| t.ops.iter())
            .filter(|p| {
                matches!(
                    p.op.kind,
                    super::OpKind::FillFwd(_) | super::OpKind::FillBwd(_)
                )
            })
            .count();
        assert!(fills > 0, "no fill ops were scheduled");
    }

    #[test]
    fn bubble_fraction_decreases_with_more_microbatches() {
        let c = cm(4);
        let s = Simulator::new(&c);
        let r8 = s.run(&Plan::one_f_one_b(4, 8, EeOptions::none(4)));
        let r32 = s.run(&Plan::one_f_one_b(4, 32, EeOptions::none(4)));
        assert!(r32.bubble_fraction() < r8.bubble_fraction());
    }
}
