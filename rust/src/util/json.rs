//! Minimal JSON parser + writer for the artifact manifests and checkpoints.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP (manifests are ASCII). Numbers parse as f64; helpers coerce.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors (with the key name) when missing.
    pub fn field(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("expected number"))
            })
            .collect()
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (valid UTF-8 passes through).
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"o":{"k":true}}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::Str("héllo → wörld".into());
        let parsed = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, parsed);
    }
}
