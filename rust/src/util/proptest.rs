//! Minimal property-testing harness (offline stand-in for `proptest`).
//!
//! Runs a property against many seeded-random cases; on failure it reports
//! the failing case number and seed so the case can be replayed by
//! constructing the same `Rng`.

use super::rng::Rng;

pub const DEFAULT_CASES: usize = 128;

/// Check `prop(rng)` for `cases` random cases. `prop` returns
/// `Err(description)` to signal a counterexample.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base_seed: u64 = 0xEE11E;
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 parity", 32, |rng| {
            let x = rng.next_u64();
            if x % 2 == 0 || x % 2 == 1 {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn reports_counterexample() {
        check("always false", 4, |_| Err("nope".into()));
    }
}
