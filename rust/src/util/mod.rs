//! Self-contained utility substrates (the build is fully offline, so JSON
//! parsing, RNG, CLI parsing, property testing, and table rendering are all
//! implemented here rather than pulled from crates.io).

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod table;
