//! Tiny CLI argument parser: `--key value`, `--flag`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `flag_names` lists options that take no value.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                    i += 1;
                } else if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                    i += 1;
                } else if i + 1 < argv.len() {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.push(name.to_string());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, flag_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            &v(&["train", "--steps", "10", "--lr=0.1", "--verbose", "x"]),
            &["verbose"],
        );
        assert_eq!(a.positional, v(&["train", "x"]));
        assert_eq!(a.usize_or("steps", 0), 10);
        assert_eq!(a.f64_or("lr", 0.0), 0.1);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_option_without_value_is_flag() {
        let a = Args::parse(&v(&["--dry-run"]), &[]);
        assert!(a.flag("dry-run"));
    }
}
