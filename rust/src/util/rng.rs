//! Deterministic RNG (xoshiro256++ seeded via splitmix64) with normal
//! sampling — parameter initialisation and data generation must be fully
//! reproducible from a seed, with no external crates.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller transform.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (e.g. one per parameter tensor).
    pub fn fork(&self, tag: u64) -> Rng {
        let mut sm = self.s[0] ^ tag.wrapping_mul(0xA24BAED4963EE407);
        Rng::new(splitmix64(&mut sm))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Rejection sampling to avoid modulo bias.
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample an index proportionally to the given non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let r = Rng::new(7);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
