//! Downstream evaluation tasks — the HELM-analogue suite for Figure 8.
//!
//! Six tasks mirroring the paper's benchmark mix (four QA-style scored with
//! EM or token-F1, two summarisation-style scored with ROUGE-L), generated
//! from the same synthetic world the model was pre-trained on:
//!
//! | paper task          | analogue here        | metric  |
//! |---------------------|----------------------|---------|
//! | BoolQ               | `fact_bool`          | EM      |
//! | TruthfulQA          | `arithmetic`         | EM      |
//! | NaturalQuestions-cb | `fact_qa`            | F1      |
//! | NaturalQuestions-ob | `fact_qa_openbook`   | F1      |
//! | XSUM                | `summary`            | ROUGE-L |
//! | CNN/DailyMail       | `copy_summary`       | ROUGE-L |

use crate::util::rng::Rng;

use super::synth::{fact_sentence, qa_pair, Corpus, Fact};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    ExactMatch,
    TokenF1,
    RougeL,
}

#[derive(Debug, Clone)]
pub struct EvalExample {
    pub prompt: String,
    pub reference: String,
}

#[derive(Debug, Clone)]
pub struct EvalTask {
    pub name: &'static str,
    pub metric: Metric,
    pub examples: Vec<EvalExample>,
    /// Generation budget per example.
    pub max_new_tokens: usize,
}

fn pick<'a>(rng: &mut Rng, facts: &'a [Fact]) -> &'a Fact {
    &facts[rng.below(facts.len())]
}

pub fn fact_qa(corpus: &Corpus, n: usize, seed: u64) -> EvalTask {
    let mut rng = Rng::new(seed);
    let examples = (0..n)
        .map(|_| {
            let f = pick(&mut rng, &corpus.facts);
            let (q, a) = qa_pair(f);
            EvalExample { prompt: q, reference: a.trim().to_string() }
        })
        .collect();
    EvalTask {
        name: "fact_qa",
        metric: Metric::TokenF1,
        examples,
        max_new_tokens: 12,
    }
}

pub fn fact_qa_openbook(corpus: &Corpus, n: usize, seed: u64) -> EvalTask {
    let mut rng = Rng::new(seed ^ 0xB00C);
    let examples = (0..n)
        .map(|_| {
            let f = pick(&mut rng, &corpus.facts);
            let (q, a) = qa_pair(f);
            // Open-book: the supporting fact precedes the question.
            EvalExample {
                prompt: format!("{} {}", fact_sentence(f, 0), q),
                reference: a.trim().to_string(),
            }
        })
        .collect();
    EvalTask {
        name: "fact_qa_openbook",
        metric: Metric::TokenF1,
        examples,
        max_new_tokens: 12,
    }
}

pub fn fact_bool(corpus: &Corpus, n: usize, seed: u64) -> EvalTask {
    let mut rng = Rng::new(seed ^ 0xB001);
    let examples = (0..n)
        .map(|_| {
            let f = pick(&mut rng, &corpus.facts);
            let truthy = rng.below(2) == 0;
            let value = if truthy {
                f.value.to_string()
            } else {
                // A wrong value of the same relation.
                let mut other = f.value;
                for g in &corpus.facts {
                    if g.relation == f.relation && g.value != f.value {
                        other = g.value;
                        break;
                    }
                }
                other.to_string()
            };
            EvalExample {
                prompt: format!(
                    "question: is the {} of {} {}? answer:",
                    f.relation, f.entity, value
                ),
                reference: (if truthy { "yes" } else { "no" }).to_string(),
            }
        })
        .collect();
    EvalTask {
        name: "fact_bool",
        metric: Metric::ExactMatch,
        examples,
        max_new_tokens: 4,
    }
}

pub fn arithmetic(n: usize, seed: u64) -> EvalTask {
    let mut rng = Rng::new(seed ^ 0xA417);
    let examples = (0..n)
        .map(|_| {
            let a = rng.below(10);
            let b = rng.below(10);
            EvalExample {
                prompt: format!("{a}+{b}="),
                reference: format!("{}", a + b),
            }
        })
        .collect();
    EvalTask {
        name: "arithmetic",
        metric: Metric::ExactMatch,
        examples,
        max_new_tokens: 4,
    }
}

pub fn summary(corpus: &Corpus, n: usize, seed: u64) -> EvalTask {
    let mut rng = Rng::new(seed ^ 0x5E44);
    let entities: Vec<String> = {
        let mut v: Vec<String> =
            corpus.facts.iter().map(|f| f.entity.clone()).collect();
        v.dedup();
        v
    };
    let examples = (0..n)
        .map(|_| {
            let e = &entities[rng.below(entities.len())];
            let ef: Vec<&Fact> =
                corpus.facts.iter().filter(|f| &f.entity == e).collect();
            let body: Vec<String> = ef
                .iter()
                .enumerate()
                .map(|(i, f)| fact_sentence(f, i))
                .collect();
            EvalExample {
                prompt: format!("{} summary:", body.join(" ")),
                reference: fact_sentence(ef[0], 0),
            }
        })
        .collect();
    EvalTask {
        name: "summary",
        metric: Metric::RougeL,
        examples,
        max_new_tokens: 48,
    }
}

pub fn copy_summary(corpus: &Corpus, n: usize, seed: u64) -> EvalTask {
    let mut rng = Rng::new(seed ^ 0xC0B1);
    let examples = (0..n)
        .map(|_| {
            let f = pick(&mut rng, &corpus.facts);
            let text = fact_sentence(f, rng.below(3));
            EvalExample {
                prompt: format!("copy: {text} |"),
                reference: text,
            }
        })
        .collect();
    EvalTask {
        name: "copy_summary",
        metric: Metric::RougeL,
        examples,
        max_new_tokens: 64,
    }
}

/// The full Figure-8 suite.
pub fn all_tasks(corpus: &Corpus, n_per_task: usize, seed: u64) -> Vec<EvalTask> {
    vec![
        fact_bool(corpus, n_per_task, seed),
        arithmetic(n_per_task, seed),
        fact_qa(corpus, n_per_task, seed),
        fact_qa_openbook(corpus, n_per_task, seed),
        summary(corpus, n_per_task, seed),
        copy_summary(corpus, n_per_task, seed),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::CorpusSpec;

    fn corpus() -> Corpus {
        Corpus::build(&CorpusSpec { seed: 2, n_entities: 8, target_bytes: 10_000 })
    }

    #[test]
    fn suite_has_six_tasks() {
        let c = corpus();
        let tasks = all_tasks(&c, 5, 1);
        assert_eq!(tasks.len(), 6);
        for t in &tasks {
            assert_eq!(t.examples.len(), 5, "{}", t.name);
            for e in &t.examples {
                assert!(!e.prompt.is_empty() && !e.reference.is_empty());
            }
        }
    }

    #[test]
    fn fact_qa_references_are_kb_values() {
        let c = corpus();
        let t = fact_qa(&c, 20, 3);
        for e in &t.examples {
            assert!(
                c.facts.iter().any(|f| f.value == e.reference),
                "{e:?}"
            );
        }
    }

    #[test]
    fn bool_task_is_balancedish() {
        let c = corpus();
        let t = fact_bool(&c, 100, 5);
        let yes = t.examples.iter().filter(|e| e.reference == "yes").count();
        assert!(yes > 25 && yes < 75, "yes={yes}");
    }

    #[test]
    fn tasks_are_deterministic() {
        let c = corpus();
        let a = summary(&c, 4, 9);
        let b = summary(&c, 4, 9);
        for (x, y) in a.examples.iter().zip(&b.examples) {
            assert_eq!(x.prompt, y.prompt);
        }
    }
}
