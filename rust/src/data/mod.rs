//! Data substrates: byte-level tokenizer, synthetic corpus generators, the
//! training dataset/batcher, and downstream eval task generators.
//!
//! The paper pre-trains on a Data-Juicer corpus and evaluates with HELM;
//! neither is available offline, so we substitute a deterministic synthetic
//! corpus with controlled difficulty structure (see [`synth`]) and a task
//! suite scored with the same metric family (EM / token-F1 / ROUGE-L, see
//! [`tasks`] and [`crate::eval`]). DESIGN.md documents why this preserves
//! the behaviours under study (loss-convergence shape; confidence-threshold
//! speed/quality trade-off).

pub mod dataset;
pub mod synth;
pub mod tasks;
pub mod tokenizer;

pub use dataset::{Dataset, TrainBatch};
pub use synth::{
    bursty_traffic, conversation_traffic, ConvoSpec, ConvoTurn, Corpus,
    CorpusSpec, TrafficRequest, TrafficSpec,
};
pub use tokenizer::{ByteTokenizer, BOS_ID, EOS_ID, PAD_ID, VOCAB_SIZE};
