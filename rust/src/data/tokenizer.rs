//! Byte-level tokenizer with PAD/BOS/EOS specials.
//!
//! Token ids 0..255 are raw bytes; ids must match `python/compile/configs.py`
//! (PAD=256, BOS=257, EOS=258; vocab padded to 320 for GEMM-friendly tiling
//! in the fused exit-loss kernel).

pub const PAD_ID: i32 = 256;
pub const BOS_ID: i32 = 257;
pub const EOS_ID: i32 = 258;
pub const VOCAB_SIZE: usize = 320;

#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    pub fn encode_with_bos(&self, text: &str) -> Vec<i32> {
        let mut v = Vec::with_capacity(text.len() + 1);
        v.push(BOS_ID);
        v.extend(text.bytes().map(|b| b as i32));
        v
    }

    /// Decode, skipping specials; invalid UTF-8 is replaced.
    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_special(&self, id: i32) -> bool {
        !(0..256).contains(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let ids = t.encode("hello, world!");
        assert_eq!(t.decode(&ids), "hello, world!");
        assert!(ids.iter().all(|&i| i < 256));
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer;
        let s = "héllo → wörld";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_are_skipped_on_decode() {
        let t = ByteTokenizer;
        let mut ids = t.encode_with_bos("ab");
        ids.push(EOS_ID);
        ids.push(PAD_ID);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn vocab_ids_in_range() {
        assert!(PAD_ID < VOCAB_SIZE as i32);
        assert!(BOS_ID < VOCAB_SIZE as i32);
        assert!(EOS_ID < VOCAB_SIZE as i32);
    }
}
