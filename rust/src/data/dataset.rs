//! Document packing and microbatch assembly.
//!
//! Documents are concatenated with BOS separators into a single token
//! stream (GPT-style packing), then sliced into (tokens, targets) examples
//! of the training sequence length with next-token targets. Batches are
//! drawn with a deterministic shuffled cursor so runs are reproducible and
//! "same data, same order" comparisons across model variants (the paper's
//! Section 5.1 methodology) hold.

use crate::runtime::tensor::IntTensor;
use crate::util::rng::Rng;

use super::tokenizer::{ByteTokenizer, BOS_ID};
use super::synth::Corpus;

#[derive(Debug, Clone)]
pub struct TrainBatch {
    /// (microbatch, seq) input token ids.
    pub tokens: IntTensor,
    /// (microbatch, seq) next-token targets (PAD marks ignored positions —
    /// none are produced by packing, but padding-aware losses allow it).
    pub targets: IntTensor,
}

#[derive(Debug, Clone)]
pub struct Dataset {
    stream: Vec<i32>,
    pub seq: usize,
    pub microbatch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl Dataset {
    pub fn from_corpus(
        corpus: &Corpus,
        seq: usize,
        microbatch: usize,
        seed: u64,
    ) -> Dataset {
        let tok = ByteTokenizer;
        let mut stream = Vec::new();
        for doc in &corpus.docs {
            stream.push(BOS_ID);
            stream.extend(tok.encode(doc));
        }
        Self::from_stream(stream, seq, microbatch, seed)
    }

    pub fn from_stream(
        stream: Vec<i32>,
        seq: usize,
        microbatch: usize,
        seed: u64,
    ) -> Dataset {
        assert!(stream.len() > seq + 1, "corpus smaller than one example");
        let n_examples = (stream.len() - 1) / seq;
        let mut order: Vec<usize> = (0..n_examples).collect();
        let mut rng = Rng::new(seed ^ 0xDA7A);
        rng.shuffle(&mut order);
        Dataset { stream, seq, microbatch, order, cursor: 0, rng }
    }

    pub fn n_examples(&self) -> usize {
        self.order.len()
    }

    /// Tokens consumed per microbatch.
    pub fn tokens_per_microbatch(&self) -> usize {
        self.seq * self.microbatch
    }

    fn example(&self, idx: usize) -> (&[i32], &[i32]) {
        let start = idx * self.seq;
        (
            &self.stream[start..start + self.seq],
            &self.stream[start + 1..start + self.seq + 1],
        )
    }

    /// Next microbatch; reshuffles at epoch boundaries.
    pub fn next_microbatch(&mut self) -> TrainBatch {
        let b = self.microbatch;
        let s = self.seq;
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        for _ in 0..b {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                let mut order = std::mem::take(&mut self.order);
                self.rng.shuffle(&mut order);
                self.order = order;
            }
            let (x, y) = self.example(self.order[self.cursor]);
            tokens.extend_from_slice(x);
            targets.extend_from_slice(y);
            self.cursor += 1;
        }
        TrainBatch {
            tokens: IntTensor::new(vec![b, s], tokens),
            targets: IntTensor::new(vec![b, s], targets),
        }
    }

    /// A fixed validation slice (never reshuffled): the last `n` examples.
    pub fn validation_batches(&self, n: usize) -> Vec<TrainBatch> {
        let b = self.microbatch;
        let s = self.seq;
        let total = self.order.len();
        let n = n.min(total / b.max(1));
        (0..n)
            .map(|i| {
                let mut tokens = Vec::with_capacity(b * s);
                let mut targets = Vec::with_capacity(b * s);
                for j in 0..b {
                    let idx = total - 1 - (i * b + j);
                    let (x, y) = self.example(idx);
                    tokens.extend_from_slice(x);
                    targets.extend_from_slice(y);
                }
                TrainBatch {
                    tokens: IntTensor::new(vec![b, s], tokens),
                    targets: IntTensor::new(vec![b, s], targets),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::CorpusSpec;

    fn tiny() -> Dataset {
        let corpus = Corpus::build(&CorpusSpec {
            seed: 1,
            n_entities: 6,
            target_bytes: 20_000,
        });
        Dataset::from_corpus(&corpus, 32, 2, 9)
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut ds = tiny();
        let b = ds.next_microbatch();
        assert_eq!(b.tokens.shape, vec![2, 32]);
        // For packed data the target at position i equals the token at
        // position i+1 within the same example.
        for row in 0..2 {
            for i in 0..31 {
                assert_eq!(
                    b.targets.data[row * 32 + i],
                    b.tokens.data[row * 32 + i + 1]
                );
            }
        }
    }

    #[test]
    fn batches_are_deterministic() {
        let mut a = tiny();
        let mut b = tiny();
        for _ in 0..5 {
            assert_eq!(a.next_microbatch().tokens, b.next_microbatch().tokens);
        }
    }

    #[test]
    fn epoch_wraps_and_reshuffles() {
        let mut ds = tiny();
        let n = ds.n_examples();
        let first = ds.next_microbatch();
        // Exhaust the epoch.
        for _ in 0..(n / 2) {
            ds.next_microbatch();
        }
        let again = ds.next_microbatch();
        // Wrapping produced a fresh shuffle, not a repeat of batch 0
        // (astronomically unlikely to collide).
        assert_ne!(first.tokens, again.tokens);
    }

    #[test]
    fn validation_is_stable() {
        let ds = tiny();
        let v1 = ds.validation_batches(3);
        let v2 = ds.validation_batches(3);
        assert_eq!(v1.len(), 3);
        for (a, b) in v1.iter().zip(&v2) {
            assert_eq!(a.tokens, b.tokens);
        }
    }
}
