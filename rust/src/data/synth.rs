//! Synthetic pre-training corpus with controlled difficulty structure.
//!
//! Early-exit behaviour depends on the *mix* of easy and hard tokens: the
//! paper's Table 4 shows exits firing confidently on predictable
//! continuations ("ij"/"ing" of "Beijing") and deferring on content words.
//! The generators below reproduce that structure deterministically:
//!
//! - **Fact KB** — a fixed world of entities with attributes, verbalised
//!   through a handful of templates. Relation words and template glue are
//!   *easy* (high-confidence at shallow exits once learned); attribute
//!   values are *hard* (require the full model / memorisation).
//! - **QA pairs** — the same KB in question-answer format; teaches the
//!   format the eval harness probes (HELM closed-book QA analogue).
//! - **Patterns** — periodic sequences and alphabet/count runs: maximally
//!   easy tokens, the head of the difficulty distribution.
//! - **Arithmetic** — single/double-digit addition: format tokens easy,
//!   result digits hard-ish.
//! - **Copy** — `copy: <text> | <text>` lines; after the separator every
//!   token is predictable from context (easy given attention).
//! - **Summary** — multi-fact paragraphs followed by `summary:` and the
//!   lead fact (the XSUM/CNN-DM analogue used for ROUGE-L scoring).

use crate::util::rng::Rng;

const SYLLABLES: [&str; 20] = [
    "ba", "ce", "di", "fo", "gu", "ha", "ki", "lo", "mu", "na", "po", "qu",
    "ri", "sa", "tu", "ve", "wi", "xa", "yo", "zu",
];

const RELATIONS: [(&str, &[&str]); 4] = [
    ("capital", &["zarbon", "melka", "tirin", "ovask", "julep", "narok"]),
    ("color", &["red", "blue", "green", "amber", "violet", "teal"]),
    ("animal", &["lynx", "heron", "otter", "ibex", "finch", "viper"]),
    ("food", &["bread", "olives", "rice", "honey", "figs", "dates"]),
];

#[derive(Debug, Clone)]
pub struct Fact {
    pub entity: String,
    pub relation: &'static str,
    pub value: &'static str,
}

#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub seed: u64,
    pub n_entities: usize,
    /// Approximate corpus size in bytes.
    pub target_bytes: usize,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec { seed: 7, n_entities: 24, target_bytes: 1 << 20 }
    }
}

#[derive(Debug, Clone)]
pub struct Corpus {
    pub facts: Vec<Fact>,
    /// Documents (one logical text each); packed by `dataset`.
    pub docs: Vec<String>,
}

pub fn entity_name(rng: &mut Rng) -> String {
    let n = 2 + rng.below(2);
    (0..n).map(|_| SYLLABLES[rng.below(SYLLABLES.len())]).collect()
}

pub fn build_world(rng: &mut Rng, n_entities: usize) -> Vec<Fact> {
    let mut facts = Vec::new();
    let mut names = std::collections::BTreeSet::new();
    while names.len() < n_entities {
        names.insert(entity_name(rng));
    }
    for entity in names {
        for (relation, values) in RELATIONS {
            facts.push(Fact {
                entity: entity.clone(),
                relation,
                value: values[rng.below(values.len())],
            });
        }
    }
    facts
}

pub fn fact_sentence(f: &Fact, template: usize) -> String {
    match template % 3 {
        0 => format!("the {} of {} is {}.", f.relation, f.entity, f.value),
        1 => format!("{} has {} as its {}.", f.entity, f.value, f.relation),
        _ => format!("in {}, the {} is {}.", f.entity, f.relation, f.value),
    }
}

pub fn qa_pair(f: &Fact) -> (String, String) {
    (
        format!("question: what is the {} of {}? answer:", f.relation, f.entity),
        format!(" {}", f.value),
    )
}

fn pattern_doc(rng: &mut Rng) -> String {
    match rng.below(3) {
        0 => {
            // Periodic letter pattern, e.g. "xy zq xy zq ...".
            let a = SYLLABLES[rng.below(SYLLABLES.len())];
            let b = SYLLABLES[rng.below(SYLLABLES.len())];
            let unit = format!("{a} {b} ");
            unit.repeat(6 + rng.below(6)).trim_end().to_string()
        }
        1 => {
            let start = rng.below(20);
            let run: Vec<String> =
                (start..start + 10 + rng.below(10)).map(|i| i.to_string()).collect();
            format!("count: {}", run.join(" "))
        }
        _ => {
            let start = rng.below(16);
            let letters: String = (0..10)
                .map(|i| (b'a' + ((start + i) % 26) as u8) as char)
                .flat_map(|c| [c, ' '])
                .collect();
            format!("abc: {}", letters.trim_end())
        }
    }
}

fn arithmetic_doc(rng: &mut Rng) -> String {
    let mut lines = Vec::new();
    for _ in 0..4 + rng.below(5) {
        let a = rng.below(10);
        let b = rng.below(10);
        lines.push(format!("{a}+{b}={}.", a + b));
    }
    lines.join(" ")
}

fn copy_doc(rng: &mut Rng, facts: &[Fact]) -> String {
    let f = &facts[rng.below(facts.len())];
    let text = fact_sentence(f, rng.below(3));
    format!("copy: {text} | {text}")
}

fn summary_doc(rng: &mut Rng, facts: &[Fact]) -> String {
    // Pick one entity; list its facts; summary = the lead (capital) fact.
    let e = &facts[rng.below(facts.len())].entity.clone();
    let ef: Vec<&Fact> = facts.iter().filter(|f| &f.entity == e).collect();
    let body: Vec<String> =
        ef.iter().enumerate().map(|(i, f)| fact_sentence(f, i)).collect();
    format!("{} summary: {}", body.join(" "), fact_sentence(ef[0], 0))
}

/// Spec for the shared-system-prompt serving workload: templated traffic
/// where many requests repeat a long fixed prefix (the common case for
/// production serving, and the case prefix KV-cache reuse exists for).
#[derive(Debug, Clone)]
pub struct SharedPrefixSpec {
    pub seed: u64,
    /// Distinct system prompts.
    pub n_groups: usize,
    /// Requests sharing each system prompt.
    pub requests_per_group: usize,
    /// Byte budget for each system prompt; the generated prefix always
    /// stays strictly under it, so callers can bound prompt length
    /// against the KV-cache capacity.
    pub prefix_bytes: usize,
}

/// Build the workload's prompts: each is
/// `<system prompt> question: what is the <relation> of <entity>? answer:`
/// with the system prompt shared byte-for-byte inside a group. Prompts
/// are emitted round-robin across groups — the serving-realistic arrival
/// order, which also exercises a prefix store holding several groups at
/// once. Deterministic in the spec.
pub fn shared_prefix_prompts(
    spec: &SharedPrefixSpec,
    facts: &[Fact],
) -> Vec<String> {
    assert!(!facts.is_empty(), "shared-prefix workload needs a fact KB");
    let mut rng = Rng::new(spec.seed);
    let groups: Vec<String> = (0..spec.n_groups)
        .map(|g| {
            // The numbered tag keeps group prefixes distinct even when
            // the same facts are drawn.
            let mut sys = format!("system {g}:");
            loop {
                let f = &facts[rng.below(facts.len())];
                let s = fact_sentence(f, rng.below(3));
                if sys.len() + s.len() + 1 >= spec.prefix_bytes {
                    break;
                }
                sys.push(' ');
                sys.push_str(&s);
            }
            sys
        })
        .collect();
    let mut prompts =
        Vec::with_capacity(spec.n_groups * spec.requests_per_group);
    for _ in 0..spec.requests_per_group {
        for sys in &groups {
            let f = &facts[rng.below(facts.len())];
            let (q, _) = qa_pair(f);
            prompts.push(format!("{sys} {q}"));
        }
    }
    prompts
}

/// Spec for the bursty, diurnal, multi-tenant serving workload the SLO
/// control plane (preemption, shedding, weighted fairness) is
/// exercised against.
#[derive(Debug, Clone)]
pub struct TrafficSpec {
    pub seed: u64,
    pub n_requests: usize,
    /// Per-tenant arrival weights (tenant `i`'s share of traffic);
    /// empty = all traffic from tenant 0.
    pub tenants: Vec<f64>,
    /// Diurnal phase length in requests: urgency swings between a calm
    /// trough and a peak every `period` requests (0 = flat).
    pub period: usize,
    /// Arrivals come in tenant-coherent bursts of this many requests
    /// (1 = independent arrivals).
    pub burst_len: usize,
    /// Deadline bounds in milliseconds `(tight, loose)`: peak-phase
    /// requests draw toward `tight`, calm-phase toward `loose`.
    pub deadline_ms: (u64, u64),
    /// Fraction of requests carrying a deadline at all.
    pub deadline_rate: f64,
    /// Generation-budget bounds `(lo, hi)`, inclusive.
    pub max_new: (usize, usize),
    /// Prompt byte-budget bounds `(lo, hi)`, inclusive — prompts are
    /// QA questions over the fact KB padded with fact sentences, so
    /// lengths spread over the range (exercises SPF and the KV
    /// capacity edge).
    pub prompt_bytes: (usize, usize),
}

impl Default for TrafficSpec {
    fn default() -> TrafficSpec {
        TrafficSpec {
            seed: 17,
            n_requests: 64,
            tenants: vec![3.0, 1.0],
            period: 16,
            burst_len: 4,
            deadline_ms: (40, 400),
            deadline_rate: 0.6,
            max_new: (4, 16),
            prompt_bytes: (32, 160),
        }
    }
}

/// One request of the bursty workload, engine-agnostic: the serve CLI,
/// benches, and tests convert these to `ServeRequest`s (the data layer
/// must not depend on the serve layer).
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficRequest {
    pub prompt: String,
    pub max_new: usize,
    pub tenant: usize,
    /// Scheduling priority (peak-phase traffic occasionally raises it).
    pub priority: i32,
    /// Relative deadline in milliseconds; `None` = best-effort.
    pub deadline_ms: Option<u64>,
}

/// Build the bursty, diurnal, multi-tenant request stream.
/// Deterministic in the spec: tenants are drawn by weight once per
/// burst (tenant-coherent clusters), urgency follows a cosine diurnal
/// swing (peak-phase deadlines tighten toward the `tight` bound and
/// priorities rise), and prompts are KB questions padded to a drawn
/// byte budget.
pub fn bursty_traffic(
    spec: &TrafficSpec,
    facts: &[Fact],
) -> Vec<TrafficRequest> {
    assert!(!facts.is_empty(), "bursty workload needs a fact KB");
    let (dl_tight, dl_loose) = spec.deadline_ms;
    assert!(dl_tight <= dl_loose, "deadline bounds inverted");
    let (mn_lo, mn_hi) = spec.max_new;
    assert!(0 < mn_lo && mn_lo <= mn_hi, "max_new bounds invalid");
    let (pb_lo, pb_hi) = spec.prompt_bytes;
    assert!(pb_lo <= pb_hi, "prompt byte bounds inverted");
    let weights: Vec<f64> = if spec.tenants.is_empty() {
        vec![1.0]
    } else {
        spec.tenants.clone()
    };
    let mut rng = Rng::new(spec.seed);
    let burst = spec.burst_len.max(1);
    let mut out = Vec::with_capacity(spec.n_requests);
    let mut tenant = 0usize;
    for i in 0..spec.n_requests {
        if i % burst == 0 {
            tenant = rng.weighted(&weights);
        }
        // Diurnal swing in [0, 1]: 0 = calm trough, 1 = peak.
        let phase = if spec.period == 0 {
            0.5
        } else {
            let t = (i % spec.period) as f64 / spec.period as f64;
            0.5 - 0.5 * (t * std::f64::consts::TAU).cos()
        };
        let deadline_ms = if rng.uniform() < spec.deadline_rate {
            let span = (dl_loose - dl_tight) as f64;
            let jitter = rng.uniform() * 0.25;
            let frac = (1.0 - phase + jitter).clamp(0.0, 1.0);
            Some(dl_tight + (span * frac) as u64)
        } else {
            None
        };
        let priority =
            if phase > 0.75 && rng.below(4) == 0 { 1 } else { 0 };
        let max_new = rng.range(mn_lo, mn_hi + 1);
        let budget = rng.range(pb_lo.max(1), pb_hi.max(pb_lo) + 1);
        let f = &facts[rng.below(facts.len())];
        let (q, _) = qa_pair(f);
        let mut prompt = String::new();
        while prompt.len() + q.len() < budget {
            let pad = fact_sentence(
                &facts[rng.below(facts.len())],
                rng.below(3),
            );
            if prompt.len() + pad.len() + 1 + q.len() > budget {
                break;
            }
            prompt.push_str(&pad);
            prompt.push(' ');
        }
        prompt.push_str(&q);
        out.push(TrafficRequest {
            prompt,
            max_new,
            tenant,
            priority,
            deadline_ms,
        });
    }
    out
}

/// Spec for the multi-turn conversational serving workload: sessions of
/// several QA turns, each conversation opening with a system prompt
/// shared across conversations, with think-time gaps between turns and
/// mixed tenants — the traffic decode-time KV snapshots exist for.
#[derive(Debug, Clone)]
pub struct ConvoSpec {
    pub seed: u64,
    /// Conversations (chat sessions) in the workload.
    pub n_conversations: usize,
    /// Turns per conversation.
    pub turns: usize,
    /// Distinct system prompts; conversation `c` opens with system
    /// prompt `c % n_system`, so several conversations share each one.
    pub n_system: usize,
    /// Byte budget per system prompt; the generated prompt always stays
    /// strictly under it.
    pub system_bytes: usize,
    /// Per-tenant weights (a conversation keeps one tenant for all its
    /// turns); empty = all traffic from tenant 0.
    pub tenants: Vec<f64>,
    /// Per-turn generation-budget bounds `(lo, hi)`, inclusive.
    pub max_new: (usize, usize),
    /// Think-time bounds in milliseconds `(lo, hi)`, inclusive — the
    /// gap between a conversation's consecutive turns (0 on openers).
    pub think_ms: (u64, u64),
}

impl Default for ConvoSpec {
    fn default() -> ConvoSpec {
        ConvoSpec {
            seed: 29,
            n_conversations: 6,
            turns: 3,
            n_system: 2,
            system_bytes: 96,
            tenants: vec![3.0, 1.0],
            max_new: (4, 10),
            think_ms: (5, 40),
        }
    }
}

/// One turn of the conversational workload, engine-agnostic (the data
/// layer must not depend on the serve layer). `user_text` is this
/// turn's *new* text only: the opening turn carries the system prompt,
/// and the serving driver stitches each later turn's prompt as the
/// conversation's running history — every earlier turn's prompt plus
/// the response text the model actually generated — followed by
/// `user_text`, which is what makes the previous turn's end-of-turn
/// snapshot an exact prefix of it.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvoTurn {
    /// Conversation id, stable across the conversation's turns.
    pub conversation: u64,
    /// Turn index within the conversation (0 = opener).
    pub turn: usize,
    /// This turn's new text: `<system prompt> <question>` on the
    /// opener, ` <question>` afterwards.
    pub user_text: String,
    pub max_new: usize,
    pub tenant: usize,
    /// Think-time gap since the conversation's previous turn completed,
    /// in milliseconds (0 on the opener).
    pub think_ms: u64,
}

/// Build the multi-turn workload: one inner vector per conversation,
/// turns in order. Deterministic in the spec. Drivers typically serve
/// round `r` of every conversation as one batch (turn `r+1`'s prompt
/// needs turn `r`'s actual response), honoring `think_ms` via arrival
/// offsets.
pub fn conversation_traffic(
    spec: &ConvoSpec,
    facts: &[Fact],
) -> Vec<Vec<ConvoTurn>> {
    assert!(!facts.is_empty(), "conversation workload needs a fact KB");
    let (mn_lo, mn_hi) = spec.max_new;
    assert!(0 < mn_lo && mn_lo <= mn_hi, "max_new bounds invalid");
    let (tk_lo, tk_hi) = spec.think_ms;
    assert!(tk_lo <= tk_hi, "think-time bounds inverted");
    let weights: Vec<f64> = if spec.tenants.is_empty() {
        vec![1.0]
    } else {
        spec.tenants.clone()
    };
    let mut rng = Rng::new(spec.seed);
    let n_system = spec.n_system.max(1);
    let systems: Vec<String> = (0..n_system)
        .map(|g| {
            // The numbered tag keeps system prompts distinct even when
            // the same facts are drawn.
            let mut sys = format!("system {g}:");
            loop {
                let f = &facts[rng.below(facts.len())];
                let s = fact_sentence(f, rng.below(3));
                if sys.len() + s.len() + 1 >= spec.system_bytes {
                    break;
                }
                sys.push(' ');
                sys.push_str(&s);
            }
            sys
        })
        .collect();
    (0..spec.n_conversations)
        .map(|c| {
            let tenant = rng.weighted(&weights);
            let sys = &systems[c % n_system];
            (0..spec.turns)
                .map(|turn| {
                    let f = &facts[rng.below(facts.len())];
                    let (q, _) = qa_pair(f);
                    let user_text = if turn == 0 {
                        format!("{sys} {q}")
                    } else {
                        format!(" {q}")
                    };
                    ConvoTurn {
                        conversation: c as u64,
                        turn,
                        user_text,
                        max_new: rng.range(mn_lo, mn_hi + 1),
                        tenant,
                        think_ms: if turn == 0 {
                            0
                        } else {
                            rng.range(tk_lo as usize, tk_hi as usize + 1)
                                as u64
                        },
                    }
                })
                .collect()
        })
        .collect()
}

impl Corpus {
    pub fn build(spec: &CorpusSpec) -> Corpus {
        let mut rng = Rng::new(spec.seed);
        let facts = build_world(&mut rng, spec.n_entities);
        let mut docs = Vec::new();
        let mut bytes = 0usize;
        // Mixture weights: facts 30%, QA 20%, patterns 20%, arithmetic 10%,
        // copy 10%, summary 10%.
        let weights = [0.30, 0.20, 0.20, 0.10, 0.10, 0.10];
        while bytes < spec.target_bytes {
            let doc = match rng.weighted(&weights) {
                0 => {
                    let f = &facts[rng.below(facts.len())];
                    fact_sentence(f, rng.below(3))
                }
                1 => {
                    let f = &facts[rng.below(facts.len())];
                    let (q, a) = qa_pair(f);
                    format!("{q}{a}")
                }
                2 => pattern_doc(&mut rng),
                3 => arithmetic_doc(&mut rng),
                4 => copy_doc(&mut rng, &facts),
                _ => summary_doc(&mut rng, &facts),
            };
            bytes += doc.len() + 1;
            docs.push(doc);
        }
        Corpus { facts, docs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic() {
        let spec = CorpusSpec { seed: 3, n_entities: 8, target_bytes: 10_000 };
        let a = Corpus::build(&spec);
        let b = Corpus::build(&spec);
        assert_eq!(a.docs, b.docs);
        assert_eq!(a.facts.len(), 8 * RELATIONS.len());
    }

    #[test]
    fn corpus_reaches_target_size() {
        let spec = CorpusSpec { seed: 1, n_entities: 8, target_bytes: 50_000 };
        let c = Corpus::build(&spec);
        // Target counts one separator byte per document.
        let total: usize = c.docs.iter().map(|d| d.len() + 1).sum();
        assert!(total >= 50_000);
    }

    #[test]
    fn different_seeds_differ() {
        let a = Corpus::build(&CorpusSpec { seed: 1, n_entities: 8, target_bytes: 5_000 });
        let b = Corpus::build(&CorpusSpec { seed: 2, n_entities: 8, target_bytes: 5_000 });
        assert_ne!(a.docs, b.docs);
    }

    #[test]
    fn facts_have_consistent_values() {
        let c = Corpus::build(&CorpusSpec::default());
        // Every (entity, relation) pair appears exactly once in the KB.
        let mut seen = std::collections::BTreeSet::new();
        for f in &c.facts {
            assert!(seen.insert((f.entity.clone(), f.relation)));
        }
    }

    #[test]
    fn qa_format_is_stable() {
        let f = Fact { entity: "bace".into(), relation: "capital", value: "zarbon" };
        let (q, a) = qa_pair(&f);
        assert_eq!(q, "question: what is the capital of bace? answer:");
        assert_eq!(a, " zarbon");
    }

    #[test]
    fn shared_prefix_prompts_share_within_groups_and_bound_length() {
        let c = Corpus::build(&CorpusSpec {
            seed: 4,
            n_entities: 8,
            target_bytes: 5_000,
        });
        let spec = SharedPrefixSpec {
            seed: 11,
            n_groups: 3,
            requests_per_group: 4,
            prefix_bytes: 96,
        };
        let a = shared_prefix_prompts(&spec, &c.facts);
        assert_eq!(a.len(), 12);
        assert_eq!(a, shared_prefix_prompts(&spec, &c.facts), "deterministic");
        // Round-robin emission: prompts i and i + n_groups share their
        // group's system prefix byte-for-byte; neighbouring prompts are
        // from different groups.
        for (i, p) in a.iter().enumerate() {
            let g = i % spec.n_groups;
            let tag = format!("system {g}:");
            assert!(p.starts_with(&tag), "{p:?}");
            let sys_len = p.find(" question: ").expect("question suffix");
            assert!(sys_len < spec.prefix_bytes, "prefix over budget: {p:?}");
            if i >= spec.n_groups {
                assert_eq!(
                    p[..sys_len],
                    a[i - spec.n_groups][..sys_len],
                    "group {g} prefix not shared"
                );
            }
            assert!(p.ends_with("? answer:"), "{p:?}");
            assert!(p.is_ascii());
        }
        // Distinct groups diverge immediately after the tag.
        assert_ne!(a[0], a[1]);
    }

    #[test]
    fn docs_are_ascii() {
        let c = Corpus::build(&CorpusSpec { seed: 5, n_entities: 6, target_bytes: 20_000 });
        for d in &c.docs {
            assert!(d.is_ascii());
        }
    }

    #[test]
    fn bursty_traffic_is_deterministic_and_in_bounds() {
        let c = Corpus::build(&CorpusSpec {
            seed: 6,
            n_entities: 10,
            target_bytes: 5_000,
        });
        let spec = TrafficSpec { seed: 23, n_requests: 96, ..TrafficSpec::default() };
        let a = bursty_traffic(&spec, &c.facts);
        assert_eq!(a.len(), 96);
        assert_eq!(a, bursty_traffic(&spec, &c.facts), "deterministic");
        let (dl_lo, dl_hi) = spec.deadline_ms;
        let (mn_lo, mn_hi) = spec.max_new;
        for r in &a {
            assert!(r.prompt.is_ascii());
            assert!(r.prompt.ends_with("? answer:"), "{:?}", r.prompt);
            assert!(r.prompt.len() <= spec.prompt_bytes.1, "{:?}", r.prompt);
            assert!((mn_lo..=mn_hi).contains(&r.max_new));
            assert!(r.tenant < spec.tenants.len());
            assert!(r.priority == 0 || r.priority == 1);
            if let Some(d) = r.deadline_ms {
                assert!((dl_lo..=dl_hi).contains(&d), "deadline {d} out of bounds");
            }
        }
        // The mix actually exercises the control plane: some deadlined,
        // some best-effort, and more than one tenant present.
        assert!(a.iter().any(|r| r.deadline_ms.is_some()));
        assert!(a.iter().any(|r| r.deadline_ms.is_none()));
        assert!(a.iter().any(|r| r.tenant == 0));
        assert!(a.iter().any(|r| r.tenant == 1));
    }

    #[test]
    fn bursty_traffic_bursts_are_tenant_coherent_and_weighted() {
        let c = Corpus::build(&CorpusSpec {
            seed: 7,
            n_entities: 8,
            target_bytes: 5_000,
        });
        let spec = TrafficSpec {
            seed: 31,
            n_requests: 400,
            tenants: vec![3.0, 1.0],
            burst_len: 4,
            ..TrafficSpec::default()
        };
        let a = bursty_traffic(&spec, &c.facts);
        // Tenant is constant within each burst of `burst_len` requests.
        for chunk in a.chunks(spec.burst_len) {
            assert!(chunk.iter().all(|r| r.tenant == chunk[0].tenant));
        }
        // Shares track the 3:1 weights coarsely (pinned seed, so the
        // bound is loose but stable).
        let t0 = a.iter().filter(|r| r.tenant == 0).count() as f64 / a.len() as f64;
        assert!((0.55..=0.95).contains(&t0), "tenant-0 share {t0}");
    }

    #[test]
    fn bursty_traffic_diurnal_peak_tightens_deadlines() {
        let c = Corpus::build(&CorpusSpec {
            seed: 8,
            n_entities: 8,
            target_bytes: 5_000,
        });
        let spec = TrafficSpec {
            seed: 41,
            n_requests: 512,
            period: 16,
            deadline_rate: 1.0,
            ..TrafficSpec::default()
        };
        let a = bursty_traffic(&spec, &c.facts);
        // Mean deadline near the diurnal peak (middle of the period) is
        // tighter than near the trough (period boundary).
        let mean = |pred: &dyn Fn(usize) -> bool| {
            let v: Vec<f64> = a
                .iter()
                .enumerate()
                .filter(|(i, _)| pred(*i))
                .filter_map(|(_, r)| r.deadline_ms.map(|d| d as f64))
                .collect();
            assert!(!v.is_empty());
            v.iter().sum::<f64>() / v.len() as f64
        };
        let peak = mean(&|i| {
            let t = i % spec.period;
            (6..=9).contains(&t)
        });
        let trough = mean(&|i| {
            let t = i % spec.period;
            t <= 1 || t >= 14
        });
        assert!(
            peak < trough,
            "peak deadlines ({peak:.1} ms) should be tighter than trough ({trough:.1} ms)"
        );
    }

    #[test]
    fn conversation_traffic_is_deterministic_and_well_shaped() {
        let c = Corpus::build(&CorpusSpec {
            seed: 9,
            n_entities: 10,
            target_bytes: 5_000,
        });
        let spec = ConvoSpec { seed: 47, ..ConvoSpec::default() };
        let convos = conversation_traffic(&spec, &c.facts);
        assert_eq!(convos.len(), spec.n_conversations);
        assert_eq!(convos, conversation_traffic(&spec, &c.facts));
        let (mn_lo, mn_hi) = spec.max_new;
        let (tk_lo, tk_hi) = spec.think_ms;
        for (c_idx, turns) in convos.iter().enumerate() {
            assert_eq!(turns.len(), spec.turns);
            for (t_idx, t) in turns.iter().enumerate() {
                assert_eq!(t.conversation, c_idx as u64);
                assert_eq!(t.turn, t_idx);
                assert!(t.user_text.is_ascii());
                assert!(t.user_text.ends_with("? answer:"));
                assert!((mn_lo..=mn_hi).contains(&t.max_new));
                assert!(t.tenant < spec.tenants.len());
                // The tenant is pinned for the whole conversation.
                assert_eq!(t.tenant, turns[0].tenant);
                if t_idx == 0 {
                    let tag = format!("system {}:", c_idx % spec.n_system);
                    assert!(t.user_text.starts_with(&tag), "{:?}", t.user_text);
                    assert_eq!(t.think_ms, 0);
                } else {
                    // Follow-up turns carry only their new text, space-
                    // prefixed so the stitched prompt stays well-formed.
                    assert!(t.user_text.starts_with(" question:"));
                    assert!((tk_lo..=tk_hi).contains(&t.think_ms));
                }
            }
        }
        // Mixed tenants actually appear under the 3:1 default weights.
        assert!(convos.iter().any(|t| t[0].tenant == 0));
        assert!(convos.iter().any(|t| t[0].tenant == 1));
    }

    #[test]
    fn conversation_traffic_shares_system_prompts_across_conversations() {
        let c = Corpus::build(&CorpusSpec {
            seed: 10,
            n_entities: 8,
            target_bytes: 5_000,
        });
        let spec = ConvoSpec {
            seed: 53,
            n_conversations: 6,
            n_system: 2,
            system_bytes: 120,
            ..ConvoSpec::default()
        };
        let convos = conversation_traffic(&spec, &c.facts);
        let system_of = |turns: &[ConvoTurn]| {
            let opener = &turns[0].user_text;
            let q = opener.find(" question:").expect("opener has a question");
            opener[..q].to_string()
        };
        for (c_idx, turns) in convos.iter().enumerate() {
            let sys = system_of(turns);
            assert!(sys.len() < spec.system_bytes, "system over budget");
            // Conversations in the same group share the system prompt
            // verbatim — that sharing is what the prefix trie exploits.
            assert_eq!(sys, system_of(&convos[c_idx % spec.n_system]));
        }
        assert_ne!(system_of(&convos[0]), system_of(&convos[1]));
    }
}
