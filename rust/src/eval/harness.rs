//! Task evaluation driver: runs a text-generation engine over a task and
//! aggregates scores + latency (the two axes of the paper's Figure 8).

use crate::data::tasks::{EvalTask, Metric};

use super::scorers::{exact_match, rouge_l, token_f1};

/// Anything that can complete a prompt (both inference engines implement
/// this; tests use closures).
pub trait Generator {
    /// Generate a completion for `prompt`, up to `max_new_tokens` tokens.
    /// Returns (text, wall_seconds).
    fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> (String, f64);
}

impl<F> Generator for F
where
    F: FnMut(&str, usize) -> (String, f64),
{
    fn generate(&mut self, prompt: &str, max: usize) -> (String, f64) {
        self(prompt, max)
    }
}

#[derive(Debug, Clone)]
pub struct TaskScore {
    pub task: &'static str,
    pub metric: Metric,
    pub score: f64,
    pub n: usize,
    pub total_seconds: f64,
    pub mean_seconds: f64,
}

/// Trim a generation at the first newline / BOS-induced break: the tasks
/// are single-line completions, and tiny models ramble.
pub fn first_line(s: &str) -> &str {
    let s = s.trim_start();
    match s.find(['\n']) {
        Some(i) => &s[..i],
        None => s,
    }
}

/// Cut a completion at sensible answer boundaries for short-form tasks.
///
/// Stop substrings must never fire *inside* a legitimate answer: a bare
/// `" the "` is too greedy (it mangles answers like "over the rainbow"),
/// so a rambling follow-on fact sentence is only detected by its full
/// `" the <word> of "` clause shape.
pub fn short_answer(s: &str) -> String {
    let line = first_line(s);
    // Stop at the start of a follow-on sentence or a new template.
    let mut cut = line.len();
    for stop in [". ", "? ", " question:", " copy:", " summary:", " answer:"]
    {
        if let Some(i) = line.find(stop) {
            cut = cut.min(i + if stop == ". " { 1 } else { 0 });
        }
    }
    if let Some(i) = fact_clause_start(line) {
        cut = cut.min(i);
    }
    line[..cut].trim().trim_end_matches('.').to_string()
}

/// Position of a rambling follow-on fact clause `" the <word> of "` (the
/// corpus' dominant sentence template), if any. A bare `" the "` followed
/// by anything else is part of the answer and survives.
fn fact_clause_start(line: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(off) = line[from..].find(" the ") {
        let i = from + off;
        let mut words = line[i + " the ".len()..].split_whitespace();
        if let (Some(_relation), Some("of")) = (words.next(), words.next()) {
            return Some(i);
        }
        from = i + 1;
    }
    None
}

pub fn score_one(metric: Metric, pred: &str, reference: &str) -> f64 {
    match metric {
        Metric::ExactMatch => exact_match(&short_answer(pred), reference),
        Metric::TokenF1 => token_f1(&short_answer(pred), reference),
        Metric::RougeL => rouge_l(first_line(pred), reference),
    }
}

pub fn evaluate_task<G: Generator>(task: &EvalTask, gen: &mut G) -> TaskScore {
    let mut total = 0.0;
    let mut seconds = 0.0;
    for ex in &task.examples {
        let (pred, secs) = gen.generate(&ex.prompt, task.max_new_tokens);
        total += score_one(task.metric, &pred, &ex.reference);
        seconds += secs;
    }
    let n = task.examples.len().max(1);
    TaskScore {
        task: task.name,
        metric: task.metric,
        score: total / n as f64,
        n: task.examples.len(),
        total_seconds: seconds,
        mean_seconds: seconds / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{Corpus, CorpusSpec};
    use crate::data::tasks;

    #[test]
    fn perfect_generator_scores_one() {
        let c = Corpus::build(&CorpusSpec {
            seed: 2,
            n_entities: 6,
            target_bytes: 8_000,
        });
        let task = tasks::fact_qa(&c, 10, 1);
        // Oracle: answer from the KB.
        let facts = c.facts.clone();
        let mut oracle = |prompt: &str, _max: usize| {
            for f in &facts {
                let (q, a) = crate::data::synth::qa_pair(f);
                if q == prompt {
                    return (a, 0.001);
                }
            }
            ("dunno".to_string(), 0.001)
        };
        let score = evaluate_task(&task, &mut oracle);
        assert!((score.score - 1.0).abs() < 1e-9, "{score:?}");
        assert!(score.mean_seconds > 0.0);
    }

    #[test]
    fn garbage_generator_scores_low() {
        let c = Corpus::build(&CorpusSpec {
            seed: 2,
            n_entities: 6,
            target_bytes: 8_000,
        });
        let task = tasks::fact_qa(&c, 10, 1);
        let mut garbage =
            |_: &str, _: usize| ("qqqq zzzz".to_string(), 0.001);
        let score = evaluate_task(&task, &mut garbage);
        assert!(score.score < 0.2, "{score:?}");
    }

    #[test]
    fn short_answer_trims_rambling() {
        assert_eq!(short_answer(" zarbon. the capital of x is y."), "zarbon");
        assert_eq!(short_answer("8. 3+4=7."), "8");
        assert_eq!(short_answer("yes question: is"), "yes");
    }

    #[test]
    fn short_answer_keeps_stop_substrings_inside_answers() {
        // Regression: answers containing the article " the " (or other
        // near-stop substrings) must survive untruncated.
        assert_eq!(short_answer("over the rainbow"), "over the rainbow");
        assert_eq!(short_answer("the red tower"), "the red tower");
        assert_eq!(short_answer("north of the wall"), "north of the wall");
        // ...while a follow-on fact clause is still cut, period or not.
        assert_eq!(short_answer("zarbon the capital of x is y"), "zarbon");
        assert_eq!(short_answer("melka the color of ovask"), "melka");
        // And template glue still truncates.
        assert_eq!(short_answer("no answer: yes"), "no");
    }
}
