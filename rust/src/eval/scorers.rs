//! Metric implementations: exact match, token-level F1 (SQuAD-style), and
//! ROUGE-L (LCS F-measure) — the metrics the paper reports via HELM.

fn normalize(s: &str) -> String {
    s.to_lowercase()
        .chars()
        .map(|c| if c.is_ascii_punctuation() { ' ' } else { c })
        .collect::<String>()
        .split_whitespace()
        .collect::<Vec<_>>()
        .join(" ")
}

fn tokens(s: &str) -> Vec<String> {
    normalize(s).split_whitespace().map(String::from).collect()
}

/// 1.0 iff the normalised prediction equals the normalised reference.
pub fn exact_match(pred: &str, reference: &str) -> f64 {
    (normalize(pred) == normalize(reference)) as u8 as f64
}

/// SQuAD-style token F1 (bag-of-tokens overlap).
pub fn token_f1(pred: &str, reference: &str) -> f64 {
    let p = tokens(pred);
    let r = tokens(reference);
    if p.is_empty() || r.is_empty() {
        return (p.is_empty() && r.is_empty()) as u8 as f64;
    }
    let mut counts = std::collections::BTreeMap::new();
    for t in &r {
        *counts.entry(t.clone()).or_insert(0usize) += 1;
    }
    let mut overlap = 0usize;
    for t in &p {
        if let Some(c) = counts.get_mut(t) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / p.len() as f64;
    let recall = overlap as f64 / r.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

fn lcs_len(a: &[String], b: &[String]) -> usize {
    let n = b.len();
    let mut prev = vec![0usize; n + 1];
    let mut cur = vec![0usize; n + 1];
    for x in a {
        for (j, y) in b.iter().enumerate() {
            cur[j + 1] = if x == y {
                prev[j] + 1
            } else {
                cur[j].max(prev[j + 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[n]
}

/// ROUGE-L F-measure over normalised tokens.
pub fn rouge_l(pred: &str, reference: &str) -> f64 {
    let p = tokens(pred);
    let r = tokens(reference);
    if p.is_empty() || r.is_empty() {
        return (p.is_empty() && r.is_empty()) as u8 as f64;
    }
    let lcs = lcs_len(&p, &r) as f64;
    if lcs == 0.0 {
        return 0.0;
    }
    let precision = lcs / p.len() as f64;
    let recall = lcs / r.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn em_ignores_case_and_punct() {
        assert_eq!(exact_match("Zarbon.", "zarbon"), 1.0);
        assert_eq!(exact_match("zarbon", "melka"), 0.0);
        assert_eq!(exact_match("the  answer", "The answer!"), 1.0);
    }

    #[test]
    fn f1_known_values() {
        assert_eq!(token_f1("a b c", "a b c"), 1.0);
        assert_eq!(token_f1("a b", "c d"), 0.0);
        // overlap 1, |p| = 1, |r| = 2 -> p=1, r=0.5, f1 = 2/3.
        assert!((token_f1("a", "a b") - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn f1_respects_multiplicity() {
        // pred has one "a", ref has two: overlap = 1.
        let f = token_f1("a", "a a");
        assert!((f - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn rouge_l_known_values() {
        assert_eq!(rouge_l("the cat sat", "the cat sat"), 1.0);
        assert_eq!(rouge_l("x y z", "a b c"), 0.0);
        // LCS("a c", "a b c") = 2; p = 2/2, r = 2/3 -> F = 0.8.
        assert!((rouge_l("a c", "a b c") - 0.8).abs() < 1e-9);
    }

    #[test]
    fn rouge_l_is_order_sensitive_where_f1_is_not() {
        let f1 = token_f1("c b a", "a b c");
        let rl = rouge_l("c b a", "a b c");
        assert_eq!(f1, 1.0);
        assert!(rl < 1.0);
    }

    fn rand_text(rng: &mut Rng) -> String {
        let n = rng.below(8);
        (0..n)
            .map(|_| ["a", "b", "cat", "dog", "x"][rng.below(5)])
            .collect::<Vec<_>>()
            .join(" ")
    }

    #[test]
    fn metric_properties() {
        check("metrics in [0,1], identity == 1", 200, |rng| {
            let a = rand_text(rng);
            let b = rand_text(rng);
            for (name, m) in [
                ("em", exact_match(&a, &b)),
                ("f1", token_f1(&a, &b)),
                ("rouge", rouge_l(&a, &b)),
            ] {
                if !(0.0..=1.0).contains(&m) {
                    return Err(format!("{name} out of range: {m}"));
                }
            }
            if !a.is_empty() {
                for (name, m) in [
                    ("em", exact_match(&a, &a)),
                    ("f1", token_f1(&a, &a)),
                    ("rouge", rouge_l(&a, &a)),
                ] {
                    if (m - 1.0).abs() > 1e-12 {
                        return Err(format!("{name}(x,x) != 1: {m}"));
                    }
                }
            }
            // Symmetry of F1.
            if (token_f1(&a, &b) - token_f1(&b, &a)).abs() > 1e-12 {
                return Err("f1 not symmetric".into());
            }
            Ok(())
        });
    }
}
