//! Evaluation harness: the metric implementations HELM uses for the
//! paper's Figure-8 tasks (EM, token-F1, ROUGE-L), plus the driver that
//! scores a generation engine over a task suite.

pub mod harness;
pub mod scorers;

pub use harness::{evaluate_task, TaskScore};
pub use scorers::{exact_match, rouge_l, token_f1};
