//! Metrics: wall-clock timers, streaming statistics, and run reporting.

use std::time::Instant;

/// Streaming summary statistics (Welford).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Stats {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// A scoped timer that records into a `Stats` on drop.
pub struct ScopedTimer<'a> {
    start: Instant,
    sink: &'a mut Stats,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(sink: &'a mut Stats) -> ScopedTimer<'a> {
        ScopedTimer { start: Instant::now(), sink }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.sink.push(self.start.elapsed().as_secs_f64());
    }
}

/// Measure a closure's wall time in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Nearest-rank percentile of `samples` (`q` in [0, 1]); 0.0 when empty.
/// Used by the serving layer for p50/p95 request latency.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// Repeat a closure with warmup and return per-iteration seconds — the
/// measurement core of the offline bench harness.
pub fn bench_loop(warmup: usize, iters: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        stats.push(t0.elapsed().as_secs_f64());
    }
    stats
}

/// Simple CSV loss-curve writer (step, series...) used by training.
pub struct CurveWriter {
    path: std::path::PathBuf,
    header: Vec<String>,
    rows: Vec<Vec<f64>>,
}

impl CurveWriter {
    pub fn new(path: &std::path::Path, header: &[&str]) -> CurveWriter {
        CurveWriter {
            path: path.to_path_buf(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.header.len());
        self.rows.push(row);
    }

    pub fn flush(&self) -> std::io::Result<()> {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(
                &r.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        std::fs::write(&self.path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_match_closed_form() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 0.5), 2.0);
        assert_eq!(percentile(&v, 0.95), 4.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn bench_loop_counts() {
        let mut n = 0;
        let stats = bench_loop(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(stats.n, 5);
    }

    #[test]
    fn scoped_timer_records() {
        let mut s = Stats::new();
        {
            let _t = ScopedTimer::new(&mut s);
        }
        assert_eq!(s.n, 1);
    }
}
