//! Single-process early-exit inference with **KV recomputation**
//! (Section 4 / Appendix D.3), and the full-model baseline (an
//! [`ExitPolicy`] that can never exit: `Confidence{1.0}` or `Never`).
//!
//! State per generation: one KV cache per stage plus the *deficit* — the
//! trailing run of positions whose deep-layer KV entries are missing
//! because their tokens were emitted at an early exit. Every decode pass
//! processes a window that covers the deficit and the current position, so
//! the stages it does run recompute (heal) the missing entries; passes that
//! run all stages clear the deficit entirely. When the deficit approaches
//! the widest available decode window, early exiting is suspended for one
//! pass (the paper's forced full-model pass).
//!
//! Windows wider than the deficit are padded on the left with
//! already-healed positions: recomputation is idempotent (validated in
//! python/tests/test_decode.py), so this only costs compute — the batching
//! effect the paper relies on.
//!
//! Fused lane decode additionally keeps each group of co-stepping
//! sessions **device-resident** (`lane_residency`, on by default): the
//! lanes' per-stage KV caches are gathered into lane-stacked literals
//! once at group formation, stepped in place every round — zero host
//! cache traffic at steady state — and scattered back to per-session
//! handles only when a lane departs (exit/deficit/close), the group is
//! re-planned, or a snapshot needs host bytes. See
//! [`SequentialEngine::run_lanes_resident`]'s lifecycle notes.

use std::collections::HashMap;

use anyhow::{ensure, Context, Result};

use crate::eval::harness::Generator;
use crate::runtime::client::StageRuntime;
use crate::runtime::tensor::{HostTensor, IntTensor};

use super::common::{
    pad_cache_to_capacity, slice_cache_positions, GenOutput, ModelState,
};
use super::policy::{summarize_logits, ExitPolicy};
use super::session::{
    DecodeBackend, DecodeSession, LaneSlot, LaneTraffic, SessionCaches,
    WindowOutcome,
};

/// Per-token probe record (Table 4): predictions + confidences at every
/// early exit and the final exit.
#[derive(Debug, Clone)]
pub struct TokenProbe {
    pub position: usize,
    /// (exit layer, predicted token, confidence), shallow to deep;
    /// the final exit is the last entry.
    pub exits: Vec<(usize, i32, f32)>,
}

/// A fused lane group whose lane-stacked per-stage KV caches live on
/// device across rounds — the burn-fusion persistent-handle idiom applied
/// to lane decode. Formed by one gather per stage, stepped with **zero**
/// host cache traffic, and dissolved back to per-session caches only when
/// a member departs (exit/deficit/close), snapshots, or the group is
/// re-planned.
struct LaneGroup {
    /// Member session ids ([`SessionCaches::generation`]), in lane order.
    members: Vec<u64>,
    /// One lane-stacked `[B, ...cache_shape]` device literal per stage.
    stacked: Vec<xla::Literal>,
}

/// The KV-recompute decode engine ("recompute" on the CLI).
///
/// All engine-held serving state — resident lane groups, scattered lane
/// caches, traffic counters — is a disposable acceleration layer over
/// [`ModelState`]: the serving pool's supervisor rebuilds a panicked
/// engine from its `ModelState` in place and re-admits the casualties
/// from their decode-time checkpoints, so nothing here needs to survive
/// a rebuild.
pub struct SequentialEngine {
    pub state: ModelState,
    rt: StageRuntime,
    /// Per-stage parameter literals (cached; params are immutable here).
    plits: Vec<Vec<xla::Literal>>,
    /// Exit-decision policy every window pass consults
    /// ([`ExitPolicy::Confidence`] reproduces the paper's scalar rule).
    pub policy: ExitPolicy,
    widths: Vec<usize>,
    /// Fused-lane batch sizes with a `decode_b{B}_w1` executable on
    /// every stage (sorted; empty on manifests without lane fusion).
    lanes: Vec<usize>,
    /// Lane sizes whose every exit on every stage also ships a
    /// lane-batched head executable (`head{L}_b{B}`) — at these sizes a
    /// fused group's exit decisions cost one dispatch per exit. Subset
    /// of `lanes`; sizes missing here fall back to per-lane solo heads.
    head_lanes: Vec<usize>,
    /// Keep fused lane groups device-resident across rounds (gather once
    /// at formation, scatter only on departure) instead of a per-step
    /// host round-trip. On by default; turned off (`--no-resident`) the
    /// engine reproduces the PR-5 gather/scatter path bit-for-bit for
    /// comparison runs.
    pub lane_residency: bool,
    /// Device-resident fused lane groups, keyed by member session ids.
    resident: Vec<LaneGroup>,
    /// Per-stage caches of sessions scattered out of dissolved groups,
    /// waiting for the owning session's next touch to sync its handle
    /// (see [`SessionCaches::generation`] on the lazy-sync contract).
    parked: HashMap<u64, Vec<xla::Literal>>,
    /// Monotonic fused-decode host⇄device traffic counters.
    traffic: LaneTraffic,
    /// Source for [`SessionCaches::generation`] ids (never reused).
    next_session: u64,
    /// Collect per-exit probes for every generated token (Table 4 mode).
    pub probe: bool,
    pub probes: Vec<TokenProbe>,
}

impl SequentialEngine {
    pub fn new(
        state: ModelState,
        policy: ExitPolicy,
    ) -> Result<SequentialEngine> {
        let mut rt = StageRuntime::cpu()?;
        // A lane size is usable only when *every* stage ships its
        // batched executable (tolerates hand-trimmed artifact sets).
        let lanes: Vec<usize> = {
            let mut lanes: Vec<usize> = state
                .man
                .decode_lanes
                .iter()
                .copied()
                .filter(|b| {
                    state.man.stages.iter().all(|st| {
                        st.executables.contains_key(&format!("decode_b{b}_w1"))
                    })
                })
                .collect();
            lanes.sort_unstable();
            lanes.dedup();
            lanes
        };
        // Batched exit heads are a capability per lane size: usable only
        // when every exit on every stage ships one (and the size fuses).
        let head_lanes: Vec<usize> = {
            let manifest_head_lanes = state.man.head_lanes();
            lanes
                .iter()
                .copied()
                .filter(|b| manifest_head_lanes.contains(b))
                .collect()
        };
        for st in &state.man.stages {
            for w in &state.man.decode_widths {
                let key = format!("decode_w{w}");
                rt.load(
                    &format!("s{}:{key}", st.index),
                    &state.man.exec_path(st.exec(&key)?),
                )?;
            }
            for b in &lanes {
                let key = format!("decode_b{b}_w1");
                rt.load(
                    &format!("s{}:{key}", st.index),
                    &state.man.exec_path(st.exec(&key)?),
                )?;
            }
            for e in &st.exits {
                let key = format!("head{}", e.layer);
                rt.load(
                    &format!("s{}:{key}", st.index),
                    &state.man.exec_path(st.exec(&key)?),
                )?;
                for b in &head_lanes {
                    let key = format!("head{}_b{b}", e.layer);
                    rt.load(
                        &format!("s{}:{key}", st.index),
                        &state.man.exec_path(st.exec(&key)?),
                    )?;
                }
            }
        }
        let plits = state
            .stage_params
            .iter()
            .map(|ps| ps.iter().map(|p| p.to_literal()).collect())
            .collect::<Result<Vec<Vec<_>>>>()?;
        let widths = state.man.decode_widths.clone();
        Ok(SequentialEngine {
            state,
            rt,
            plits,
            policy,
            widths,
            lanes,
            head_lanes,
            lane_residency: true,
            resident: Vec::new(),
            parked: HashMap::new(),
            traffic: LaneTraffic::default(),
            next_session: 0,
            probe: false,
            probes: Vec::new(),
        })
    }

    fn head_logits(&self, s: usize, layer: usize, x: &[f32]) -> Result<Vec<f32>> {
        let st = &self.state.man.stages[s];
        let e = st
            .exits
            .iter()
            .find(|e| e.layer == layer)
            .context("exit not on stage")?;
        let xlit = HostTensor::new(vec![x.len()], x.to_vec()).to_literal()?;
        let mut args: Vec<&xla::Literal> = e
            .head_param_idx
            .iter()
            .map(|&i| &self.plits[s][i])
            .collect();
        args.push(&xlit);
        let out = self
            .rt
            .get(&format!("s{s}:head{layer}"))?
            .run(&args)?;
        Ok(HostTensor::from_literal(&out[0])?.data)
    }

    /// Per-lane logits for the exit at `layer` on stage `s`, over the
    /// lane batch `xh` (shape `[B, H]`). One lane-batched `head{L}_b{B}`
    /// dispatch when the manifest ships it for this lane count — the
    /// whole batch is evaluated (fired lanes ride as padding; the head
    /// is a per-lane vmap, so unconsumed rows perturb nothing) — else
    /// per-lane solo head calls restricted to the lanes in `need`
    /// (other entries come back empty).
    fn head_logits_lanes(
        &self,
        s: usize,
        layer: usize,
        xh: &HostTensor,
        need: &[bool],
    ) -> Result<Vec<Vec<f32>>> {
        let b = need.len();
        let h = self.state.man.model.hidden;
        if !self.head_lanes.contains(&b) {
            return need
                .iter()
                .enumerate()
                .map(|(i, &n)| {
                    if n {
                        self.head_logits(s, layer, &xh.data[i * h..(i + 1) * h])
                    } else {
                        Ok(Vec::new())
                    }
                })
                .collect();
        }
        let st = &self.state.man.stages[s];
        let e = st
            .exits
            .iter()
            .find(|e| e.layer == layer)
            .context("exit not on stage")?;
        let xlit = xh.to_literal()?;
        let mut args: Vec<&xla::Literal> = e
            .head_param_idx
            .iter()
            .map(|&i| &self.plits[s][i])
            .collect();
        args.push(&xlit);
        let out = self
            .rt
            .get(&format!("s{s}:head{layer}_b{b}"))?
            .run(&args)?;
        let t = HostTensor::from_literal(&out[0])?;
        let v = self.state.man.model.vocab;
        ensure!(
            t.data.len() == b * v,
            "batched head{layer}_b{b} returned {} logits, want {}",
            t.data.len(),
            b * v
        );
        Ok((0..b).map(|i| t.data[i * v..(i + 1) * v].to_vec()).collect())
    }

    /// Per-lane entry-exit decisions for stage `s` (Optimization-2
    /// placement) over the batched hidden state, marking lanes that fire
    /// in `fired` as (token, exit layer, stages run). Decision order and
    /// gating match the solo path lane-for-lane: a lane that fires at a
    /// shallower exit is excluded from deeper exits at the same entry.
    fn entry_exit_lanes(
        &self,
        s: usize,
        xh: &HostTensor,
        lanes: &[LaneSlot<'_>],
        fired: &mut [Option<(i32, usize, usize)>],
    ) -> Result<()> {
        let layers: Vec<usize> =
            self.state.entry_exits(s).iter().map(|e| e.layer).collect();
        for layer in layers {
            if !self.policy.may_exit_at(layer) {
                continue;
            }
            let need: Vec<bool> = (0..lanes.len())
                .map(|i| fired[i].is_none() && lanes[i].allow_exit)
                .collect();
            if !need.iter().any(|&n| n) {
                continue;
            }
            let logits = self.head_logits_lanes(s, layer, xh, &need)?;
            for (i, &n) in need.iter().enumerate() {
                if !n {
                    continue;
                }
                let sum = summarize_logits(&logits[i]);
                if self.policy.decide(layer, &sum).is_exit() {
                    fired[i] = Some((sum.token, layer, s));
                }
            }
        }
        Ok(())
    }

    fn stage_cache_elems(&self, s: usize) -> usize {
        self.state.man.stages[s].cache_shape.iter().product()
    }

    /// Dissolve any resident lane group containing session `id`: every
    /// member's lane is scattered out of the stacked device literals
    /// into `parked` (stage order), except `drop_id`, whose state is
    /// discarded without a scatter (a closing session needs none). This
    /// — one scatter per parked lane per stage — is the departure
    /// traffic the resident design pays instead of per-step round-trips.
    fn dissolve_containing(
        &mut self,
        id: u64,
        drop_id: Option<u64>,
    ) -> Result<()> {
        let Some(gi) =
            self.resident.iter().position(|g| g.members.contains(&id))
        else {
            return Ok(());
        };
        let g = self.resident.swap_remove(gi);
        for (s, lit) in g.stacked.iter().enumerate() {
            let len = self.stage_cache_elems(s);
            let t = HostTensor::from_literal(lit)?;
            debug_assert_eq!(t.data.len(), g.members.len() * len);
            let shape = &self.state.man.stages[s].cache_shape;
            for (i, &m) in g.members.iter().enumerate() {
                if Some(m) == drop_id {
                    continue;
                }
                let lane = HostTensor::literal_from_slice(
                    shape,
                    &t.data[i * len..(i + 1) * len],
                )?;
                self.parked.entry(m).or_default().push(lane);
            }
        }
        let kept =
            g.members.iter().filter(|&&m| Some(m) != drop_id).count() as u64;
        let stages = g.stacked.len() as u64;
        self.traffic.cache_scatters += kept * stages;
        for s in 0..g.stacked.len() {
            self.traffic.scatter_bytes +=
                kept * (self.stage_cache_elems(s) * 4) as u64;
        }
        Ok(())
    }

    /// Sync session `id`'s own caches handle with the engine-side truth:
    /// dissolve its resident group (if any), then move its parked
    /// literals back into the handle. No-op for ungrouped sessions, so
    /// every mutable touch point (solo windows, group formation) calls
    /// this unconditionally.
    fn claim(&mut self, caches: &mut SessionCaches) -> Result<()> {
        let id = caches.generation;
        self.dissolve_containing(id, None)?;
        if let Some(lits) = self.parked.remove(&id) {
            caches.caches = lits;
        }
        Ok(())
    }

    /// The per-lane per-stage cache shape check, hoisted to group
    /// formation (and once per round-trip fused pass) so the gather /
    /// scatter hot loops carry only debug assertions. Cheap: reads
    /// literal metadata, not data.
    fn validate_lane_shapes(&self, lanes: &[LaneSlot<'_>]) -> Result<()> {
        let stages = &self.state.man.stages;
        for (i, lane) in lanes.iter().enumerate() {
            ensure!(
                lane.caches.caches.len() == stages.len(),
                "lane {i} has {} stage caches, engine has {} stages",
                lane.caches.caches.len(),
                stages.len()
            );
            for (st, lit) in stages.iter().zip(&lane.caches.caches) {
                let shape = lit.array_shape().context("lane cache shape")?;
                let dims: Vec<usize> =
                    shape.dims().iter().map(|&d| d as usize).collect();
                ensure!(
                    dims == st.cache_shape,
                    "lane {i} stage {} cache shape {dims:?} != {:?}",
                    st.index,
                    st.cache_shape
                );
            }
        }
        Ok(())
    }

    /// Gather the lanes' per-session caches into a fresh device-resident
    /// group — the one host→device copy of the group's lifetime. Members
    /// may still sit in stale resident groups (regroup) or parked from
    /// dissolved ones; every handle is synced first.
    fn form_group(
        &mut self,
        lanes: &mut [LaneSlot<'_>],
        ids: &[u64],
    ) -> Result<LaneGroup> {
        for lane in lanes.iter_mut() {
            self.claim(lane.caches)?;
        }
        self.validate_lane_shapes(lanes)?;
        let mut stacked = Vec::with_capacity(self.state.man.stages.len());
        for s in 0..self.state.man.stages.len() {
            stacked.push(self.gather_lane_caches(lanes, s)?);
        }
        self.traffic.cold_forms += 1;
        Ok(LaneGroup { members: ids.to_vec(), stacked })
    }

    /// Run one decode window pass.
    ///
    /// Returns (emitted token, exit layer, stages_run). Exit checks are
    /// skipped when `allow_exit` is false (prefill / forced-full passes).
    /// When `emit` is false (pure prefill) the pass always runs all stages
    /// and returns token = -1.
    #[allow(clippy::too_many_arguments)]
    fn window_pass(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        caches: &mut [xla::Literal],
        allow_exit: bool,
        emit: bool,
    ) -> Result<(i32, usize, usize)> {
        let p = self.state.man.stages.len();
        let h = self.state.man.model.hidden;
        let window = &tokens[pos0..pos0 + width];
        let pos_lit = IntTensor::scalar(pos0 as i32).to_literal()?;
        let mut x: Option<HostTensor> = None;
        let mut probe = TokenProbe {
            position: pos0 + width - 1,
            exits: Vec::new(),
        };

        for s in 0..p {
            // Entry exits (paper: Optimization-2 placement). Head logits
            // are only worth computing when someone consumes them — an
            // exit decision or a probe record. In particular the
            // full-model baseline (`allow_exit` false: prefill, forced
            // full passes, or a policy that can never exit) skips every
            // exit head, which is exactly what the paper's speedup
            // denominator should cost.
            if let Some(xh) = x.as_ref().filter(|_| {
                emit && (allow_exit || self.probe)
            }) {
                let last = &xh.data[(width - 1) * h..];
                for e in self.state.entry_exits(s) {
                    let layer = e.layer;
                    // Layers where the policy can never fire (unlisted
                    // or 1.0 in a PerLayer) only matter to the probe.
                    if !self.probe && !self.policy.may_exit_at(layer) {
                        continue;
                    }
                    let logits = self.head_logits(s, layer, last)?;
                    let sum = summarize_logits(&logits);
                    if self.probe && emit {
                        probe.exits.push((layer, sum.token, sum.top_prob));
                    }
                    if allow_exit
                        && emit
                        && self.policy.decide(layer, &sum).is_exit()
                    {
                        if self.probe {
                            self.probes.push(probe);
                        }
                        return Ok((sum.token, layer, s));
                    }
                }
            }
            // Stage decode.
            let in_lit: xla::Literal = if s == 0 {
                IntTensor::new(vec![width], window.to_vec()).to_literal()?
            } else {
                x.as_ref().unwrap().to_literal()?
            };
            // Perf pass §L3-2: the KV cache stays an xla::Literal across
            // steps — no host round-trip of ~0.5-2 MiB per stage per token.
            let mut args: Vec<&xla::Literal> = self.plits[s].iter().collect();
            args.push(&in_lit);
            args.push(&caches[s]);
            args.push(&pos_lit);
            let out = self
                .rt
                .get(&format!("s{s}:decode_w{width}"))?
                .run(&args)?;
            let mut it = out.into_iter();
            x = Some(HostTensor::from_literal(&it.next().unwrap())?);
            caches[s] = it.next().unwrap();
        }

        if !emit {
            return Ok((-1, 0, p));
        }
        let xh = x.unwrap();
        let last = &xh.data[(width - 1) * h..];
        let fin = self.state.final_exit();
        let logits = self.head_logits(p - 1, fin.layer, last)?;
        let sum = summarize_logits(&logits);
        if self.probe {
            probe.exits.push((fin.layer, sum.token, sum.top_prob));
            self.probes.push(probe);
        }
        Ok((sum.token, fin.layer, p))
    }

    /// Stack the lanes' per-session stage-`s` caches into the fused
    /// `[B, ...cache_shape]` layout one batched executable consumes —
    /// one host→device lane×stage copy per lane. Under residency this
    /// runs once per group formation; with residency off it runs every
    /// fused step (the PR-5 trade, kept as the measurable baseline).
    /// Shape validation is hoisted to [`validate_lane_shapes`]; only a
    /// debug assertion rides the hot loop.
    ///
    /// [`validate_lane_shapes`]: SequentialEngine::validate_lane_shapes
    fn gather_lane_caches(
        &mut self,
        lanes: &[LaneSlot<'_>],
        s: usize,
    ) -> Result<xla::Literal> {
        let len = self.stage_cache_elems(s);
        self.traffic.cache_gathers += lanes.len() as u64;
        self.traffic.gather_bytes += (lanes.len() * len * 4) as u64;
        let shape = &self.state.man.stages[s].cache_shape;
        let mut data = Vec::with_capacity(lanes.len() * len);
        for lane in lanes {
            let t = HostTensor::from_literal(&lane.caches.caches[s])?;
            debug_assert_eq!(t.shape, *shape, "lane cache shape drifted");
            data.extend_from_slice(&t.data);
        }
        let mut full = Vec::with_capacity(shape.len() + 1);
        full.push(lanes.len());
        full.extend_from_slice(shape);
        HostTensor::new(full, data).to_literal()
    }

    /// Scatter a fused pass's updated stage-`s` caches back to their
    /// sessions (round-trip mode only). Lanes with `skip[i]` set
    /// (already fired at an earlier stage entry) keep their pre-pass
    /// literal: the solo path never runs stages at or beyond an exit,
    /// and mirroring that here keeps the per-session cache state — and
    /// therefore every downstream deficit-heal window — bit-identical
    /// to unfused decoding. Each kept lane's literal is built straight
    /// from its slice of the host copy, no intermediate owned buffer.
    fn scatter_lane_caches(
        &mut self,
        lanes: &mut [LaneSlot<'_>],
        s: usize,
        stacked: &xla::Literal,
        skip: &[bool],
    ) -> Result<()> {
        let len = self.stage_cache_elems(s);
        let moved = skip.iter().filter(|&&k| !k).count();
        self.traffic.cache_scatters += moved as u64;
        self.traffic.scatter_bytes += (moved * len * 4) as u64;
        let shape = &self.state.man.stages[s].cache_shape;
        let t = HostTensor::from_literal(stacked)?;
        debug_assert_eq!(
            t.data.len(),
            lanes.len() * len,
            "fused stage cache output size drifted"
        );
        for (i, lane) in lanes.iter_mut().enumerate() {
            if skip[i] {
                continue;
            }
            lane.caches.caches[s] = HostTensor::literal_from_slice(
                shape,
                &t.data[i * len..(i + 1) * len],
            )?;
        }
        Ok(())
    }

    /// The device-resident fused pass: step an already-warm lane group
    /// (or form one) with **zero** per-step host cache traffic. Where
    /// the round-trip path gathers and scatters every lane's cache per
    /// stage per step, this one looks up a resident [`LaneGroup`] whose
    /// members are exactly these lanes in this order (a warm hit) or
    /// gathers one (a cold form), steps it against the group's device
    /// literals, and leaves every member's `SessionCaches` handle stale
    /// until the session next touches the engine — a solo window,
    /// snapshot, or close lazily scatters its lane back out
    /// ([`SequentialEngine::claim`] / `dissolve_containing`).
    ///
    /// Output-invisibility vs. solo decode: an un-fired lane's row gets
    /// exactly the solo cache update (the batched executables are
    /// per-lane vmaps). A **fired** lane's deeper-stage rows receive the
    /// batched pass's writes — which solo decode would skip — but only
    /// at the lane's window position; firing gives that lane a recompute
    /// deficit ≥ 1, it departs the group, and every subsequent healing
    /// window covers the whole deficit tail and rewrites those positions
    /// at every stage it runs before any read (the Section-4 masking
    /// argument), so the divergence is unobservable in tokens, exit
    /// layers, and every later cache read. Pinned by
    /// `tests/resident_lanes_equivalence.rs`.
    fn run_lanes_resident(
        &mut self,
        lanes: &mut [LaneSlot<'_>],
    ) -> Result<Vec<WindowOutcome>> {
        let ids: Vec<u64> =
            lanes.iter().map(|l| l.caches.generation).collect();
        let mut group =
            match self.resident.iter().position(|g| g.members == ids) {
                Some(i) => {
                    self.traffic.warm_hits += 1;
                    self.resident.swap_remove(i)
                }
                None => self.form_group(lanes, &ids)?,
            };
        let outcome = self.resident_pass(&mut group, lanes);
        // The group goes back on the resident list whatever happened —
        // pre-round state on error (updates are committed only after a
        // full pass, so the pool's solo retry claims what it would have
        // seen before the round), post-round state on success. Dropping
        // it would drop the members' only cache state.
        self.resident.push(group);
        outcome
    }

    /// One fused pass over a formed group's device literals: the batched
    /// decode per stage plus per-lane exit decisions from lane-batched
    /// heads ([`SequentialEngine::head_logits_lanes`]). Updated stage
    /// literals are committed to the group only after every fallible
    /// step has succeeded.
    fn resident_pass(
        &mut self,
        group: &mut LaneGroup,
        lanes: &mut [LaneSlot<'_>],
    ) -> Result<Vec<WindowOutcome>> {
        let b = lanes.len();
        let p = self.state.man.stages.len();
        let mut fired: Vec<Option<(i32, usize, usize)>> = vec![None; b];
        let pos_lit = IntTensor::new(
            vec![b],
            lanes.iter().map(|l| l.pos as i32).collect(),
        )
        .to_literal()?;
        let mut x: Option<HostTensor> = None;
        let mut pending: Vec<(usize, xla::Literal)> = Vec::new();
        for s in 0..p {
            if let Some(xh) = x.as_ref() {
                self.entry_exit_lanes(s, xh, lanes, &mut fired)?;
                if fired.iter().all(|f| f.is_some()) {
                    // Every lane has fired: deeper stages would only
                    // compute padding, and their stacked literals keep
                    // pre-round values — exactly the stages solo decode
                    // never ran.
                    break;
                }
            }
            let in_lit: xla::Literal = if s == 0 {
                IntTensor::new(
                    vec![b],
                    lanes.iter().map(|l| l.token).collect(),
                )
                .to_literal()?
            } else {
                x.as_ref().unwrap().to_literal()?
            };
            let mut args: Vec<&xla::Literal> =
                self.plits[s].iter().collect();
            args.push(&in_lit);
            args.push(&group.stacked[s]);
            args.push(&pos_lit);
            let out = self
                .rt
                .get(&format!("s{s}:decode_b{b}_w1"))?
                .run(&args)?;
            let mut it = out.into_iter();
            x = Some(HostTensor::from_literal(&it.next().unwrap())?);
            pending.push((s, it.next().unwrap()));
        }
        let fin_layer = self.state.final_exit().layer;
        let mut outs = Vec::with_capacity(b);
        let unfired: Vec<bool> =
            fired.iter().map(|f| f.is_none()).collect();
        let final_logits = if unfired.iter().any(|&n| n) {
            let xh = x.as_ref().expect("un-fired lanes ran all stages");
            self.head_logits_lanes(p - 1, fin_layer, xh, &unfired)?
        } else {
            Vec::new()
        };
        for (i, f) in fired.iter().enumerate() {
            if let Some(&(token, layer, stage)) = f.as_ref() {
                outs.push(WindowOutcome {
                    token,
                    exit_layer: layer,
                    stages_run: stage,
                });
            } else {
                let sum = summarize_logits(&final_logits[i]);
                outs.push(WindowOutcome {
                    token: sum.token,
                    exit_layer: fin_layer,
                    stages_run: p,
                });
            }
        }
        // Every fallible step is behind us: commit the device updates.
        for (s, lit) in pending {
            group.stacked[s] = lit;
        }
        Ok(outs)
    }

    /// The PR-5 fused pass, kept bit-for-bit as the measurable baseline
    /// (`lane_residency` off / serve-bench `--no-resident`): gather the
    /// lanes' caches per stage, run the batched executable, scatter the
    /// updates back — a full host round-trip per lane per stage per
    /// step, with per-lane solo exit-head calls.
    fn run_lanes_roundtrip(
        &mut self,
        lanes: &mut [LaneSlot<'_>],
    ) -> Result<Vec<WindowOutcome>> {
        let b = lanes.len();
        let p = self.state.man.stages.len();
        let h = self.state.man.model.hidden;
        // Sessions may arrive with stale handles if residency was live
        // earlier on this engine; sync them (no-op otherwise), and do
        // the hoisted shape validation once per pass.
        for lane in lanes.iter_mut() {
            self.claim(lane.caches)?;
        }
        self.validate_lane_shapes(lanes)?;
        // (token, exit layer, stages run) per fired lane.
        let mut fired: Vec<Option<(i32, usize, usize)>> = vec![None; b];
        let pos_lit = IntTensor::new(
            vec![b],
            lanes.iter().map(|l| l.pos as i32).collect(),
        )
        .to_literal()?;
        let mut x: Option<HostTensor> = None;
        // Cache scatters are deferred until the whole pass has
        // succeeded, so a mid-pass error leaves every lane's session
        // state untouched and the caller can retry those sessions on
        // the solo path.
        let mut pending: Vec<(usize, xla::Literal, Vec<bool>)> = Vec::new();
        for s in 0..p {
            // Entry exits (Optimization-2 placement) per un-fired lane,
            // on its slice of the batched hidden state.
            if let Some(xh) = x.as_ref() {
                for (i, lane) in lanes.iter().enumerate() {
                    if fired[i].is_some() || !lane.allow_exit {
                        continue;
                    }
                    let last = &xh.data[i * h..(i + 1) * h];
                    for e in self.state.entry_exits(s) {
                        let layer = e.layer;
                        if !self.policy.may_exit_at(layer) {
                            continue;
                        }
                        let logits = self.head_logits(s, layer, last)?;
                        let sum = summarize_logits(&logits);
                        if self.policy.decide(layer, &sum).is_exit() {
                            fired[i] = Some((sum.token, layer, s));
                            break;
                        }
                    }
                }
                if fired.iter().all(|f| f.is_some()) {
                    // Every lane has fired: deeper stages would only
                    // compute padding. Un-fired lanes never reach here,
                    // so they never pay for a skipped stage.
                    break;
                }
            }
            let in_lit: xla::Literal = if s == 0 {
                IntTensor::new(
                    vec![b],
                    lanes.iter().map(|l| l.token).collect(),
                )
                .to_literal()?
            } else {
                x.as_ref().unwrap().to_literal()?
            };
            let stacked = self.gather_lane_caches(lanes, s)?;
            let mut args: Vec<&xla::Literal> =
                self.plits[s].iter().collect();
            args.push(&in_lit);
            args.push(&stacked);
            args.push(&pos_lit);
            let out = self
                .rt
                .get(&format!("s{s}:decode_b{b}_w1"))?
                .run(&args)?;
            let mut it = out.into_iter();
            x = Some(HostTensor::from_literal(&it.next().unwrap())?);
            let new_caches = it.next().unwrap();
            let skip: Vec<bool> =
                fired.iter().map(|f| f.is_some()).collect();
            pending.push((s, new_caches, skip));
        }
        let fin_layer = self.state.final_exit().layer;
        let mut outs = Vec::with_capacity(b);
        for (i, f) in fired.iter().enumerate() {
            if let Some(&(token, layer, stage)) = f.as_ref() {
                outs.push(WindowOutcome {
                    token,
                    exit_layer: layer,
                    stages_run: stage,
                });
            } else {
                let xh = x.as_ref().expect("un-fired lanes ran all stages");
                let last = &xh.data[i * h..(i + 1) * h];
                let logits = self.head_logits(p - 1, fin_layer, last)?;
                let sum = summarize_logits(&logits);
                outs.push(WindowOutcome {
                    token: sum.token,
                    exit_layer: fin_layer,
                    stages_run: p,
                });
            }
        }
        // Every fallible step is behind us: commit the cache updates.
        for (s, stacked, skip) in &pending {
            self.scatter_lane_caches(lanes, *s, stacked, skip)?;
        }
        Ok(outs)
    }

    /// Generate up to `max_new` tokens after `prompt` (token ids, BOS
    /// prepended automatically) — a [`DecodeSession`] drained to
    /// completion.
    pub fn generate_tokens(
        &mut self,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<GenOutput> {
        let mut session = DecodeSession::new(self, prompt, max_new)?;
        session.drain(self)
    }

    pub fn generate_text(
        &mut self,
        prompt: &str,
        max_new: usize,
    ) -> Result<GenOutput> {
        let ids = crate::data::tokenizer::ByteTokenizer.encode(prompt);
        self.generate_tokens(&ids, max_new)
    }
}

impl DecodeBackend for SequentialEngine {
    /// One zeroed KV cache per stage, owned by the session — so many
    /// sessions can be live on one engine (continuous batching). The
    /// `generation` is a unique session id: lane residency keys
    /// device-resident groups and parked caches by it, so ids are never
    /// reused within an engine.
    fn fresh_caches(&mut self) -> Result<SessionCaches> {
        self.next_session += 1;
        Ok(SessionCaches {
            caches: self
                .state
                .man
                .stages
                .iter()
                .map(|st| HostTensor::zeros(&st.cache_shape).to_literal())
                .collect::<Result<Vec<_>>>()?,
            generation: self.next_session,
        })
    }

    fn run_window(
        &mut self,
        caches: &mut SessionCaches,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        allow_exit: bool,
        emit: bool,
    ) -> Result<WindowOutcome> {
        // A solo window on a session that was riding a resident fused
        // group: lazily sync its handle first (no-op otherwise).
        self.claim(caches)?;
        let (token, exit_layer, stages_run) = self.window_pass(
            tokens,
            pos0,
            width,
            &mut caches.caches,
            allow_exit,
            emit,
        )?;
        Ok(WindowOutcome { token, exit_layer, stages_run })
    }

    fn decode_widths(&self) -> &[usize] {
        &self.widths
    }

    fn decode_lanes(&self) -> &[usize] {
        &self.lanes
    }

    /// The lane-fused batched decode pass: one `decode_b{B}_w1` dispatch
    /// per stage advances every lane's width-1 window at once, with
    /// per-lane exit decisions at stage entries. Control flow mirrors
    /// [`SequentialEngine::window_pass`] per lane exactly — a fired lane
    /// reports `stages_run` at its exit — so fused and solo stepping are
    /// interchangeable mid-generation. With `lane_residency` on (the
    /// default) the pass steps a device-resident [`LaneGroup`] with zero
    /// per-step host cache traffic; off, it runs the gather/scatter
    /// round-trip baseline. Probe mode is a solo-path feature; fused
    /// passes are only issued by the serving pool, which never probes.
    fn run_lanes(
        &mut self,
        lanes: &mut [LaneSlot<'_>],
    ) -> Result<Vec<WindowOutcome>> {
        let b = lanes.len();
        ensure!(
            self.lanes.contains(&b),
            "no decode_b{b}_w1 executable (available lane sizes {:?})",
            self.lanes
        );
        if self.lane_residency {
            self.run_lanes_resident(lanes)
        } else {
            self.run_lanes_roundtrip(lanes)
        }
    }

    fn max_seq(&self) -> usize {
        self.state.man.model.max_seq
    }

    fn n_stages(&self) -> usize {
        self.state.man.stages.len()
    }

    fn exit_policy(&self) -> &ExitPolicy {
        &self.policy
    }

    fn tracks_deficit(&self) -> bool {
        true
    }

    fn max_live_sessions(&self) -> usize {
        usize::MAX
    }

    /// Sessions own their per-stage KV caches as plain literals, so the
    /// prefix cache can copy them to host and rebuild them freely.
    fn supports_cache_snapshots(&self) -> bool {
        true
    }

    /// Bytes-accurate snapshots: only the first `positions` entries of
    /// the position axis are copied to host — the rest of the
    /// fixed-shape cache is zeros-by-construction (prefill never wrote
    /// past the prompt), so a short prompt's snapshot is proportionally
    /// small whatever the cache capacity.
    fn snapshot_caches(
        &mut self,
        caches: &SessionCaches,
        positions: usize,
    ) -> Result<Vec<HostTensor>> {
        // The session may be riding a resident fused group, in which
        // case its handle is stale; dissolve the group so the parked
        // entry holds the truth. The handle itself can't be refreshed
        // through the shared reference — it syncs on the session's next
        // mutable touch (`run_window` / `run_lanes`) — so read from the
        // parked entry when one exists.
        self.dissolve_containing(caches.generation, None)?;
        let lits =
            self.parked.get(&caches.generation).unwrap_or(&caches.caches);
        lits.iter()
            .zip(&self.state.man.stages)
            .map(|(lit, st)| {
                let t = HostTensor::from_literal(lit)?;
                slice_cache_positions(&t, &st.cache_shape, positions)
                    .with_context(|| format!("stage {}", st.index))
            })
            .collect::<Result<Vec<_>>>()
            .context("snapshotting per-stage KV caches")
    }

    fn restore_caches(
        &mut self,
        snapshot: &[HostTensor],
    ) -> Result<SessionCaches> {
        let stages = &self.state.man.stages;
        ensure!(
            snapshot.len() == stages.len(),
            "snapshot has {} stage caches, engine has {} stages",
            snapshot.len(),
            stages.len()
        );
        let caches = snapshot
            .iter()
            .zip(stages)
            .map(|(t, st)| {
                // Position-sliced snapshots zero-pad back to capacity;
                // full-capacity ones pass through.
                pad_cache_to_capacity(t, &st.cache_shape)
                    .with_context(|| format!("stage {}", st.index))?
                    .to_literal()
            })
            .collect::<Result<Vec<_>>>()
            .context("restoring per-stage KV caches")?;
        self.next_session += 1;
        Ok(SessionCaches { caches, generation: self.next_session })
    }

    /// Scatter the session out of any resident fused group (dropping
    /// its own lane — nobody will read it) and free its parked entry,
    /// so closed sessions leak no engine-side state.
    fn release_caches(&mut self, caches: &SessionCaches) -> Result<()> {
        self.dissolve_containing(
            caches.generation,
            Some(caches.generation),
        )?;
        self.parked.remove(&caches.generation);
        Ok(())
    }

    fn lane_traffic(&self) -> LaneTraffic {
        self.traffic
    }
}

impl Generator for SequentialEngine {
    fn generate(&mut self, prompt: &str, max_new: usize) -> (String, f64) {
        match self.generate_text(prompt, max_new) {
            Ok(out) => (out.text, out.seconds),
            Err(e) => {
                eprintln!("generation error: {e:#}");
                (String::new(), 0.0)
            }
        }
    }
}
