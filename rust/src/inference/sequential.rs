//! Single-process early-exit inference with **KV recomputation**
//! (Section 4 / Appendix D.3), and the full-model baseline (an
//! [`ExitPolicy`] that can never exit: `Confidence{1.0}` or `Never`).
//!
//! State per generation: one KV cache per stage plus the *deficit* — the
//! trailing run of positions whose deep-layer KV entries are missing
//! because their tokens were emitted at an early exit. Every decode pass
//! processes a window that covers the deficit and the current position, so
//! the stages it does run recompute (heal) the missing entries; passes that
//! run all stages clear the deficit entirely. When the deficit approaches
//! the widest available decode window, early exiting is suspended for one
//! pass (the paper's forced full-model pass).
//!
//! Windows wider than the deficit are padded on the left with
//! already-healed positions: recomputation is idempotent (validated in
//! python/tests/test_decode.py), so this only costs compute — the batching
//! effect the paper relies on.

use anyhow::{ensure, Context, Result};

use crate::eval::harness::Generator;
use crate::runtime::client::StageRuntime;
use crate::runtime::tensor::{HostTensor, IntTensor};

use super::common::{
    pad_cache_to_capacity, slice_cache_positions, GenOutput, ModelState,
};
use super::policy::{summarize_logits, ExitPolicy};
use super::session::{
    DecodeBackend, DecodeSession, LaneSlot, SessionCaches, WindowOutcome,
};

/// Per-token probe record (Table 4): predictions + confidences at every
/// early exit and the final exit.
#[derive(Debug, Clone)]
pub struct TokenProbe {
    pub position: usize,
    /// (exit layer, predicted token, confidence), shallow to deep;
    /// the final exit is the last entry.
    pub exits: Vec<(usize, i32, f32)>,
}

pub struct SequentialEngine {
    pub state: ModelState,
    rt: StageRuntime,
    /// Per-stage parameter literals (cached; params are immutable here).
    plits: Vec<Vec<xla::Literal>>,
    /// Exit-decision policy every window pass consults
    /// ([`ExitPolicy::Confidence`] reproduces the paper's scalar rule).
    pub policy: ExitPolicy,
    widths: Vec<usize>,
    /// Fused-lane batch sizes with a `decode_b{B}_w1` executable on
    /// every stage (sorted; empty on manifests without lane fusion).
    lanes: Vec<usize>,
    /// Collect per-exit probes for every generated token (Table 4 mode).
    pub probe: bool,
    pub probes: Vec<TokenProbe>,
}

impl SequentialEngine {
    pub fn new(
        state: ModelState,
        policy: ExitPolicy,
    ) -> Result<SequentialEngine> {
        let mut rt = StageRuntime::cpu()?;
        // A lane size is usable only when *every* stage ships its
        // batched executable (tolerates hand-trimmed artifact sets).
        let lanes: Vec<usize> = {
            let mut lanes: Vec<usize> = state
                .man
                .decode_lanes
                .iter()
                .copied()
                .filter(|b| {
                    state.man.stages.iter().all(|st| {
                        st.executables.contains_key(&format!("decode_b{b}_w1"))
                    })
                })
                .collect();
            lanes.sort_unstable();
            lanes.dedup();
            lanes
        };
        for st in &state.man.stages {
            for w in &state.man.decode_widths {
                let key = format!("decode_w{w}");
                rt.load(
                    &format!("s{}:{key}", st.index),
                    &state.man.exec_path(st.exec(&key)?),
                )?;
            }
            for b in &lanes {
                let key = format!("decode_b{b}_w1");
                rt.load(
                    &format!("s{}:{key}", st.index),
                    &state.man.exec_path(st.exec(&key)?),
                )?;
            }
            for e in &st.exits {
                let key = format!("head{}", e.layer);
                rt.load(
                    &format!("s{}:{key}", st.index),
                    &state.man.exec_path(st.exec(&key)?),
                )?;
            }
        }
        let plits = state
            .stage_params
            .iter()
            .map(|ps| ps.iter().map(|p| p.to_literal()).collect())
            .collect::<Result<Vec<Vec<_>>>>()?;
        let widths = state.man.decode_widths.clone();
        Ok(SequentialEngine {
            state,
            rt,
            plits,
            policy,
            widths,
            lanes,
            probe: false,
            probes: Vec::new(),
        })
    }

    fn head_logits(&self, s: usize, layer: usize, x: &[f32]) -> Result<Vec<f32>> {
        let st = &self.state.man.stages[s];
        let e = st
            .exits
            .iter()
            .find(|e| e.layer == layer)
            .context("exit not on stage")?;
        let xlit = HostTensor::new(vec![x.len()], x.to_vec()).to_literal()?;
        let mut args: Vec<&xla::Literal> = e
            .head_param_idx
            .iter()
            .map(|&i| &self.plits[s][i])
            .collect();
        args.push(&xlit);
        let out = self
            .rt
            .get(&format!("s{s}:head{layer}"))?
            .run(&args)?;
        Ok(HostTensor::from_literal(&out[0])?.data)
    }

    /// Run one decode window pass.
    ///
    /// Returns (emitted token, exit layer, stages_run). Exit checks are
    /// skipped when `allow_exit` is false (prefill / forced-full passes).
    /// When `emit` is false (pure prefill) the pass always runs all stages
    /// and returns token = -1.
    #[allow(clippy::too_many_arguments)]
    fn window_pass(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        caches: &mut [xla::Literal],
        allow_exit: bool,
        emit: bool,
    ) -> Result<(i32, usize, usize)> {
        let p = self.state.man.stages.len();
        let h = self.state.man.model.hidden;
        let window = &tokens[pos0..pos0 + width];
        let pos_lit = IntTensor::scalar(pos0 as i32).to_literal()?;
        let mut x: Option<HostTensor> = None;
        let mut probe = TokenProbe {
            position: pos0 + width - 1,
            exits: Vec::new(),
        };

        for s in 0..p {
            // Entry exits (paper: Optimization-2 placement). Head logits
            // are only worth computing when someone consumes them — an
            // exit decision or a probe record. In particular the
            // full-model baseline (`allow_exit` false: prefill, forced
            // full passes, or a policy that can never exit) skips every
            // exit head, which is exactly what the paper's speedup
            // denominator should cost.
            if let Some(xh) = x.as_ref().filter(|_| {
                emit && (allow_exit || self.probe)
            }) {
                let last = &xh.data[(width - 1) * h..];
                for e in self.state.entry_exits(s) {
                    let layer = e.layer;
                    // Layers where the policy can never fire (unlisted
                    // or 1.0 in a PerLayer) only matter to the probe.
                    if !self.probe && !self.policy.may_exit_at(layer) {
                        continue;
                    }
                    let logits = self.head_logits(s, layer, last)?;
                    let sum = summarize_logits(&logits);
                    if self.probe && emit {
                        probe.exits.push((layer, sum.token, sum.top_prob));
                    }
                    if allow_exit
                        && emit
                        && self.policy.decide(layer, &sum).is_exit()
                    {
                        if self.probe {
                            self.probes.push(probe);
                        }
                        return Ok((sum.token, layer, s));
                    }
                }
            }
            // Stage decode.
            let in_lit: xla::Literal = if s == 0 {
                IntTensor::new(vec![width], window.to_vec()).to_literal()?
            } else {
                x.as_ref().unwrap().to_literal()?
            };
            // Perf pass §L3-2: the KV cache stays an xla::Literal across
            // steps — no host round-trip of ~0.5-2 MiB per stage per token.
            let mut args: Vec<&xla::Literal> = self.plits[s].iter().collect();
            args.push(&in_lit);
            args.push(&caches[s]);
            args.push(&pos_lit);
            let out = self
                .rt
                .get(&format!("s{s}:decode_w{width}"))?
                .run(&args)?;
            let mut it = out.into_iter();
            x = Some(HostTensor::from_literal(&it.next().unwrap())?);
            caches[s] = it.next().unwrap();
        }

        if !emit {
            return Ok((-1, 0, p));
        }
        let xh = x.unwrap();
        let last = &xh.data[(width - 1) * h..];
        let fin = self.state.final_exit();
        let logits = self.head_logits(p - 1, fin.layer, last)?;
        let sum = summarize_logits(&logits);
        if self.probe {
            probe.exits.push((fin.layer, sum.token, sum.top_prob));
            self.probes.push(probe);
        }
        Ok((sum.token, fin.layer, p))
    }

    /// Stack the lanes' per-session stage-`s` caches into the fused
    /// `[B, ...cache_shape]` layout one batched executable consumes.
    ///
    /// Known cost: this is a host round-trip of each lane's full
    /// fixed-shape cache per stage per fused step (the solo path keeps
    /// caches device-resident, §L3-2), traded for correctness-first
    /// group membership that may change every round. Keeping a
    /// lane-stacked literal device-resident across a group's lifetime
    /// is the ROADMAP next step; the serving benches report the
    /// fused-vs-solo throughput ratio so the trade stays visible.
    fn gather_lane_caches(
        &self,
        lanes: &[LaneSlot<'_>],
        s: usize,
    ) -> Result<xla::Literal> {
        let shape = &self.state.man.stages[s].cache_shape;
        let len: usize = shape.iter().product();
        let mut data = Vec::with_capacity(lanes.len() * len);
        for lane in lanes {
            let t = HostTensor::from_literal(&lane.caches.caches[s])?;
            ensure!(
                t.shape == *shape,
                "lane cache shape {:?} != stage {s} cache shape {shape:?}",
                t.shape
            );
            data.extend_from_slice(&t.data);
        }
        let mut full = Vec::with_capacity(shape.len() + 1);
        full.push(lanes.len());
        full.extend_from_slice(shape);
        HostTensor::new(full, data).to_literal()
    }

    /// Scatter a fused pass's updated stage-`s` caches back to their
    /// sessions. Lanes with `skip[i]` set (already fired at an earlier
    /// stage entry) keep their pre-pass literal: the solo path never
    /// runs stages at or beyond an exit, and mirroring that here keeps
    /// the per-session cache state — and therefore every downstream
    /// deficit-heal window — bit-identical to unfused decoding.
    fn scatter_lane_caches(
        &self,
        lanes: &mut [LaneSlot<'_>],
        s: usize,
        stacked: &xla::Literal,
        skip: &[bool],
    ) -> Result<()> {
        let shape = &self.state.man.stages[s].cache_shape;
        let len: usize = shape.iter().product();
        let t = HostTensor::from_literal(stacked)?;
        ensure!(
            t.data.len() == lanes.len() * len,
            "fused stage {s} cache output has {} elements, want {}",
            t.data.len(),
            lanes.len() * len
        );
        for (i, lane) in lanes.iter_mut().enumerate() {
            if skip[i] {
                continue;
            }
            let chunk = t.data[i * len..(i + 1) * len].to_vec();
            lane.caches.caches[s] =
                HostTensor::new(shape.clone(), chunk).to_literal()?;
        }
        Ok(())
    }

    /// Generate up to `max_new` tokens after `prompt` (token ids, BOS
    /// prepended automatically) — a [`DecodeSession`] drained to
    /// completion.
    pub fn generate_tokens(
        &mut self,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<GenOutput> {
        let mut session = DecodeSession::new(self, prompt, max_new)?;
        session.drain(self)
    }

    pub fn generate_text(
        &mut self,
        prompt: &str,
        max_new: usize,
    ) -> Result<GenOutput> {
        let ids = crate::data::tokenizer::ByteTokenizer.encode(prompt);
        self.generate_tokens(&ids, max_new)
    }
}

impl DecodeBackend for SequentialEngine {
    /// One zeroed KV cache per stage, owned by the session — so many
    /// sessions can be live on one engine (continuous batching).
    fn fresh_caches(&mut self) -> Result<SessionCaches> {
        Ok(SessionCaches {
            caches: self
                .state
                .man
                .stages
                .iter()
                .map(|st| HostTensor::zeros(&st.cache_shape).to_literal())
                .collect::<Result<Vec<_>>>()?,
            // All decode state is session-owned; generations are moot.
            generation: 0,
        })
    }

    fn run_window(
        &mut self,
        caches: &mut SessionCaches,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        allow_exit: bool,
        emit: bool,
    ) -> Result<WindowOutcome> {
        let (token, exit_layer, stages_run) = self.window_pass(
            tokens,
            pos0,
            width,
            &mut caches.caches,
            allow_exit,
            emit,
        )?;
        Ok(WindowOutcome { token, exit_layer, stages_run })
    }

    fn decode_widths(&self) -> &[usize] {
        &self.widths
    }

    fn decode_lanes(&self) -> &[usize] {
        &self.lanes
    }

    /// The lane-fused batched decode pass: one `decode_b{B}_w1` dispatch
    /// per stage advances every lane's width-1 window at once, with
    /// per-lane exit decisions at stage entries. Control flow and cache
    /// effects mirror [`SequentialEngine::window_pass`] per lane exactly
    /// — a fired lane reports `stages_run` at its exit and keeps its
    /// deeper-stage caches untouched (it rides the batch as padding
    /// until every lane has fired, at which point the remaining stages
    /// are skipped) — so fused and solo stepping are interchangeable
    /// mid-generation. Probe mode is a solo-path feature; fused passes
    /// are only issued by the serving pool, which never probes.
    fn run_lanes(
        &mut self,
        lanes: &mut [LaneSlot<'_>],
    ) -> Result<Vec<WindowOutcome>> {
        let b = lanes.len();
        ensure!(
            self.lanes.contains(&b),
            "no decode_b{b}_w1 executable (available lane sizes {:?})",
            self.lanes
        );
        let p = self.state.man.stages.len();
        let h = self.state.man.model.hidden;
        // (token, exit layer, stages run) per fired lane.
        let mut fired: Vec<Option<(i32, usize, usize)>> = vec![None; b];
        let pos_lit = IntTensor::new(
            vec![b],
            lanes.iter().map(|l| l.pos as i32).collect(),
        )
        .to_literal()?;
        let mut x: Option<HostTensor> = None;
        // Cache scatters are deferred until the whole pass has
        // succeeded, so a mid-pass error leaves every lane's session
        // state untouched and the caller can retry those sessions on
        // the solo path.
        let mut pending: Vec<(usize, xla::Literal, Vec<bool>)> = Vec::new();
        for s in 0..p {
            // Entry exits (Optimization-2 placement) per un-fired lane,
            // on its slice of the batched hidden state.
            if let Some(xh) = x.as_ref() {
                for (i, lane) in lanes.iter().enumerate() {
                    if fired[i].is_some() || !lane.allow_exit {
                        continue;
                    }
                    let last = &xh.data[i * h..(i + 1) * h];
                    for e in self.state.entry_exits(s) {
                        let layer = e.layer;
                        if !self.policy.may_exit_at(layer) {
                            continue;
                        }
                        let logits = self.head_logits(s, layer, last)?;
                        let sum = summarize_logits(&logits);
                        if self.policy.decide(layer, &sum).is_exit() {
                            fired[i] = Some((sum.token, layer, s));
                            break;
                        }
                    }
                }
                if fired.iter().all(|f| f.is_some()) {
                    // Every lane has fired: deeper stages would only
                    // compute padding. Un-fired lanes never reach here,
                    // so they never pay for a skipped stage.
                    break;
                }
            }
            let in_lit: xla::Literal = if s == 0 {
                IntTensor::new(
                    vec![b],
                    lanes.iter().map(|l| l.token).collect(),
                )
                .to_literal()?
            } else {
                x.as_ref().unwrap().to_literal()?
            };
            let stacked = self.gather_lane_caches(lanes, s)?;
            let mut args: Vec<&xla::Literal> =
                self.plits[s].iter().collect();
            args.push(&in_lit);
            args.push(&stacked);
            args.push(&pos_lit);
            let out = self
                .rt
                .get(&format!("s{s}:decode_b{b}_w1"))?
                .run(&args)?;
            let mut it = out.into_iter();
            x = Some(HostTensor::from_literal(&it.next().unwrap())?);
            let new_caches = it.next().unwrap();
            let skip: Vec<bool> =
                fired.iter().map(|f| f.is_some()).collect();
            pending.push((s, new_caches, skip));
        }
        let fin_layer = self.state.final_exit().layer;
        let mut outs = Vec::with_capacity(b);
        for (i, f) in fired.iter().enumerate() {
            if let Some(&(token, layer, stage)) = f.as_ref() {
                outs.push(WindowOutcome {
                    token,
                    exit_layer: layer,
                    stages_run: stage,
                });
            } else {
                let xh = x.as_ref().expect("un-fired lanes ran all stages");
                let last = &xh.data[i * h..(i + 1) * h];
                let logits = self.head_logits(p - 1, fin_layer, last)?;
                let sum = summarize_logits(&logits);
                outs.push(WindowOutcome {
                    token: sum.token,
                    exit_layer: fin_layer,
                    stages_run: p,
                });
            }
        }
        // Every fallible step is behind us: commit the cache updates.
        for (s, stacked, skip) in &pending {
            self.scatter_lane_caches(lanes, *s, stacked, skip)?;
        }
        Ok(outs)
    }

    fn max_seq(&self) -> usize {
        self.state.man.model.max_seq
    }

    fn n_stages(&self) -> usize {
        self.state.man.stages.len()
    }

    fn exit_policy(&self) -> &ExitPolicy {
        &self.policy
    }

    fn tracks_deficit(&self) -> bool {
        true
    }

    fn max_live_sessions(&self) -> usize {
        usize::MAX
    }

    /// Sessions own their per-stage KV caches as plain literals, so the
    /// prefix cache can copy them to host and rebuild them freely.
    fn supports_cache_snapshots(&self) -> bool {
        true
    }

    /// Bytes-accurate snapshots: only the first `positions` entries of
    /// the position axis are copied to host — the rest of the
    /// fixed-shape cache is zeros-by-construction (prefill never wrote
    /// past the prompt), so a short prompt's snapshot is proportionally
    /// small whatever the cache capacity.
    fn snapshot_caches(
        &mut self,
        caches: &SessionCaches,
        positions: usize,
    ) -> Result<Vec<HostTensor>> {
        caches
            .caches
            .iter()
            .zip(&self.state.man.stages)
            .map(|(lit, st)| {
                let t = HostTensor::from_literal(lit)?;
                slice_cache_positions(&t, &st.cache_shape, positions)
                    .with_context(|| format!("stage {}", st.index))
            })
            .collect::<Result<Vec<_>>>()
            .context("snapshotting per-stage KV caches")
    }

    fn restore_caches(
        &mut self,
        snapshot: &[HostTensor],
    ) -> Result<SessionCaches> {
        let stages = &self.state.man.stages;
        ensure!(
            snapshot.len() == stages.len(),
            "snapshot has {} stage caches, engine has {} stages",
            snapshot.len(),
            stages.len()
        );
        let caches = snapshot
            .iter()
            .zip(stages)
            .map(|(t, st)| {
                // Position-sliced snapshots zero-pad back to capacity;
                // full-capacity ones pass through.
                pad_cache_to_capacity(t, &st.cache_shape)
                    .with_context(|| format!("stage {}", st.index))?
                    .to_literal()
            })
            .collect::<Result<Vec<_>>>()
            .context("restoring per-stage KV caches")?;
        Ok(SessionCaches { caches, generation: 0 })
    }
}

impl Generator for SequentialEngine {
    fn generate(&mut self, prompt: &str, max_new: usize) -> (String, f64) {
        match self.generate_text(prompt, max_new) {
            Ok(out) => (out.text, out.seconds),
            Err(e) => {
                eprintln!("generation error: {e:#}");
                (String::new(), 0.0)
            }
        }
    }
}
