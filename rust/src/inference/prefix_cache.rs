//! Shared-prefix KV-cache store — a token-trie keyed store of immutable
//! prefill snapshots, so decode sessions whose prompts share a prefix
//! (templated / system-prompt traffic) pay prefill only for the suffix.
//!
//! The store holds [`CacheSnapshot`]s: host-side copies of a session's
//! per-stage KV caches, together with the token prefix they cover and
//! the recompute deficit they carry (Section 4 / Appendix D.3 — trailing
//! positions whose deep-layer KV entries an early exit left missing).
//! Snapshots come from two boundaries: right after prefill
//! ([`DecodeSession::prefix_snapshot`], shared-prompt reuse) and at
//! end-of-turn once decoding completes
//! ([`DecodeSession::finish_snapshot`], conversational reuse — keyed
//! under prompt ⧺ generated so the next turn restores the whole
//! history and prefills only its own new text). Snapshots are immutable
//! and handed out by `Arc`, so a restore never races an eviction.
//!
//! Semantics:
//!
//! - **Lookup** walks the token trie and returns the entry with the
//!   *longest common prefix* against the query (maximal by construction:
//!   trie nodes exist only on paths to live entries). The caller may
//!   trust restored KV entries for positions below
//!   `matched.min(healed frontier)` and must re-run the rest — tokens
//!   past the common prefix differ, and the snapshot's deficit region
//!   was never fully healed.
//! - **Pinning** — a hit returns a [`PinnedSnapshot`] guard; entries with
//!   live pins are never evicted. Sessions hold their pin until they
//!   finish, so a hot prefix stays resident while anyone decodes from it.
//! - **Eviction** is LRU over unpinned entries under a configurable
//!   budget of cached positions; inserts that cannot fit (budget smaller
//!   than the snapshot, or every resident entry pinned) are rejected
//!   rather than ever exceeding the budget.
//! - **Counters** — hits, misses, insertions, rejections, evictions,
//!   evicted positions, and prefill positions saved (reported by the
//!   sessions that skipped them) for [`ServeMetrics`].
//!
//! The properties above are enforced by the model-based property tests at
//! the bottom of this file and by `rust/tests/prefix_cache_equivalence.rs`
//! (cache-on outputs are token-for-token and exit-layer-for-exit-layer
//! identical to cache-off).
//!
//! [`ServeMetrics`]: crate::serve::ServeMetrics
//! [`DecodeSession::prefix_snapshot`]: super::session::DecodeSession::prefix_snapshot
//! [`DecodeSession::finish_snapshot`]: super::session::DecodeSession::finish_snapshot

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::tensor::HostTensor;

/// Shortest prefix worth caching: BOS plus at least one real token.
/// Every token buffer starts with BOS, so a 1-token "shared prefix"
/// saves nothing and would still burn a budget position.
const MIN_PREFIX: usize = 2;

/// An immutable prefill-state snapshot: everything a session needs to
/// resume decoding after `tokens` as if it had prefilled them itself.
///
/// Sizing is bytes-accurate: `stage_caches` holds each stage's cache
/// *sliced to the live prefix* along the position axis
/// ([`DecodeBackend::snapshot_caches`] with the prefilled position
/// count), so a short prompt's snapshot is proportionally small
/// whatever the cache capacity — and the budget charges the positions
/// actually held ([`CacheSnapshot::positions`]), not the key length.
///
/// [`DecodeBackend::snapshot_caches`]: super::session::DecodeBackend::snapshot_caches
#[derive(Debug, Clone)]
pub struct CacheSnapshot {
    /// Token prefix the snapshot covers (BOS included).
    pub tokens: Vec<i32>,
    /// Host-side copy of the per-stage KV caches
    /// ([`DecodeBackend::snapshot_caches`]).
    ///
    /// [`DecodeBackend::snapshot_caches`]: super::session::DecodeBackend::snapshot_caches
    pub stage_caches: Vec<HostTensor>,
    /// Recompute-deficit bookkeeping carried across the store: the number
    /// of trailing positions healed by fewer than all stages when the
    /// snapshot was taken. Restorers must not trust KV entries at
    /// positions `>= tokens.len() - 1 - deficit` (the healed frontier)
    /// without re-running them.
    pub deficit: usize,
}

impl CacheSnapshot {
    /// Budget weight of the snapshot: the KV positions it actually
    /// holds, read from the sliced cache tensors' position axis.
    /// Tensor-less snapshots (store unit tests, older callers) fall
    /// back to the token-key length as before.
    pub fn positions(&self) -> usize {
        match self.stage_caches.first() {
            Some(t) if t.shape.len() == 5 => t.shape[2],
            _ => self.tokens.len(),
        }
    }

    /// Host memory the snapshot occupies (the bytes-accurate quantity
    /// the position budget is a proxy for).
    pub fn bytes(&self) -> usize {
        self.stage_caches.iter().map(|t| t.bytes()).sum()
    }

    /// First position whose KV entries are *not* fully healed: trailing
    /// deficit positions were only partially recomputed, and the last
    /// token's position was never prefilled at all.
    pub fn healed_frontier(&self) -> usize {
        self.tokens
            .len()
            .saturating_sub(1)
            .saturating_sub(self.deficit)
    }
}

/// Activity counters of a [`PrefixCacheStore`] (monotonic; diff two
/// readings with [`PrefixCacheStats::since`] to attribute one batch).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Lookups that returned a usable shared prefix.
    pub hits: u64,
    /// Lookups with no shared prefix of at least two positions.
    pub misses: u64,
    /// Snapshots stored.
    pub insertions: u64,
    /// Inserts refused: snapshot over budget, too short to ever help, or
    /// every resident entry pinned.
    pub rejected: u64,
    /// Entries evicted (LRU under the position budget).
    pub evictions: u64,
    /// Positions those evictions released.
    pub evicted_positions: u64,
    /// Prefill positions sessions skipped thanks to hits (reported via
    /// [`PrefixCacheStore::record_saved`]).
    pub saved_positions: u64,
}

impl PrefixCacheStats {
    /// Total lookups.
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction (0 when the store was never consulted).
    pub fn hit_rate(&self) -> f64 {
        self.hits as f64 / (self.lookups().max(1)) as f64
    }

    /// Accumulate another store's counters into one [`ServeMetrics`]
    /// reading (the pool shares a single store today, but stats from
    /// several stores — e.g. multiple pools — still merge).
    ///
    /// [`ServeMetrics`]: crate::serve::ServeMetrics
    pub fn merge(&mut self, other: &PrefixCacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.insertions += other.insertions;
        self.rejected += other.rejected;
        self.evictions += other.evictions;
        self.evicted_positions += other.evicted_positions;
        self.saved_positions += other.saved_positions;
    }

    /// Counter delta `self - baseline` (saturating): the activity since
    /// an earlier reading of the same store.
    pub fn since(&self, baseline: &PrefixCacheStats) -> PrefixCacheStats {
        PrefixCacheStats {
            hits: self.hits.saturating_sub(baseline.hits),
            misses: self.misses.saturating_sub(baseline.misses),
            insertions: self.insertions.saturating_sub(baseline.insertions),
            rejected: self.rejected.saturating_sub(baseline.rejected),
            evictions: self.evictions.saturating_sub(baseline.evictions),
            evicted_positions: self
                .evicted_positions
                .saturating_sub(baseline.evicted_positions),
            saved_positions: self
                .saved_positions
                .saturating_sub(baseline.saved_positions),
        }
    }
}

/// One stored snapshot plus its bookkeeping. Shared by `Arc` between the
/// store (trie + index) and outstanding [`PinnedSnapshot`] guards.
struct Entry {
    snap: CacheSnapshot,
    /// Live [`PinnedSnapshot`] guards; entries with pins are never
    /// evicted. Increments happen under the store lock, decrements on
    /// guard drop (lock-free) — so a zero observed under the lock stays
    /// zero for the duration of the critical section.
    pins: AtomicUsize,
    /// Logical LRU clock reading of the last touch (insert or hit).
    last_used: AtomicU64,
}

/// RAII pin on a cached snapshot: the entry cannot be evicted while any
/// pin is live. Sessions hold their pin until they finish decoding.
pub struct PinnedSnapshot {
    entry: Arc<Entry>,
}

impl PinnedSnapshot {
    pub fn snapshot(&self) -> &CacheSnapshot {
        &self.entry.snap
    }

    /// Token key of the pinned snapshot.
    pub fn tokens(&self) -> &[i32] {
        &self.entry.snap.tokens
    }
}

impl Clone for PinnedSnapshot {
    fn clone(&self) -> PinnedSnapshot {
        self.entry.pins.fetch_add(1, Ordering::AcqRel);
        PinnedSnapshot { entry: Arc::clone(&self.entry) }
    }
}

impl Drop for PinnedSnapshot {
    fn drop(&mut self) {
        self.entry.pins.fetch_sub(1, Ordering::AcqRel);
    }
}

/// A successful lookup: the pinned snapshot plus how much of the query
/// it matched.
pub struct PrefixHit {
    pub snapshot: PinnedSnapshot,
    /// Length of the common prefix between the query and the snapshot's
    /// token key (>= 2). Restored KV entries are trustworthy below
    /// `matched.min(snapshot.healed_frontier())`.
    pub matched: usize,
}

/// What a decode session needs from a snapshot store at prefill time:
/// a longest-common-prefix lookup plus saved-position attribution.
/// Implemented by [`PrefixCacheStore`] (host tier) and by the tiered
/// device+host store ([`TieredStore`]), so session code is agnostic to
/// which one the pool wired in.
///
/// [`TieredStore`]: super::tiered_store::TieredStore
pub trait SnapshotSource {
    /// Longest-common-prefix lookup (see [`PrefixCacheStore::lookup`]).
    fn lookup(&self, query: &[i32]) -> Option<PrefixHit>;
    /// Attribute prefill positions skipped thanks to a hit.
    fn record_saved(&self, positions: u64);
}

#[derive(Default)]
struct TrieNode {
    children: BTreeMap<i32, TrieNode>,
    entry: Option<Arc<Entry>>,
}

/// Remove the entry at `tokens`, pruning now-empty nodes on unwind.
/// Returns true when `node` itself became prunable.
fn trie_remove(node: &mut TrieNode, tokens: &[i32]) -> bool {
    match tokens.split_first() {
        None => node.entry = None,
        Some((&t, rest)) => {
            if let Some(child) = node.children.get_mut(&t) {
                if trie_remove(child, rest) {
                    node.children.remove(&t);
                }
            }
        }
    }
    node.entry.is_none() && node.children.is_empty()
}

/// Shallowest entry in `node`'s subtree (ties: smallest token path —
/// `BTreeMap` keeps children sorted, so level order is deterministic).
fn min_depth_entry(node: &TrieNode) -> Option<Arc<Entry>> {
    let mut level: Vec<&TrieNode> = vec![node];
    while !level.is_empty() {
        for n in &level {
            if let Some(e) = &n.entry {
                return Some(Arc::clone(e));
            }
        }
        level = level.iter().flat_map(|n| n.children.values()).collect();
    }
    None
}

struct Inner {
    root: TrieNode,
    /// Key -> entry, for budget accounting and LRU victim scans.
    index: BTreeMap<Vec<i32>, Arc<Entry>>,
    used_positions: usize,
    clock: u64,
    stats: PrefixCacheStats,
}

/// Thread-safe prefix KV-cache store. The serving pool shares one store
/// across all its workers (the internal lock makes that safe); snapshots
/// are engine-independent host tensors, so a prefix prefilled on one
/// worker's engine restores onto any same-shaped engine.
pub struct PrefixCacheStore {
    max_positions: usize,
    inner: Mutex<Inner>,
}

impl PrefixCacheStore {
    /// A store that may hold at most `max_positions` cached positions
    /// (summed over resident snapshots).
    pub fn new(max_positions: usize) -> PrefixCacheStore {
        PrefixCacheStore {
            max_positions,
            inner: Mutex::new(Inner {
                root: TrieNode::default(),
                index: BTreeMap::new(),
                used_positions: 0,
                clock: 0,
                stats: PrefixCacheStats::default(),
            }),
        }
    }

    pub fn max_positions(&self) -> usize {
        self.max_positions
    }

    /// Cached positions currently resident.
    pub fn used_positions(&self) -> usize {
        self.inner.lock().unwrap().used_positions
    }

    /// Resident snapshots.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident snapshots with at least one live pin.
    pub fn pinned_entries(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .index
            .values()
            .filter(|e| e.pins.load(Ordering::Acquire) > 0)
            .count()
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PrefixCacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Attribute `positions` prefill positions skipped thanks to a hit
    /// (called by the session that performed the cached prefill).
    pub fn record_saved(&self, positions: u64) {
        self.inner.lock().unwrap().stats.saved_positions += positions;
    }

    /// Longest-common-prefix lookup: the entry sharing the most leading
    /// tokens with `query` (maximal — the trie walk depth *is* the best
    /// achievable match, since nodes exist only on paths to entries).
    /// Returns `None`, and counts a miss, when no entry shares at least
    /// two positions. A hit pins the entry and refreshes its LRU slot.
    pub fn lookup(&self, query: &[i32]) -> Option<PrefixHit> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let mut node = &inner.root;
        let mut depth = 0usize;
        for &t in query {
            match node.children.get(&t) {
                Some(child) => {
                    node = child;
                    depth += 1;
                }
                None => break,
            }
        }
        let best = if depth >= MIN_PREFIX { min_depth_entry(node) } else { None };
        match best {
            Some(entry) => {
                inner.clock += 1;
                entry.last_used.store(inner.clock, Ordering::Relaxed);
                entry.pins.fetch_add(1, Ordering::AcqRel);
                inner.stats.hits += 1;
                Some(PrefixHit {
                    snapshot: PinnedSnapshot { entry },
                    matched: depth,
                })
            }
            None => {
                inner.stats.misses += 1;
                None
            }
        }
    }

    /// Store a snapshot, evicting LRU unpinned entries as needed to stay
    /// within the position budget. Returns false — and stores nothing —
    /// when the snapshot is too short to ever help, already present
    /// (its entry's LRU slot is refreshed instead), over the whole
    /// budget, or cannot fit because every resident entry is pinned.
    pub fn insert(&self, snap: CacheSnapshot) -> bool {
        let need = snap.positions();
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        if need < MIN_PREFIX || need > self.max_positions {
            inner.stats.rejected += 1;
            return false;
        }
        if let Some(existing) = inner.index.get(&snap.tokens) {
            inner.clock += 1;
            existing.last_used.store(inner.clock, Ordering::Relaxed);
            return false;
        }
        // Feasibility before any eviction: reclaiming can only free
        // unpinned positions, so an insert that cannot fit even after
        // flushing every unpinned entry must be rejected up front —
        // not after collateral-evicting the whole hot working set.
        if Self::pinned_positions_locked(inner) + need > self.max_positions {
            inner.stats.rejected += 1;
            return false;
        }
        while inner.used_positions + need > self.max_positions {
            if Self::evict_lru_locked(inner).is_none() {
                // Unreachable given the feasibility check; never loop.
                inner.stats.rejected += 1;
                return false;
            }
        }
        inner.clock += 1;
        let entry = Arc::new(Entry {
            pins: AtomicUsize::new(0),
            last_used: AtomicU64::new(inner.clock),
            snap,
        });
        let mut node = &mut inner.root;
        for &t in &entry.snap.tokens {
            node = node.children.entry(t).or_default();
        }
        node.entry = Some(Arc::clone(&entry));
        inner.used_positions += need;
        inner.index.insert(entry.snap.tokens.clone(), entry);
        inner.stats.insertions += 1;
        true
    }

    /// Whether a snapshot of `positions` could currently be admitted:
    /// within the whole budget and not blocked by pinned entries. A
    /// cheap pre-check so callers can skip building an expensive
    /// snapshot (a full host copy of the KV caches) that the store
    /// would only reject. Advisory under the pool's shared store
    /// (another worker may insert between the check and the insert);
    /// `insert` itself re-checks under the lock, so the race only costs
    /// a wasted snapshot copy, never a budget violation.
    pub fn would_admit(&self, positions: usize) -> bool {
        if positions < MIN_PREFIX || positions > self.max_positions {
            return false;
        }
        let inner = self.inner.lock().unwrap();
        Self::pinned_positions_locked(&inner) + positions
            <= self.max_positions
    }

    /// Evict the least-recently-used unpinned entry, returning its token
    /// key (`None` when nothing is evictable). Exposed for tests and for
    /// manual trimming.
    pub fn evict_one(&self) -> Option<Vec<i32>> {
        Self::evict_lru_locked(&mut self.inner.lock().unwrap())
    }

    /// Remove the entry stored under exactly `tokens`, if present and
    /// unpinned. Unlike eviction this is a deliberate drop (conversation
    /// TTL expiry), so it is *not* counted in the eviction stats —
    /// expiry must not masquerade as budget pressure.
    pub fn remove(&self, tokens: &[i32]) -> bool {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        match inner.index.get(tokens) {
            Some(e) if e.pins.load(Ordering::Acquire) == 0 => {}
            _ => return false,
        }
        let entry = inner.index.remove(tokens).unwrap();
        trie_remove(&mut inner.root, tokens);
        inner.used_positions -= entry.snap.positions();
        true
    }

    /// Host memory held by resident snapshots (the bytes-accurate
    /// quantity the position budget is a proxy for).
    pub fn used_bytes(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .index
            .values()
            .map(|e| e.snap.bytes())
            .sum()
    }

    /// Positions held by entries with live pins (not reclaimable).
    fn pinned_positions_locked(inner: &Inner) -> usize {
        inner
            .index
            .values()
            .filter(|e| e.pins.load(Ordering::Acquire) > 0)
            .map(|e| e.snap.positions())
            .sum()
    }

    fn evict_lru_locked(inner: &mut Inner) -> Option<Vec<i32>> {
        let victim = inner
            .index
            .iter()
            .filter(|(_, e)| e.pins.load(Ordering::Acquire) == 0)
            .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
            .map(|(k, _)| k.clone())?;
        let entry = inner.index.remove(&victim).unwrap();
        trie_remove(&mut inner.root, &victim);
        inner.used_positions -= entry.snap.positions();
        inner.stats.evictions += 1;
        inner.stats.evicted_positions += entry.snap.positions() as u64;
        Some(victim)
    }
}

impl SnapshotSource for PrefixCacheStore {
    fn lookup(&self, query: &[i32]) -> Option<PrefixHit> {
        PrefixCacheStore::lookup(self, query)
    }

    fn record_saved(&self, positions: u64) {
        PrefixCacheStore::record_saved(self, positions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    /// Snapshot with no tensors — the store never inspects them, so the
    /// trie/LRU/pinning machinery can be tested without a model.
    fn snap(tokens: &[i32]) -> CacheSnapshot {
        CacheSnapshot {
            tokens: tokens.to_vec(),
            stage_caches: Vec::new(),
            deficit: 0,
        }
    }

    #[test]
    fn lookup_returns_longest_common_prefix() {
        let s = PrefixCacheStore::new(64);
        assert!(s.insert(snap(&[1, 2, 3])));
        assert!(s.insert(snap(&[1, 2, 3, 4, 5])));
        assert!(s.insert(snap(&[1, 9])));
        // Query diverges after [1,2,3,4]: the deepest walkable node is
        // depth 4, and the shallowest entry below it is the 5-key.
        let hit = s.lookup(&[1, 2, 3, 4, 9, 9]).expect("hit");
        assert_eq!(hit.matched, 4);
        assert_eq!(hit.snapshot.tokens(), &[1, 2, 3, 4, 5]);
        // Exact-prefix query: the 3-key matches in full.
        let hit = s.lookup(&[1, 2, 3]).expect("hit");
        assert_eq!(hit.matched, 3);
        assert_eq!(hit.snapshot.tokens(), &[1, 2, 3]);
        // No shared prefix of >= 2 positions: a miss.
        assert!(s.lookup(&[2, 2, 2]).is_none());
        assert!(s.lookup(&[1]).is_none());
        let st = s.stats();
        assert_eq!(st.hits, 2);
        assert_eq!(st.misses, 2);
    }

    /// Bytes-accurate budgeting: the store charges the positions a
    /// snapshot actually holds (the sliced tensors' position axis), so
    /// a short-prompt snapshot charges less than a long-prompt one even
    /// though both engines share one fixed cache capacity.
    #[test]
    fn budget_charges_actual_positions_held() {
        fn sized(tokens: &[i32], held: usize) -> CacheSnapshot {
            CacheSnapshot {
                tokens: tokens.to_vec(),
                stage_caches: vec![HostTensor::zeros(&[1, 2, held, 1, 1])],
                deficit: 0,
            }
        }
        let short = sized(&[1, 2, 3, 4], 3);
        let long = sized(&[9, 8, 7, 6, 5, 4, 3, 2, 1, 0], 9);
        assert_eq!(short.positions(), 3);
        assert_eq!(long.positions(), 9);
        assert!(short.positions() < long.positions());
        assert!(short.bytes() < long.bytes());
        let s = PrefixCacheStore::new(64);
        assert!(s.insert(short));
        assert_eq!(s.used_positions(), 3, "short prompt charged its slice");
        assert!(s.insert(long));
        assert_eq!(s.used_positions(), 12);
        // Tensor-less snapshots (unit-test fixtures) still weigh their
        // key length.
        assert!(s.insert(snap(&[40, 41])));
        assert_eq!(s.used_positions(), 14);
    }

    #[test]
    fn insert_rejects_over_budget_and_trivial_prefixes() {
        let s = PrefixCacheStore::new(4);
        assert!(!s.insert(snap(&[1])), "1-token prefix can never help");
        assert!(!s.insert(snap(&[1, 2, 3, 4, 5])), "over the whole budget");
        assert!(s.insert(snap(&[1, 2, 3])));
        assert_eq!(s.used_positions(), 3);
        assert_eq!(s.stats().rejected, 2);
    }

    #[test]
    fn duplicate_insert_touches_instead_of_storing() {
        let s = PrefixCacheStore::new(8);
        assert!(s.insert(snap(&[1, 2, 3])));
        assert!(!s.insert(snap(&[1, 2, 3])));
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats().insertions, 1);
    }

    #[test]
    fn eviction_is_lru_and_skips_pinned() {
        let s = PrefixCacheStore::new(6);
        assert!(s.insert(snap(&[1, 2])));
        assert!(s.insert(snap(&[3, 4])));
        assert!(s.insert(snap(&[5, 6])));
        // Touch [1,2] so [3,4] becomes the LRU victim.
        let pin = s.lookup(&[1, 2]).expect("hit");
        assert_eq!(s.evict_one().expect("victim"), vec![3, 4]);
        // Pin [5,6]; with [1,2] also pinned, nothing is evictable.
        let pin2 = s.lookup(&[5, 6]).expect("hit");
        assert!(s.evict_one().is_none());
        assert_eq!(s.pinned_entries(), 2);
        drop(pin);
        assert_eq!(s.evict_one().expect("victim"), vec![1, 2]);
        drop(pin2);
        assert_eq!(s.pinned_entries(), 0);
        let st = s.stats();
        assert_eq!(st.evictions, 2);
        assert_eq!(st.evicted_positions, 4);
    }

    #[test]
    fn insert_evicts_lru_to_fit_but_never_pinned() {
        let s = PrefixCacheStore::new(5);
        assert!(s.insert(snap(&[1, 2])));
        assert!(s.insert(snap(&[3, 4])));
        // Needs 3 positions: evicts [1,2] (LRU), then fits.
        assert!(s.insert(snap(&[5, 6, 7])));
        assert!(s.lookup(&[1, 2]).is_none());
        assert_eq!(s.used_positions(), 5);
        // Pin everything: a large insert cannot evict and is rejected.
        let _p1 = s.lookup(&[3, 4]).unwrap();
        let _p2 = s.lookup(&[5, 6, 7]).unwrap();
        assert!(!s.insert(snap(&[8, 9, 10, 11])));
        assert_eq!(s.used_positions(), 5);
    }

    /// Regression: an insert that cannot fit even after flushing every
    /// unpinned entry must be rejected up front, not after evicting the
    /// whole hot working set as collateral damage.
    #[test]
    fn infeasible_insert_evicts_nothing() {
        let s = PrefixCacheStore::new(8);
        assert!(s.insert(snap(&[1, 2])));
        assert!(s.insert(snap(&[3, 4])));
        let _pin = s.lookup(&[1, 2]).unwrap();
        // Needs 7; even evicting the unpinned [3,4] leaves only
        // 8 - 2 (pinned) = 6 positions. Must reject *and* keep [3,4].
        assert!(!s.would_admit(7));
        assert!(!s.insert(snap(&[5, 6, 7, 8, 9, 10, 11])));
        assert_eq!(s.len(), 2, "hot entries were collateral-evicted");
        assert_eq!(s.stats().evictions, 0);
        // A feasible insert still evicts just enough.
        assert!(s.would_admit(6));
        assert!(s.insert(snap(&[5, 6, 7, 8, 9, 10])));
        assert_eq!(s.stats().evictions, 1);
        assert_eq!(s.used_positions(), 8);
    }

    /// `remove` is the TTL-expiry drop: exact-key, pin-respecting, and
    /// invisible to the eviction counters.
    #[test]
    fn remove_drops_exact_unpinned_keys_without_eviction_stats() {
        let s = PrefixCacheStore::new(16);
        assert!(s.insert(snap(&[1, 2, 3])));
        assert!(s.insert(snap(&[1, 2, 3, 4])));
        // Pinned entries stay put.
        let pin = s.lookup(&[1, 2, 3]).expect("hit");
        assert!(!s.remove(&[1, 2, 3]));
        drop(pin);
        // Exact key only — a prefix of a resident key is not removable.
        assert!(!s.remove(&[1, 2]));
        assert!(s.remove(&[1, 2, 3]));
        assert!(!s.remove(&[1, 2, 3]), "already gone");
        assert_eq!(s.len(), 1);
        assert_eq!(s.used_positions(), 4);
        // The surviving sibling is still reachable through the trie.
        let hit = s.lookup(&[1, 2, 3, 4, 5]).expect("hit");
        assert_eq!(hit.snapshot.tokens(), &[1, 2, 3, 4]);
        assert_eq!(s.stats().evictions, 0, "removal is not eviction");
        assert_eq!(s.stats().evicted_positions, 0);
    }

    #[test]
    fn used_bytes_tracks_resident_tensors() {
        let sized = |tokens: &[i32], held: usize| CacheSnapshot {
            tokens: tokens.to_vec(),
            stage_caches: vec![HostTensor::zeros(&[1, 2, held, 1, 1])],
            deficit: 0,
        };
        let s = PrefixCacheStore::new(64);
        assert_eq!(s.used_bytes(), 0);
        let a = sized(&[1, 2, 3], 2);
        let b = sized(&[4, 5, 6, 7], 3);
        let (a_bytes, b_bytes) = (a.bytes(), b.bytes());
        assert!(s.insert(a));
        assert_eq!(s.used_bytes(), a_bytes);
        assert!(s.insert(b));
        assert_eq!(s.used_bytes(), a_bytes + b_bytes);
        assert!(s.remove(&[1, 2, 3]));
        assert_eq!(s.used_bytes(), b_bytes);
        s.evict_one();
        assert_eq!(s.used_bytes(), 0);
    }

    #[test]
    fn stats_since_reports_the_delta() {
        let s = PrefixCacheStore::new(8);
        assert!(s.insert(snap(&[1, 2])));
        let base = s.stats();
        assert!(s.lookup(&[1, 2, 3]).is_some());
        s.record_saved(5);
        let d = s.stats().since(&base);
        assert_eq!(d.hits, 1);
        assert_eq!(d.insertions, 0);
        assert_eq!(d.saved_positions, 5);
        let mut merged = base;
        merged.merge(&d);
        assert_eq!(merged, s.stats());
    }

    #[test]
    fn concurrent_hammering_preserves_invariants() {
        let s = std::sync::Arc::new(PrefixCacheStore::new(48));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = std::sync::Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xC0FFEE ^ t);
                let mut pins = Vec::new();
                for _ in 0..500 {
                    let key: Vec<i32> = (0..rng.range(2, 8))
                        .map(|_| rng.below(4) as i32)
                        .collect();
                    match rng.below(4) {
                        0 => {
                            s.insert(snap(&key));
                        }
                        1 => {
                            if let Some(h) = s.lookup(&key) {
                                pins.push(h.snapshot);
                            }
                        }
                        2 => {
                            if !pins.is_empty() {
                                pins.swap_remove(rng.below(pins.len()));
                            }
                        }
                        _ => {
                            s.evict_one();
                        }
                    }
                    assert!(s.used_positions() <= s.max_positions());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(s.used_positions() <= s.max_positions());
        assert_eq!(s.pinned_entries(), 0, "all pins were dropped");
        while s.evict_one().is_some() {}
        assert!(s.is_empty());
        assert_eq!(s.used_positions(), 0);
    }

    /// Mirror of the store used by the model-based property test below:
    /// same keys, same logical clock, same pin state.
    struct Model {
        /// key -> (positions, last_used).
        entries: std::collections::BTreeMap<Vec<i32>, (usize, u64)>,
        clock: u64,
    }

    impl Model {
        fn used(&self) -> usize {
            self.entries.values().map(|e| e.0).sum()
        }

        /// (best lcp, chosen key) under the store's selection rule:
        /// max lcp, then shortest key, then smallest token order.
        fn best(&self, query: &[i32]) -> Option<(usize, Vec<i32>)> {
            let lcp = |k: &[i32]| {
                k.iter().zip(query).take_while(|(a, b)| a == b).count()
            };
            let m = self.entries.keys().map(|k| lcp(k)).max()?;
            if m < MIN_PREFIX {
                return None;
            }
            let key = self
                .entries
                .keys()
                .filter(|k| lcp(k) == m)
                .min_by_key(|k| (k.len(), (*k).clone()))
                .unwrap()
                .clone();
            Some((m, key))
        }

        /// LRU victim among unpinned keys (clock readings are unique).
        fn victim(&self, pinned: &std::collections::BTreeSet<Vec<i32>>) -> Option<Vec<i32>> {
            self.entries
                .iter()
                .filter(|(k, _)| !pinned.contains(*k))
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| k.clone())
        }
    }

    /// The ISSUE's store properties, checked against the mirror model on
    /// random insert / lookup / release / evict sequences:
    /// the position budget is never exceeded, longest-prefix lookup is
    /// maximal, pinned entries are never evicted, and eviction order is
    /// LRU.
    #[test]
    fn store_matches_model_on_random_op_sequences() {
        proptest::check("prefix cache store model", 96, |rng| {
            let budget = rng.range(6, 32);
            let store = PrefixCacheStore::new(budget);
            let mut model = Model {
                entries: std::collections::BTreeMap::new(),
                clock: 0,
            };
            // Live pins: (key, guard). The model's pinned set derives
            // from it.
            let mut pins: Vec<(Vec<i32>, PinnedSnapshot)> = Vec::new();
            for _ in 0..rng.range(20, 80) {
                let key: Vec<i32> = (0..rng.range(1, 9))
                    .map(|_| rng.below(3) as i32)
                    .collect();
                let pinned: std::collections::BTreeSet<Vec<i32>> =
                    pins.iter().map(|(k, _)| k.clone()).collect();
                match rng.below(4) {
                    0 => {
                        // Insert: mirror the store's evict-to-fit loop.
                        let stored = store.insert(snap(&key));
                        let need = key.len();
                        if need < MIN_PREFIX || need > budget {
                            if stored {
                                return Err(format!(
                                    "stored unstorable key {key:?}"
                                ));
                            }
                        } else if model.entries.contains_key(&key) {
                            if stored {
                                return Err(format!(
                                    "re-stored duplicate {key:?}"
                                ));
                            }
                            model.clock += 1;
                            model.entries.get_mut(&key).unwrap().1 =
                                model.clock;
                        } else {
                            // Feasibility mirror: only unpinned
                            // positions are reclaimable, and an
                            // infeasible insert must evict nothing.
                            let pinned_used: usize = model
                                .entries
                                .iter()
                                .filter(|(k, _)| pinned.contains(*k))
                                .map(|(_, (n, _))| n)
                                .sum();
                            let fits = pinned_used + need <= budget;
                            if stored != fits {
                                return Err(format!(
                                    "insert {key:?}: store said {stored}, \
                                     model said {fits}"
                                ));
                            }
                            if fits {
                                while model.used() + need > budget {
                                    let v =
                                        model.victim(&pinned).expect("victim");
                                    model.entries.remove(&v);
                                }
                                model.clock += 1;
                                model
                                    .entries
                                    .insert(key.clone(), (need, model.clock));
                            }
                        }
                    }
                    1 => {
                        // Lookup: maximality + deterministic selection.
                        let got = store.lookup(&key);
                        match (got, model.best(&key)) {
                            (None, None) => {}
                            (Some(h), Some((m, k))) => {
                                if h.matched != m {
                                    return Err(format!(
                                        "lookup {key:?}: matched \
                                         {} != model lcp {m}",
                                        h.matched
                                    ));
                                }
                                if h.snapshot.tokens() != k.as_slice() {
                                    return Err(format!(
                                        "lookup {key:?}: chose {:?}, model \
                                         chose {k:?}",
                                        h.snapshot.tokens()
                                    ));
                                }
                                model.clock += 1;
                                model.entries.get_mut(&k).unwrap().1 =
                                    model.clock;
                                pins.push((k, h.snapshot));
                            }
                            (got, want) => {
                                return Err(format!(
                                    "lookup {key:?}: hit {} vs model {}",
                                    got.is_some(),
                                    want.is_some()
                                ));
                            }
                        }
                    }
                    2 => {
                        // Release a random pin.
                        if !pins.is_empty() {
                            pins.swap_remove(rng.below(pins.len()));
                        }
                    }
                    _ => {
                        // Explicit evict: must pick the model's LRU
                        // victim and never a pinned entry.
                        let got = store.evict_one();
                        let want = model.victim(&pinned);
                        if got != want {
                            return Err(format!(
                                "evict: store {got:?} vs model {want:?}"
                            ));
                        }
                        if let Some(v) = got {
                            if pinned.contains(&v) {
                                return Err(format!(
                                    "evicted pinned entry {v:?}"
                                ));
                            }
                            model.entries.remove(&v);
                        }
                    }
                }
                if store.used_positions() > budget {
                    return Err(format!(
                        "budget exceeded: {} > {budget}",
                        store.used_positions()
                    ));
                }
                if store.used_positions() != model.used() {
                    return Err(format!(
                        "usage drift: store {} vs model {}",
                        store.used_positions(),
                        model.used()
                    ));
                }
                if store.len() != model.entries.len() {
                    return Err(format!(
                        "entry-count drift: store {} vs model {}",
                        store.len(),
                        model.entries.len()
                    ));
                }
            }
            Ok(())
        });
    }
}
