//! Early-exit inference engines — the paper's Section 4 contribution (C3).
//!
//! Both engines are compatible with KV caching, resolving the conflict the
//! paper identifies (a token generated at an early exit leaves its deep-
//! layer KV entries missing):
//!
//! - [`sequential`] — single-threaded stage walk with **KV recomputation**
//!   (Appendix D.3 / Bae et al. variant): deficit tokens ride in the next
//!   decode window so their missing KV entries are recomputed; a full-model
//!   pass is forced when the deficit hits its cap. With threshold = 1.0
//!   this is the full-model baseline the paper's speedups are measured
//!   against.
//! - [`pipelined`] — the paper's novel **pipeline-based** method: one
//!   thread per stage; when an exit fires at stage s, the token is sent
//!   back to the first stage immediately and generation of the next token
//!   overlaps with the KV back-fill of the current token at stages >= s.
//!
//! Exit decisions are delegated to a pluggable [`ExitPolicy`] ([`policy`])
//! evaluated at stage-entry exits (Optimization-2 placement):
//! [`ExitPolicy::Confidence`] is the paper's rule (max softmax probability
//! >= threshold, with 1.0 the full-model baseline), and the same surface
//! carries per-layer, top-2-margin, entropy, never, and probe-calibrated
//! policies end-to-end — per request, through the serving pool, without
//! touching the engines.
//!
//! Both engines drive the same resumable decode core: a [`DecodeSession`]
//! ([`session`]) advances one token per `step()` over a [`DecodeBackend`]
//! (implemented by each engine), which is what lets the serving layer
//! interleave many requests over one engine (continuous batching) and
//! stream tokens as they are emitted. `generate_tokens` on either engine
//! is just a session drained to completion. Each engine also batches
//! many sessions its own way: the sequential engine fuses them into one
//! batched pass per stage ([`DecodeBackend::run_lanes`] over the
//! manifest's `decode_lanes` executables; [`DecodeSession::step_fused`]),
//! with per-lane exit decisions; the pipelined engine interleaves their
//! width-1 windows down its stage chain
//! ([`DecodeBackend::interleaves_windows`];
//! [`DecodeSession::step_interleaved`]), so one session's KV back-fill
//! fills another session's pipeline bubble. Both are the serving pool's
//! hot paths, and both are output-invisible.
//!
//! Fused lane groups on the sequential engine are **device-resident**
//! (`SequentialEngine::lane_residency`, on by default): a group's
//! lane-stacked per-stage KV caches are gathered once at formation, held
//! as device literals across rounds — a warm round is one XLA dispatch
//! per stage plus one lane-batched exit-head dispatch per exit (the
//! manifest's `s{s}_head{L}_b{B}` executables), with zero host cache
//! traffic — and scattered back to per-session handles only when a lane
//! departs (exit/deficit/close), the group is re-planned, or a snapshot
//! needs host bytes. Member handles go stale while resident and lazily
//! re-sync on their next engine touch (see [`SessionCaches::generation`]).
//! Gather/scatter/warm-hit traffic is surfaced via
//! [`DecodeBackend::lane_traffic`] ([`session::LaneTraffic`]).
//!
//! [`prefix_cache`] adds shared-prefix KV reuse on top of the sessions:
//! a token-trie keyed store of immutable cache snapshots (refcounted,
//! LRU-evicted under a position budget), taken post-prefill
//! ([`DecodeSession::prefix_snapshot`]) or at end-of-turn
//! ([`DecodeSession::finish_snapshot`] — conversational reuse), so
//! sessions whose prompts share a prefix restore it and prefill only the
//! suffix. Both engines participate
//! ([`DecodeBackend::supports_cache_snapshots`]): sequential sessions own
//! their caches outright, and the pipelined engine drains per-stage
//! session slots over its chain's snapshot protocol. [`tiered_store`]
//! layers a small pinned device-resident tier on top
//! ([`TieredStore`]), so hot system prompts and active conversations
//! never leave the device.
//!
//! [`probe`] reproduces Table 4: per-exit predictions + confidences for
//! every generated token.

pub mod common;
pub mod pipelined;
pub mod policy;
pub mod prefix_cache;
pub mod probe;
pub mod sequential;
pub mod session;
pub mod tiered_store;

pub use common::{ExitStats, GenOutput, ModelState};
pub use pipelined::PipelinedEngine;
pub use policy::{summarize_logits, ExitDecision, ExitPolicy, LogitsSummary};
pub use prefix_cache::{
    CacheSnapshot, PinnedSnapshot, PrefixCacheStats, PrefixCacheStore,
    PrefixHit, SnapshotSource,
};
pub use sequential::SequentialEngine;
pub use session::{
    CachedPrefill, DecodeBackend, DecodeSession, DoneReason, FusedStep,
    LaneSlot, LaneTraffic, ParkedSession, SessionCaches, StepEvent,
    WindowOutcome,
};
pub use tiered_store::{TierStats, TieredStore};
