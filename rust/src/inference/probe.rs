//! Exit-confidence probing — the paper's Table 4.
//!
//! Runs generation with full-model passes while recording every exit's
//! prediction and confidence for each token, so one can see which tokens
//! are "easy" (all exits agree with high confidence) and which require the
//! full model.
//!
//! Probe data is also the input to exit-policy calibration
//! ([`ExitPolicy::calibrated`](super::policy::ExitPolicy::calibrated)):
//! run `ee-llm probe --calibrate TARGET` to fit per-layer confidence
//! thresholds whose accepted tokens agree with the final exit at the
//! target rate, emitted as a ready-to-use `--policy per-layer:...` spec.

use anyhow::Result;

use crate::data::tokenizer::ByteTokenizer;
use crate::util::table::Table;

use super::common::ModelState;
use super::policy::ExitPolicy;
use super::sequential::{SequentialEngine, TokenProbe};

pub struct ProbeReport {
    pub probes: Vec<TokenProbe>,
    pub generated: String,
    /// Exit layers, shallow to deep (final last).
    pub exit_layers: Vec<usize>,
}

/// Generate with the full model while probing every exit per token.
pub fn probe_generation(
    state: ModelState,
    prompt: &str,
    max_new: usize,
) -> Result<ProbeReport> {
    let mut exit_layers: Vec<usize> = state
        .man
        .exit_order()
        .iter()
        .map(|&(_, l, _)| l)
        .filter(|&l| l > 0)
        .collect();
    exit_layers.sort();
    // `Never`: no early exits, so every exit is probed for every token
    // (the Table 4 setting, previously spelled threshold 1.0).
    let mut eng = SequentialEngine::new(state, ExitPolicy::Never)?;
    eng.probe = true;
    let out = eng.generate_text(prompt, max_new)?;
    Ok(ProbeReport {
        probes: eng.probes.clone(),
        generated: out.text,
        exit_layers,
    })
}

impl ProbeReport {
    /// Render as the paper's Table 4: one row per token, one column pair
    /// per exit.
    pub fn to_table(&self) -> Table {
        let tok = ByteTokenizer;
        let mut headers: Vec<String> = vec!["token".into()];
        for l in &self.exit_layers {
            headers.push(format!("layer {l}"));
            headers.push(format!("conf@{l}"));
        }
        let mut t = Table::new(
            "Table 4 analogue: per-exit prediction and confidence",
            &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for p in &self.probes {
            let mut row = Vec::with_capacity(headers.len());
            // The emitted token is the final exit's prediction.
            let emitted = p.exits.last().map(|e| e.1).unwrap_or(-1);
            row.push(printable(&tok, emitted));
            for l in &self.exit_layers {
                match p.exits.iter().find(|e| e.0 == *l) {
                    Some(&(_, tk, conf)) => {
                        row.push(printable(&tok, tk));
                        row.push(format!("{conf:.3}"));
                    }
                    None => {
                        row.push("-".into());
                        row.push("-".into());
                    }
                }
            }
            t.row(row);
        }
        t
    }

    /// Fraction of tokens where all exits agree on the prediction and the
    /// shallowest exit is confident above `tau` — the paper's observation
    /// that high-confidence tokens agree across exits.
    pub fn agreement_at(&self, tau: f32) -> f64 {
        let mut confident = 0usize;
        let mut agree = 0usize;
        for p in &self.probes {
            if let Some(first) = p.exits.first() {
                if first.2 >= tau {
                    confident += 1;
                    if p.exits.iter().all(|e| e.1 == first.1) {
                        agree += 1;
                    }
                }
            }
        }
        if confident == 0 {
            1.0
        } else {
            agree as f64 / confident as f64
        }
    }
}

fn printable(tok: &ByteTokenizer, id: i32) -> String {
    if id < 0 {
        return "?".into();
    }
    let s = tok.decode(&[id]);
    if s.is_empty() {
        format!("<{id}>")
    } else if s.chars().all(|c| c.is_ascii_graphic()) {
        s
    } else {
        format!("{:?}", s)
    }
}
