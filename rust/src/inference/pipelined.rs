//! Pipeline-based early-exit inference — the paper's novel Section 4
//! method, as a real thread-per-stage pipeline multiplexing many decode
//! sessions down one stage chain.
//!
//! When stage s's entry exit fires for the current token, two things happen
//! *in parallel* (Figure 5):
//!
//!  1. the token is reported to the leader, which immediately starts the
//!     next token's forward pass at stage 0;
//!  2. the current token's forward pass **continues** through stages
//!     s..P-1 (flagged `exited`), filling its KV caches in all deeper
//!     layers — so no KV entry is ever missing and no recomputation is
//!     needed.
//!
//! Each stage processes its FIFO inbox in arrival order, which serialises
//! the KV back-fill of token t before the forward of token t+1 on the same
//! stage — exactly the constraint the paper's latency analysis assumes.
//! The generation latency of a token emitted at stage s is therefore the
//! forward time of stages 0..s (plus queueing), not of the full model.
//!
//! **Session multiplexing.** Every [`Work::Window`] carries a session id
//! and every stage keeps a per-session KV-cache slot map, so the leader
//! interleaves windows from many live [`DecodeSession`]s down the one
//! chain: while session A's token back-fills the deep stages, session B's
//! next token occupies the shallow ones — one session's KV back-fill
//! fills another session's pipeline bubble, the serving-side analogue of
//! the paper's training-time bubble filling. Sessions open with
//! [`Work::Open`] (a fresh zeroed slot, or one restored from a prefix
//! snapshot), close with [`Work::Close`] (acked by the last stage), and
//! snapshot with [`Work::Snapshot`]. The snapshot message's FIFO
//! traversal *is* the quiesce/drain protocol: by the time a stage
//! processes it, every earlier window of that session has been applied,
//! so the per-stage cache reads are consistent without stopping the rest
//! of the chain. Each slot also carries the [`ExitPolicy`] captured when
//! the session opened, so interleaved sessions may decode under
//! different policies without any engine-resident swap.
//!
//! A stage that fails (error or panic) reports [`ToLeader::StageError`]
//! and forwards `Shutdown` down-chain before exiting, so the leader gets
//! an error instead of blocking forever on an ack from a dead stage
//! while shallower stages keep its channel open.

use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::eval::harness::Generator;
use crate::runtime::client::StageRuntime;
use crate::runtime::tensor::{HostTensor, IntTensor};

use super::common::{
    pad_cache_to_capacity, slice_cache_positions, GenOutput, ModelState,
};
use super::policy::{summarize_logits, ExitPolicy};
use super::session::{
    DecodeBackend, DecodeSession, SessionCaches, WindowOutcome,
};

/// Work flowing down the stage chain. Every variant that touches decode
/// state names its session; stage FIFO order guarantees an `Open`
/// precedes its session's windows and a `Snapshot` follows them.
enum Work {
    /// Start a session: each stage installs a KV-cache slot for it —
    /// zeroed, or rebuilt from `restore[s]` (a full-capacity per-stage
    /// snapshot) — and captures `policy` for the session's exit
    /// decisions. Fire-and-forget: FIFO ordering makes an ack redundant.
    Open {
        session: u64,
        policy: ExitPolicy,
        restore: Option<Arc<Vec<HostTensor>>>,
    },
    /// Decode a window of tokens at [pos0, pos0+width) for `session`.
    /// `payload` is tokens for stage 0, hidden states beyond.
    Window {
        session: u64,
        width: usize,
        pos0: usize,
        tokens: Vec<i32>,
        hidden: Option<HostTensor>,
        /// Token already emitted at an earlier stage (KV back-fill only) —
        /// or prefill, where no token is wanted either.
        exited: bool,
        /// Exit checks enabled (generation steps, not prefill).
        check_exits: bool,
    },
    /// End a session: each stage drops its slot; the last stage acks the
    /// leader with [`ToLeader::Closed`].
    Close { session: u64 },
    /// Read a session's per-stage KV caches, sliced to the first
    /// `positions` entries: each stage sends a [`ToLeader::SnapshotPart`]
    /// and forwards. FIFO order quiesces the session — every earlier
    /// window has been applied by the time a stage reads its slot.
    Snapshot { session: u64, positions: usize },
    Shutdown,
    /// Fault injection: the named stage fails on receipt, everyone else
    /// forwards — the mid-chain-failure regression hook, also used by
    /// the serving pool's chaos harness
    /// ([`PipelinedEngine::inject_stage_failure`]).
    Fail { stage: usize },
}

enum ToLeader {
    Token { session: u64, token: i32, exit_layer: usize },
    /// Last-stage ack for [`Work::Close`]: every stage has dropped the
    /// session's slot and no more of its messages are in flight.
    Closed { session: u64 },
    /// One stage's cache slice for a [`Work::Snapshot`] read.
    SnapshotPart { session: u64, stage: usize, cache: HostTensor },
    /// A stage died (error or panic). Sent before the stage exits so the
    /// leader fails fast instead of deadlocking on an ack that can never
    /// arrive.
    StageError { stage: usize, error: String },
}

/// A chain message the leader can act on. [`PipelinedEngine::recv_ok`]
/// has already converted stage failures, hung-stage watchdog timeouts,
/// and chain disconnects into typed errors, so match sites handle only
/// the healthy protocol — there is no error variant to forget.
enum ChainMsg {
    Token { session: u64, token: i32, exit_layer: usize },
    Closed { session: u64 },
    SnapshotPart { session: u64, stage: usize, cache: HostTensor },
}

struct StageThread {
    join: Option<std::thread::JoinHandle<Result<()>>>,
}

/// One session's decode state on one stage: its KV-cache slice plus the
/// exit policy captured when the session opened.
struct SessionSlot {
    cache: xla::Literal,
    policy: ExitPolicy,
}

pub struct PipelinedEngine {
    pub state: ModelState,
    /// Exit-decision policy captured by sessions as they open
    /// ([`PipelinedEngine::set_policy`]); live sessions keep the policy
    /// they opened under, so a swap never leaks into an in-flight
    /// request.
    pub policy: ExitPolicy,
    to_first: Sender<Work>,
    from_last: Receiver<ToLeader>,
    threads: Vec<StageThread>,
    /// Monotonic session-id source; ids are never reused, so a stale
    /// message can never be routed to a newer session.
    next_session: u64,
    /// Tokens that arrived while the leader was collecting for another
    /// session (interleaved serving), parked until their own collect.
    pending: HashMap<u64, WindowOutcome>,
    /// First stage failure observed; once set, every chain operation
    /// fails fast instead of feeding a dead pipeline.
    chain_error: Option<String>,
    /// Window deadline for leader-side chain waits
    /// ([`PipelinedEngine::set_watchdog`]): a stage that produces no
    /// message within this budget is declared hung and the chain
    /// poisoned with a typed failure, instead of the leader stalling
    /// indefinitely.
    watchdog: Duration,
}

struct StageWorker {
    s: usize,
    p: usize,
    man: crate::runtime::artifacts::Manifest,
    rt: StageRuntime,
    plits: Vec<xla::Literal>,
    /// Per-session KV-cache slots, keyed by session id.
    slots: HashMap<u64, SessionSlot>,
    inbox: Receiver<Work>,
    next: Option<Sender<Work>>,
    leader: Sender<ToLeader>,
    entry_exit_layers: Vec<usize>,
    final_layer: usize,
}

impl StageWorker {
    fn head_logits(&self, layer: usize, x: &[f32]) -> Result<Vec<f32>> {
        let st = &self.man.stages[self.s];
        let e = st
            .exits
            .iter()
            .find(|e| e.layer == layer)
            .context("exit not on stage")?;
        let xlit = HostTensor::new(vec![x.len()], x.to_vec()).to_literal()?;
        let mut args: Vec<&xla::Literal> = e
            .head_param_idx
            .iter()
            .map(|&i| &self.plits[i])
            .collect();
        args.push(&xlit);
        let out = self.rt.get(&format!("head{layer}"))?.run(&args)?;
        Ok(HostTensor::from_literal(&out[0])?.data)
    }

    fn run(&mut self) -> Result<()> {
        let h = self.man.model.hidden;
        loop {
            match self.inbox.recv() {
                Err(_) => return Ok(()),
                Ok(Work::Shutdown) => {
                    // Propagate down the chain explicitly: deeper stages
                    // must not depend on the channel-close cascade, which
                    // never happens if a `Sender` clone outlives the
                    // engine (the serving pool clones senders).
                    if let Some(n) = &self.next {
                        let _ = n.send(Work::Shutdown);
                    }
                    return Ok(());
                }
                Ok(Work::Fail { stage }) => {
                    if stage == self.s {
                        bail!("injected stage failure");
                    }
                    if let Some(n) = &self.next {
                        n.send(Work::Fail { stage })
                            .ok()
                            .context("next stage gone")?;
                    }
                }
                Ok(Work::Open { session, policy, restore }) => {
                    let cache = match &restore {
                        Some(parts) => parts[self.s].to_literal()?,
                        None => HostTensor::zeros(
                            &self.man.stages[self.s].cache_shape,
                        )
                        .to_literal()?,
                    };
                    self.slots.insert(
                        session,
                        SessionSlot { cache, policy: policy.clone() },
                    );
                    if let Some(n) = &self.next {
                        n.send(Work::Open { session, policy, restore })
                            .ok()
                            .context("next stage gone")?;
                    }
                }
                Ok(Work::Close { session }) => {
                    self.slots.remove(&session);
                    match &self.next {
                        Some(n) => n
                            .send(Work::Close { session })
                            .ok()
                            .context("next stage gone")?,
                        None => {
                            self.leader
                                .send(ToLeader::Closed { session })
                                .ok();
                        }
                    }
                }
                Ok(Work::Snapshot { session, positions }) => {
                    // FIFO has already applied every earlier window of
                    // this session: the slot is quiescent.
                    let slot =
                        self.slots.get(&session).with_context(|| {
                            format!(
                                "snapshot for unknown session {session} \
                                 at stage {}",
                                self.s
                            )
                        })?;
                    let full = HostTensor::from_literal(&slot.cache)?;
                    let part = slice_cache_positions(
                        &full,
                        &self.man.stages[self.s].cache_shape,
                        positions,
                    )?;
                    self.leader
                        .send(ToLeader::SnapshotPart {
                            session,
                            stage: self.s,
                            cache: part,
                        })
                        .ok();
                    if let Some(n) = &self.next {
                        n.send(Work::Snapshot { session, positions })
                            .ok()
                            .context("next stage gone")?;
                    }
                }
                Ok(Work::Window {
                    session,
                    width,
                    pos0,
                    tokens,
                    hidden,
                    mut exited,
                    check_exits,
                }) => {
                    ensure!(
                        self.slots.contains_key(&session),
                        "window for unknown session {session} at stage {}",
                        self.s
                    );
                    // Entry-exit decision on the last window position,
                    // under the session's own policy (captured at open).
                    // Policies that can never exit (`Never`, confidence
                    // 1.0 — the full-model baseline) skip the exit heads
                    // entirely; the decision could only be Continue.
                    if self.s > 0 && !exited && check_exits {
                        let policy = self.slots[&session].policy.clone();
                        if policy.may_exit() {
                            let xh = hidden.as_ref().unwrap();
                            let last = &xh.data[(width - 1) * h..];
                            for &layer in &self.entry_exit_layers.clone() {
                                // Skip heads the policy can never fire at
                                // (unlisted / 1.0 per-layer thresholds).
                                if !policy.may_exit_at(layer) {
                                    continue;
                                }
                                let logits = self.head_logits(layer, last)?;
                                let sum = summarize_logits(&logits);
                                if policy.decide(layer, &sum).is_exit() {
                                    self.leader
                                        .send(ToLeader::Token {
                                            session,
                                            token: sum.token,
                                            exit_layer: layer,
                                        })
                                        .ok();
                                    exited = true;
                                    break;
                                }
                            }
                        }
                    }

                    // Stage decode (KV fill) against the session's slot,
                    // always.
                    let in_lit: xla::Literal = if self.s == 0 {
                        IntTensor::new(vec![width], tokens.clone())
                            .to_literal()?
                    } else {
                        hidden.as_ref().unwrap().to_literal()?
                    };
                    // Perf pass §L3-2: cache stays an xla::Literal.
                    let pos_lit = IntTensor::scalar(pos0 as i32).to_literal()?;
                    let mut args: Vec<&xla::Literal> =
                        self.plits.iter().collect();
                    args.push(&in_lit);
                    args.push(&self.slots[&session].cache);
                    args.push(&pos_lit);
                    let out = self
                        .rt
                        .get(&format!("decode_w{width}"))?
                        .run(&args)?;
                    let mut it = out.into_iter();
                    let x_out = HostTensor::from_literal(&it.next().unwrap())?;
                    let new_cache = it.next().unwrap();
                    self.slots.get_mut(&session).unwrap().cache = new_cache;

                    if self.s + 1 < self.p {
                        self.next
                            .as_ref()
                            .unwrap()
                            .send(Work::Window {
                                session,
                                width,
                                pos0,
                                tokens,
                                hidden: Some(x_out),
                                exited,
                                check_exits,
                            })
                            .ok()
                            .context("next stage gone")?;
                    } else if !exited && check_exits {
                        let last = &x_out.data[(width - 1) * h..];
                        let logits =
                            self.head_logits(self.final_layer, last)?;
                        let sum = summarize_logits(&logits);
                        self.leader
                            .send(ToLeader::Token {
                                session,
                                token: sum.token,
                                exit_layer: self.final_layer,
                            })
                            .ok();
                    }
                }
            }
        }
    }
}

impl PipelinedEngine {
    pub fn new(
        state: ModelState,
        policy: ExitPolicy,
    ) -> Result<PipelinedEngine> {
        let p = state.man.stages.len();
        let (leader_tx, from_last) = channel::<ToLeader>();

        // Build the chain back to front.
        let mut next_tx: Option<Sender<Work>> = None;
        let mut first_tx: Option<Sender<Work>> = None;
        let mut threads = Vec::new();
        for s in (0..p).rev() {
            let (tx, rx) = channel::<Work>();
            let man = state.man.clone();
            let params = state.stage_params[s].clone();
            let next = next_tx.take();
            let leader = leader_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("infer-{s}"))
                .spawn(move || -> Result<()> {
                    let leader_err = leader.clone();
                    let next_err = next.clone();
                    let serve = move || -> Result<()> {
                        let mut rt = StageRuntime::cpu()?;
                        rt.load_stage_inference(&man, &man.stages[s])?;
                        let plits = params
                            .iter()
                            .map(|t| t.to_literal())
                            .collect::<Result<Vec<_>>>()?;
                        let entry_exit_layers: Vec<usize> = man.stages[s]
                            .exits
                            .iter()
                            .filter(|e| {
                                !e.is_final && e.entry && e.layer > 0
                            })
                            .map(|e| e.layer)
                            .collect();
                        let final_layer = man.model.n_layers;
                        let mut w = StageWorker {
                            s,
                            p,
                            man,
                            rt,
                            plits,
                            slots: HashMap::new(),
                            inbox: rx,
                            next,
                            leader,
                            entry_exit_layers,
                            final_layer,
                        };
                        w.run()
                    };
                    let result =
                        match std::panic::catch_unwind(AssertUnwindSafe(
                            serve,
                        )) {
                            Ok(r) => r,
                            Err(_) => Err(anyhow!("stage thread panicked")),
                        };
                    if let Err(e) = &result {
                        // Report before exiting: the leader may be
                        // blocked on an ack only this stage or its
                        // descendants could send, and the shallower
                        // stages keep its channel open — without this
                        // message it would wait forever (the mid-chain
                        // deadlock this fixes). Deeper stages exit via
                        // the forwarded `Shutdown`.
                        if let Some(n) = &next_err {
                            n.send(Work::Shutdown).ok();
                        }
                        leader_err
                            .send(ToLeader::StageError {
                                stage: s,
                                error: format!("{e:#}"),
                            })
                            .ok();
                    }
                    result
                })
                .expect("spawn inference stage");
            threads.push(StageThread { join: Some(join) });
            next_tx = Some(tx.clone());
            first_tx = Some(tx);
        }

        Ok(PipelinedEngine {
            state,
            policy,
            to_first: first_tx.unwrap(),
            from_last,
            threads,
            next_session: 0,
            pending: HashMap::new(),
            chain_error: None,
            watchdog: PipelinedEngine::DEFAULT_WATCHDOG,
        })
    }

    /// Default leader-side window deadline: generous enough for cold
    /// XLA compilation on the first window, far below "stalled forever".
    pub const DEFAULT_WATCHDOG: Duration = Duration::from_secs(60);

    /// Set the leader's per-message window deadline. Waits on the chain
    /// (token collects, close acks, snapshot parts) that exceed it
    /// poison the engine with a typed hung-stage failure — the serving
    /// supervisor then rebuilds the engine instead of hanging a worker.
    pub fn set_watchdog(&mut self, deadline: Duration) {
        self.watchdog = deadline;
    }

    /// The current leader-side window deadline.
    pub fn watchdog(&self) -> Duration {
        self.watchdog
    }

    /// Whether a stage failure (or watchdog timeout) has poisoned the
    /// chain: every further chain operation fails fast. A poisoned
    /// engine cannot heal itself — the serving supervisor tears it down
    /// and rebuilds ([`crate::serve::EnginePool`]'s recovery path).
    pub fn chain_down(&self) -> bool {
        self.chain_error.is_some()
    }

    /// Kill stage `stage` on its next message receipt (chaos testing —
    /// [`Work::Fail`]). The failure surfaces on the next chain wait as
    /// a typed stage error, exactly like an organic stage death.
    pub fn inject_stage_failure(&mut self, stage: usize) -> Result<()> {
        self.check_chain()?;
        let p = self.state.man.stages.len();
        ensure!(stage < p, "stage {stage} out of range (chain has {p})");
        self.to_first
            .send(Work::Fail { stage })
            .ok()
            .context("stage chain gone")
    }

    /// Swap the exit policy for sessions opened from now on. Live
    /// sessions keep the policy captured when they opened — each stage
    /// slot carries its own copy — so a swap never leaks into an
    /// in-flight request (what lets the pool interleave mixed-policy
    /// sessions down one chain).
    pub fn set_policy(&mut self, policy: ExitPolicy) {
        self.policy = policy;
    }

    /// Fail fast once a stage has died.
    fn check_chain(&self) -> Result<()> {
        if let Some(e) = &self.chain_error {
            bail!("pipelined stage chain is down: {e}");
        }
        Ok(())
    }

    /// Poison the chain and fail with a typed chain-down error.
    fn poison(&mut self, msg: String) -> anyhow::Error {
        self.chain_error = Some(msg.clone());
        anyhow!("pipelined stage chain is down: {msg}")
    }

    /// Receive one chain message, converting a stage failure, a chain
    /// disconnect, or a hung stage (no message within the watchdog
    /// deadline) into a typed error — and poisoning the engine —
    /// instead of blocking forever on an ack that can never arrive.
    /// Callers therefore only ever see healthy-protocol [`ChainMsg`]s.
    fn recv_ok(&mut self) -> Result<ChainMsg> {
        self.check_chain()?;
        match self.from_last.recv_timeout(self.watchdog) {
            Ok(ToLeader::Token { session, token, exit_layer }) => {
                Ok(ChainMsg::Token { session, token, exit_layer })
            }
            Ok(ToLeader::Closed { session }) => {
                Ok(ChainMsg::Closed { session })
            }
            Ok(ToLeader::SnapshotPart { session, stage, cache }) => {
                Ok(ChainMsg::SnapshotPart { session, stage, cache })
            }
            Ok(ToLeader::StageError { stage, error }) => {
                Err(self.poison(format!("stage {stage} failed: {error}")))
            }
            Err(RecvTimeoutError::Timeout) => {
                let deadline = self.watchdog;
                Err(self.poison(format!(
                    "watchdog: no chain message within {deadline:?} \
                     (hung stage)"
                )))
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(self.poison("every stage thread exited".to_string()))
            }
        }
    }

    /// Allocate a session id and open its per-stage slots (zeroed, or
    /// restored from full-capacity per-stage snapshots).
    fn open_session(
        &mut self,
        restore: Option<Arc<Vec<HostTensor>>>,
    ) -> Result<u64> {
        self.check_chain()?;
        self.next_session += 1;
        let id = self.next_session;
        self.to_first
            .send(Work::Open {
                session: id,
                policy: self.policy.clone(),
                restore,
            })
            .ok()
            .context("stage chain gone")?;
        Ok(id)
    }

    /// Send one window down the chain (fire-and-forget; the matching
    /// token, if any, is picked up by [`PipelinedEngine::collect`]).
    fn submit(
        &mut self,
        session: u64,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        emit: bool,
    ) -> Result<()> {
        self.check_chain()?;
        self.to_first
            .send(Work::Window {
                session,
                width,
                pos0,
                tokens: tokens[pos0..pos0 + width].to_vec(),
                hidden: None,
                exited: !emit, // prefill wants no emission
                check_exits: emit,
            })
            .ok()
            .context("stage chain gone")
    }

    /// Await the emitted token of `session`'s outstanding window,
    /// parking tokens of other interleaved sessions as they arrive.
    fn collect(&mut self, session: u64) -> Result<WindowOutcome> {
        if let Some(out) = self.pending.remove(&session) {
            return Ok(out);
        }
        let p = self.state.man.stages.len();
        loop {
            match self.recv_ok()? {
                ChainMsg::Token { session: s, token, exit_layer } => {
                    // KV back-fill always completes through every stage,
                    // so no session ever accrues a deficit.
                    let out =
                        WindowOutcome { token, exit_layer, stages_run: p };
                    if s == session {
                        return Ok(out);
                    }
                    self.pending.insert(s, out);
                }
                ChainMsg::Closed { session: s } => {
                    bail!(
                        "unexpected close ack for session {s} while \
                         awaiting a token for session {session}"
                    );
                }
                ChainMsg::SnapshotPart { session: s, stage, .. } => {
                    bail!(
                        "unexpected snapshot part (session {s}, stage \
                         {stage}) while awaiting a token for session \
                         {session}"
                    );
                }
            }
        }
    }

    /// Generate up to `max_new` tokens — a [`DecodeSession`] drained to
    /// completion over the stage chain.
    pub fn generate_tokens(
        &mut self,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<GenOutput> {
        let mut session = DecodeSession::new(self, prompt, max_new)?;
        session.drain(self)
    }

    pub fn generate_text(
        &mut self,
        prompt: &str,
        max_new: usize,
    ) -> Result<GenOutput> {
        let ids = crate::data::tokenizer::ByteTokenizer.encode(prompt);
        self.generate_tokens(&ids, max_new)
    }

    pub fn shutdown(mut self) {
        // Stage 0 forwards `Shutdown` down the chain, so every stage exits
        // on the explicit message even if a `Sender` clone keeps some
        // stage's inbox open (channel-close is only the fallback).
        let _ = self.to_first.send(Work::Shutdown);
        for t in &mut self.threads {
            if let Some(j) = t.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl DecodeBackend for PipelinedEngine {
    /// Open a new session on the chain: every stage installs a zeroed
    /// KV-cache slot keyed by a fresh session id (returned in
    /// [`SessionCaches::generation`]), capturing the current
    /// [`PipelinedEngine::set_policy`] policy. Arbitrarily many sessions
    /// may be live at once; their windows interleave down the chain.
    fn fresh_caches(&mut self) -> Result<SessionCaches> {
        {
            let widths = &self.state.man.decode_widths;
            // Generation steps decode one position at a time.
            if !widths.contains(&1) {
                bail!(
                    "pipelined engine decodes with width-1 windows, but \
                     the manifest only lists decode widths {widths:?}"
                );
            }
        }
        let id = self.open_session(None)?;
        Ok(SessionCaches { caches: Vec::new(), generation: id })
    }

    /// Prefill windows (`emit` false) are fire-and-forget KV fills; the
    /// stage FIFOs serialise them before the first generation step.
    /// Generation windows await the emitted token from the chain. Exit
    /// checks ride on `emit` exactly as the monolithic loop did: the
    /// back-fill design never suspends exits, so `allow_exit` (a
    /// recompute-deficit concern) is ignored.
    fn run_window(
        &mut self,
        caches: &mut SessionCaches,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        _allow_exit: bool,
        emit: bool,
    ) -> Result<WindowOutcome> {
        self.submit(caches.generation, tokens, pos0, width, emit)?;
        if !emit {
            let p = self.state.man.stages.len();
            return Ok(WindowOutcome { token: -1, exit_layer: 0, stages_run: p });
        }
        self.collect(caches.generation)
    }

    /// The split-phase emitting window pass interleaved serving is built
    /// on: submit now, collect later, other sessions' windows in between
    /// ([`DecodeSession::step_interleaved`]).
    fn submit_window(
        &mut self,
        caches: &mut SessionCaches,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        _allow_exit: bool,
    ) -> Result<()> {
        self.submit(caches.generation, tokens, pos0, width, true)
    }

    fn collect_window(
        &mut self,
        caches: &mut SessionCaches,
    ) -> Result<WindowOutcome> {
        self.collect(caches.generation)
    }

    fn interleaves_windows(&self) -> bool {
        true
    }

    fn decode_widths(&self) -> &[usize] {
        &self.state.man.decode_widths
    }

    fn max_seq(&self) -> usize {
        self.state.man.model.max_seq
    }

    fn n_stages(&self) -> usize {
        self.state.man.stages.len()
    }

    fn exit_policy(&self) -> &ExitPolicy {
        &self.policy
    }

    fn tracks_deficit(&self) -> bool {
        false
    }

    /// Per-session stage slots make live sessions independent; the
    /// serving pool's `max_concurrent` is the only admission bound.
    fn max_live_sessions(&self) -> usize {
        usize::MAX
    }

    /// Sessions' KV state lives sharded across the stage threads, but
    /// the `Snapshot`/`SnapshotPart` drain protocol reads it out
    /// consistently (and `Open` rebuilds it), so the prefix KV cache
    /// works on this engine exactly as on the sequential one.
    fn supports_cache_snapshots(&self) -> bool {
        true
    }

    /// Quiesce-and-read: a [`Work::Snapshot`] flows down the chain
    /// behind the session's windows (the FIFO is the drain), each stage
    /// answers with its position-sliced cache, and the leader reassembles
    /// the per-stage snapshot in stage order.
    fn snapshot_caches(
        &mut self,
        caches: &SessionCaches,
        positions: usize,
    ) -> Result<Vec<HostTensor>> {
        let session = caches.generation;
        self.check_chain()?;
        self.to_first
            .send(Work::Snapshot { session, positions })
            .ok()
            .context("stage chain gone")?;
        let p = self.state.man.stages.len();
        let mut parts: Vec<Option<HostTensor>> = (0..p).map(|_| None).collect();
        let mut got = 0usize;
        while got < p {
            match self.recv_ok()? {
                ChainMsg::SnapshotPart { session: s, stage, cache } => {
                    ensure!(
                        s == session,
                        "snapshot part for session {s} while snapshotting \
                         session {session}"
                    );
                    ensure!(
                        stage < p && parts[stage].is_none(),
                        "duplicate or out-of-range snapshot part for \
                         stage {stage}"
                    );
                    parts[stage] = Some(cache);
                    got += 1;
                }
                // Tokens of other interleaved sessions may be in flight;
                // park them for their own collect calls.
                ChainMsg::Token { session: s, token, exit_layer } => {
                    self.pending.insert(
                        s,
                        WindowOutcome { token, exit_layer, stages_run: p },
                    );
                }
                ChainMsg::Closed { session: s } => {
                    bail!(
                        "unexpected close ack for session {s} while \
                         snapshotting session {session}"
                    );
                }
            }
        }
        Ok(parts
            .into_iter()
            .map(|o| o.expect("collected every stage part"))
            .collect())
    }

    /// Open a session whose per-stage slots start from a snapshot taken
    /// by [`DecodeBackend::snapshot_caches`] on a same-shaped engine
    /// (either engine: the host snapshot format is shared). Validation
    /// and zero-padding happen leader-side, so a malformed snapshot is
    /// rejected here — where the prefix cache treats restores as
    /// best-effort — instead of killing a stage thread.
    fn restore_caches(
        &mut self,
        snapshot: &[HostTensor],
    ) -> Result<SessionCaches> {
        let parts = {
            let stages = &self.state.man.stages;
            ensure!(
                snapshot.len() == stages.len(),
                "snapshot has {} stage caches, engine has {} stages",
                snapshot.len(),
                stages.len()
            );
            snapshot
                .iter()
                .zip(stages)
                .map(|(t, st)| {
                    pad_cache_to_capacity(t, &st.cache_shape)
                        .with_context(|| format!("stage {}", st.index))
                })
                .collect::<Result<Vec<_>>>()
                .context("restoring per-stage KV caches")?
        };
        let id = self.open_session(Some(Arc::new(parts)))?;
        Ok(SessionCaches { caches: Vec::new(), generation: id })
    }

    /// Close the session on every stage and wait for the last stage's
    /// ack, so its slots are gone (and none of its messages are in
    /// flight) before the caches handle is dropped.
    fn release_caches(&mut self, caches: &SessionCaches) -> Result<()> {
        let session = caches.generation;
        self.check_chain()?;
        self.to_first
            .send(Work::Close { session })
            .ok()
            .context("stage chain gone")?;
        loop {
            match self.recv_ok()? {
                ChainMsg::Closed { session: s } if s == session => break,
                ChainMsg::Closed { session: s } => {
                    bail!(
                        "unexpected close ack for session {s} while \
                         closing session {session}"
                    );
                }
                ChainMsg::Token { session: s, token, exit_layer } => {
                    // Another session's token parks; a token of the
                    // closing session is stale and drops with it.
                    if s != session {
                        let p = self.state.man.stages.len();
                        self.pending.insert(
                            s,
                            WindowOutcome {
                                token,
                                exit_layer,
                                stages_run: p,
                            },
                        );
                    }
                }
                ChainMsg::SnapshotPart { session: s, stage, .. } => {
                    bail!(
                        "unexpected snapshot part (session {s}, stage \
                         {stage}) while closing session {session}"
                    );
                }
            }
        }
        self.pending.remove(&session);
        Ok(())
    }
}

impl Generator for PipelinedEngine {
    fn generate(&mut self, prompt: &str, max_new: usize) -> (String, f64) {
        match self.generate_text(prompt, max_new) {
            Ok(out) => (out.text, out.seconds),
            Err(e) => {
                eprintln!("generation error: {e:#}");
                (String::new(), 0.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;
    use std::time::Duration;

    use crate::runtime::artifacts::Manifest;

    use super::super::session::StepEvent;
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_root().join("ee-tiny").join("manifest.json").is_file()
    }

    /// Regression (shutdown propagation): `shutdown` must join every
    /// stage thread even when a clone of the work sender outlives the
    /// engine — stages exit on the explicit `Shutdown` message flowing
    /// down the chain, not only on the channel-close cascade.
    #[test]
    fn shutdown_joins_with_live_sender_clone() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let man =
            Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
        let state = ModelState::init(man, 1);
        let eng =
            PipelinedEngine::new(state, ExitPolicy::confidence(1.0)).unwrap();
        let extra: Sender<Work> = eng.to_first.clone();
        let (done_tx, done_rx) = channel::<()>();
        std::thread::spawn(move || {
            eng.shutdown();
            done_tx.send(()).ok();
        });
        assert!(
            done_rx.recv_timeout(Duration::from_secs(60)).is_ok(),
            "shutdown hung with a live Sender clone"
        );
        drop(extra);
    }

    /// Regression (mid-chain stage failure): a dead mid-chain stage must
    /// surface as an error on the leader — not the pre-fix deadlock,
    /// where deeper stages exited but the shallower ones kept the leader
    /// channel open, so the leader blocked forever awaiting an ack only
    /// the dead stage's descendants could send.
    #[test]
    fn mid_chain_stage_failure_errors_instead_of_deadlocking() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let man =
            Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
        let state = ModelState::init(man, 1);
        let mut eng =
            PipelinedEngine::new(state, ExitPolicy::confidence(1.0)).unwrap();
        let fail_stage = eng.state.man.stages.len() - 1;
        let (done_tx, done_rx) = channel::<Result<(), String>>();
        std::thread::spawn(move || {
            let mut caches = eng.fresh_caches().unwrap();
            // Kill a deeper stage, then ask for a token: the emitting
            // window chases the failure injection down the FIFO and the
            // collect must error out.
            eng.inject_stage_failure(fail_stage).unwrap();
            let tokens = [1i32, 42];
            let stepped =
                eng.run_window(&mut caches, &tokens, 1, 1, true, true);
            // Every later chain operation fails fast, including the
            // close ack wait — none of them may hang. The failures are
            // *typed* stage errors propagated to the caller (regression
            // for the old `unreachable!("recv_ok")` arms), and the
            // engine reports itself down to the supervisor.
            let released = eng.release_caches(&caches);
            let verdict = match (&stepped, &released) {
                (Err(a), Err(b)) => {
                    let (a, b) = (format!("{a:#}"), format!("{b:#}"));
                    if !a.contains("stage") || !a.contains("injected") {
                        Err(format!("untyped step error: {a}"))
                    } else if !b.contains("chain is down") {
                        Err(format!("untyped release error: {b}"))
                    } else if !eng.chain_down() {
                        Err("engine does not report chain down".into())
                    } else {
                        Ok(())
                    }
                }
                _ => Err("chain operations against a dead stage must \
                          error"
                    .into()),
            };
            done_tx.send(verdict).ok();
            eng.shutdown();
        });
        done_rx
            .recv_timeout(Duration::from_secs(60))
            .expect("leader hung on a dead mid-chain stage")
            .unwrap();
    }

    /// Satellite (hung-stage watchdog): a chain wait that gets no
    /// message within the configured window deadline must surface as a
    /// typed hung-stage failure that poisons the engine — not the
    /// pre-watchdog indefinite stall.
    #[test]
    fn watchdog_turns_hung_wait_into_typed_failure() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let man =
            Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
        let state = ModelState::init(man, 1);
        let mut eng =
            PipelinedEngine::new(state, ExitPolicy::confidence(1.0)).unwrap();
        assert_eq!(eng.watchdog(), PipelinedEngine::DEFAULT_WATCHDOG);
        eng.set_watchdog(Duration::from_millis(200));
        let (done_tx, done_rx) = channel::<String>();
        std::thread::spawn(move || {
            let mut caches = eng.fresh_caches().unwrap();
            // Collect with no outstanding window: no token will ever
            // arrive, which is indistinguishable from a hung stage.
            let err = eng
                .collect_window(&mut caches)
                .expect_err("collect with nothing in flight must fail");
            let mut msg = format!("{err:#}");
            if !eng.chain_down() {
                msg = format!("watchdog did not poison the chain ({msg})");
            }
            // Poisoned chain fails fast instead of waiting again.
            if eng.release_caches(&caches).is_ok() {
                msg = format!("poisoned chain accepted a close ({msg})");
            }
            eng.shutdown();
            done_tx.send(msg).ok();
        });
        let msg = done_rx
            .recv_timeout(Duration::from_secs(60))
            .expect("watchdog never fired");
        assert!(msg.contains("watchdog"), "untyped watchdog error: {msg}");
    }

    /// Two sessions stepped interleaved down one chain must reproduce
    /// their serial streams token-for-token and exit-layer-for-exit-layer
    /// (the full suite is `tests/pipelined_serving_equivalence.rs`; this
    /// is the engine-level smoke check).
    #[test]
    fn interleaved_sessions_match_serial_streams() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let man =
            Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
        let state = ModelState::init(man, 5);
        let prompts = ["the capital of ", "count: 3 4 5 "];
        let max_new = 8;
        let mut eng =
            PipelinedEngine::new(state, ExitPolicy::confidence(0.2)).unwrap();

        let serial: Vec<Vec<(i32, usize)>> = prompts
            .iter()
            .map(|p| {
                let mut s =
                    DecodeSession::new_text(&mut eng, p, max_new).unwrap();
                s.prefill(&mut eng).unwrap();
                let mut out = Vec::new();
                while !s.is_done() {
                    if let StepEvent::Token { token, exit_layer, .. } =
                        s.step(&mut eng).unwrap()
                    {
                        out.push((token, exit_layer));
                    }
                }
                s.close(&mut eng);
                out
            })
            .collect();

        let mut sessions: Vec<DecodeSession> = prompts
            .iter()
            .map(|p| {
                let mut s =
                    DecodeSession::new_text(&mut eng, p, max_new).unwrap();
                s.prefill(&mut eng).unwrap();
                s
            })
            .collect();
        let mut streams: Vec<Vec<(i32, usize)>> =
            vec![Vec::new(); prompts.len()];
        loop {
            let eligible: Vec<usize> = (0..sessions.len())
                .filter(|&i| sessions[i].fusable(&eng))
                .collect();
            if eligible.is_empty() {
                break;
            }
            let mut refs: Vec<&mut DecodeSession> = sessions
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| eligible.contains(i))
                .map(|(_, s)| s)
                .collect();
            let events =
                DecodeSession::step_interleaved(&mut eng, &mut refs)
                    .unwrap();
            for (&i, ev) in eligible.iter().zip(events) {
                if let StepEvent::Token { token, exit_layer, .. } = ev {
                    streams[i].push((token, exit_layer));
                }
            }
        }
        for s in &mut sessions {
            s.close(&mut eng);
        }
        assert_eq!(
            streams, serial,
            "interleaved streams diverged from serial"
        );
        eng.shutdown();
    }
}
