//! Pipeline-based early-exit inference — the paper's novel Section 4
//! method, as a real thread-per-stage pipeline.
//!
//! When stage s's entry exit fires for the current token, two things happen
//! *in parallel* (Figure 5):
//!
//!  1. the token is reported to the leader, which immediately starts the
//!     next token's forward pass at stage 0;
//!  2. the current token's forward pass **continues** through stages
//!     s..P-1 (flagged `exited`), filling its KV caches in all deeper
//!     layers — so no KV entry is ever missing and no recomputation is
//!     needed.
//!
//! Each stage processes its FIFO inbox in arrival order, which serialises
//! the KV back-fill of token t before the forward of token t+1 on the same
//! stage — exactly the constraint the paper's latency analysis assumes.
//! The generation latency of a token emitted at stage s is therefore the
//! forward time of stages 0..s (plus queueing), not of the full model.

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{bail, Context, Result};

use crate::eval::harness::Generator;
use crate::runtime::client::StageRuntime;
use crate::runtime::tensor::{HostTensor, IntTensor};

use super::common::{GenOutput, ModelState};
use super::policy::{summarize_logits, ExitPolicy};
use super::session::{
    DecodeBackend, DecodeSession, SessionCaches, WindowOutcome,
};

/// Work flowing down the stage chain.
enum Work {
    /// Decode a window of tokens at [pos0, pos0+width).
    /// `payload` is tokens for stage 0, hidden states beyond.
    Window {
        width: usize,
        pos0: usize,
        tokens: Vec<i32>,
        hidden: Option<HostTensor>,
        /// Token already emitted at an earlier stage (KV back-fill only) —
        /// or prefill, where no token is wanted either.
        exited: bool,
        /// Exit checks enabled (generation steps, not prefill).
        check_exits: bool,
    },
    /// Clear KV caches, then propagate; last stage acks the leader.
    Reset,
    Shutdown,
}

enum ToLeader {
    Token { token: i32, exit_layer: usize },
    ResetDone,
}

struct StageThread {
    join: Option<std::thread::JoinHandle<Result<()>>>,
}

pub struct PipelinedEngine {
    pub state: ModelState,
    /// Exit-decision policy the stage threads run under. Updated via
    /// [`PipelinedEngine::set_policy`]; the stages pick the new policy up
    /// at the next chain reset (session start).
    pub policy: ExitPolicy,
    to_first: Sender<Work>,
    from_last: Receiver<ToLeader>,
    threads: Vec<StageThread>,
    /// Per-stage policy channels: each stage thread carries its own
    /// [`ExitPolicy`] clone and refreshes it during `Reset`.
    policy_tx: Vec<Sender<ExitPolicy>>,
    /// Bumped on every session start (chain reset); window passes from a
    /// superseded session are refused instead of silently decoding
    /// against the reset stage caches.
    session_generation: u64,
}

struct StageWorker {
    s: usize,
    p: usize,
    man: crate::runtime::artifacts::Manifest,
    rt: StageRuntime,
    plits: Vec<xla::Literal>,
    cache: xla::Literal,
    policy: ExitPolicy,
    inbox: Receiver<Work>,
    next: Option<Sender<Work>>,
    leader: Sender<ToLeader>,
    policy_rx: Receiver<ExitPolicy>,
    entry_exit_layers: Vec<usize>,
    final_layer: usize,
}

impl StageWorker {
    fn head_logits(&self, layer: usize, x: &[f32]) -> Result<Vec<f32>> {
        let st = &self.man.stages[self.s];
        let e = st
            .exits
            .iter()
            .find(|e| e.layer == layer)
            .context("exit not on stage")?;
        let xlit = HostTensor::new(vec![x.len()], x.to_vec()).to_literal()?;
        let mut args: Vec<&xla::Literal> = e
            .head_param_idx
            .iter()
            .map(|&i| &self.plits[i])
            .collect();
        args.push(&xlit);
        let out = self.rt.get(&format!("head{layer}"))?.run(&args)?;
        Ok(HostTensor::from_literal(&out[0])?.data)
    }

    fn run(&mut self) -> Result<()> {
        let h = self.man.model.hidden;
        loop {
            match self.inbox.recv() {
                Err(_) => return Ok(()),
                Ok(Work::Shutdown) => {
                    // Propagate down the chain explicitly: deeper stages
                    // must not depend on the channel-close cascade, which
                    // never happens if a `Sender` clone outlives the
                    // engine (the serving pool clones senders).
                    if let Some(n) = &self.next {
                        let _ = n.send(Work::Shutdown);
                    }
                    return Ok(());
                }
                Ok(Work::Reset) => {
                    while let Ok(p) = self.policy_rx.try_recv() {
                        self.policy = p;
                    }
                    self.cache = HostTensor::zeros(
                        &self.man.stages[self.s].cache_shape,
                    )
                    .to_literal()?;
                    match &self.next {
                        Some(n) => n.send(Work::Reset).ok().context("next")?,
                        None => {
                            self.leader.send(ToLeader::ResetDone).ok();
                        }
                    }
                }
                Ok(Work::Window {
                    width,
                    pos0,
                    tokens,
                    hidden,
                    mut exited,
                    check_exits,
                }) => {
                    // Entry-exit decision on the last window position.
                    // Policies that can never exit (`Never`, confidence
                    // 1.0 — the full-model baseline) skip the exit heads
                    // entirely; the decision could only be Continue.
                    if self.s > 0
                        && !exited
                        && check_exits
                        && self.policy.may_exit()
                    {
                        let xh = hidden.as_ref().unwrap();
                        let last = &xh.data[(width - 1) * h..];
                        for &layer in &self.entry_exit_layers.clone() {
                            // Skip heads the policy can never fire at
                            // (unlisted / 1.0 per-layer thresholds).
                            if !self.policy.may_exit_at(layer) {
                                continue;
                            }
                            let logits = self.head_logits(layer, last)?;
                            let sum = summarize_logits(&logits);
                            if self.policy.decide(layer, &sum).is_exit() {
                                self.leader
                                    .send(ToLeader::Token {
                                        token: sum.token,
                                        exit_layer: layer,
                                    })
                                    .ok();
                                exited = true;
                                break;
                            }
                        }
                    }

                    // Stage decode (KV fill), always.
                    let in_lit: xla::Literal = if self.s == 0 {
                        IntTensor::new(vec![width], tokens.clone())
                            .to_literal()?
                    } else {
                        hidden.as_ref().unwrap().to_literal()?
                    };
                    // Perf pass §L3-2: cache stays an xla::Literal.
                    let pos_lit = IntTensor::scalar(pos0 as i32).to_literal()?;
                    let mut args: Vec<&xla::Literal> =
                        self.plits.iter().collect();
                    args.push(&in_lit);
                    args.push(&self.cache);
                    args.push(&pos_lit);
                    let out = self
                        .rt
                        .get(&format!("decode_w{width}"))?
                        .run(&args)?;
                    let mut it = out.into_iter();
                    let x_out = HostTensor::from_literal(&it.next().unwrap())?;
                    self.cache = it.next().unwrap();

                    if self.s + 1 < self.p {
                        self.next
                            .as_ref()
                            .unwrap()
                            .send(Work::Window {
                                width,
                                pos0,
                                tokens,
                                hidden: Some(x_out),
                                exited,
                                check_exits,
                            })
                            .ok()
                            .context("next stage gone")?;
                    } else if !exited && check_exits {
                        let last = &x_out.data[(width - 1) * h..];
                        let logits =
                            self.head_logits(self.final_layer, last)?;
                        let sum = summarize_logits(&logits);
                        self.leader
                            .send(ToLeader::Token {
                                token: sum.token,
                                exit_layer: self.final_layer,
                            })
                            .ok();
                    }
                }
            }
        }
    }
}

impl PipelinedEngine {
    pub fn new(
        state: ModelState,
        policy: ExitPolicy,
    ) -> Result<PipelinedEngine> {
        let p = state.man.stages.len();
        let (leader_tx, from_last) = channel::<ToLeader>();

        // Build the chain back to front.
        let mut next_tx: Option<Sender<Work>> = None;
        let mut first_tx: Option<Sender<Work>> = None;
        let mut threads = Vec::new();
        let mut policy_tx = Vec::new();
        for s in (0..p).rev() {
            let (tx, rx) = channel::<Work>();
            let (ptx, prx) = channel::<ExitPolicy>();
            policy_tx.push(ptx);
            let man = state.man.clone();
            let params = state.stage_params[s].clone();
            let next = next_tx.take();
            let leader = leader_tx.clone();
            let pol = policy.clone();
            let join = std::thread::Builder::new()
                .name(format!("infer-{s}"))
                .spawn(move || -> Result<()> {
                    let mut rt = StageRuntime::cpu()?;
                    rt.load_stage_inference(&man, &man.stages[s])?;
                    let plits = params
                        .iter()
                        .map(|t| t.to_literal())
                        .collect::<Result<Vec<_>>>()?;
                    let entry_exit_layers: Vec<usize> = man.stages[s]
                        .exits
                        .iter()
                        .filter(|e| !e.is_final && e.entry && e.layer > 0)
                        .map(|e| e.layer)
                        .collect();
                    let final_layer = man.model.n_layers;
                    let mut w = StageWorker {
                        s,
                        p,
                        cache: HostTensor::zeros(&man.stages[s].cache_shape)
                            .to_literal()?,
                        man,
                        rt,
                        plits,
                        policy: pol,
                        inbox: rx,
                        next,
                        leader,
                        policy_rx: prx,
                        entry_exit_layers,
                        final_layer,
                    };
                    w.run()
                })
                .expect("spawn inference stage");
            threads.push(StageThread { join: Some(join) });
            next_tx = Some(tx.clone());
            first_tx = Some(tx);
        }
        policy_tx.reverse();

        Ok(PipelinedEngine {
            state,
            policy,
            to_first: first_tx.unwrap(),
            from_last,
            threads,
            policy_tx,
            session_generation: 0,
        })
    }

    /// Swap the exit policy. The stage threads adopt it at the next chain
    /// reset (i.e. the next session start), exactly when the old
    /// per-threshold setter took effect.
    pub fn set_policy(&mut self, policy: ExitPolicy) {
        self.policy = policy;
        for tx in &self.policy_tx {
            tx.send(self.policy.clone()).ok();
        }
    }

    fn reset(&self) -> Result<()> {
        self.to_first.send(Work::Reset).ok().context("chain gone")?;
        loop {
            match self.from_last.recv().context("reset ack")? {
                ToLeader::ResetDone => return Ok(()),
                // Drain stale tokens from an aborted previous run.
                ToLeader::Token { .. } => continue,
            }
        }
    }

    /// Generate up to `max_new` tokens — a [`DecodeSession`] drained to
    /// completion over the stage chain.
    pub fn generate_tokens(
        &mut self,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<GenOutput> {
        let mut session = DecodeSession::new(self, prompt, max_new)?;
        session.drain(self)
    }

    pub fn generate_text(
        &mut self,
        prompt: &str,
        max_new: usize,
    ) -> Result<GenOutput> {
        let ids = crate::data::tokenizer::ByteTokenizer.encode(prompt);
        self.generate_tokens(&ids, max_new)
    }

    pub fn shutdown(mut self) {
        // Stage 0 forwards `Shutdown` down the chain, so every stage exits
        // on the explicit message even if a `Sender` clone keeps some
        // stage's inbox open (channel-close is only the fallback).
        let _ = self.to_first.send(Work::Shutdown);
        for t in &mut self.threads {
            if let Some(j) = t.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl DecodeBackend for PipelinedEngine {
    /// Decode state lives in the stage threads, so a fresh session resets
    /// the whole chain — and only one session may be live at a time.
    /// Policies set via [`PipelinedEngine::set_policy`] are picked up
    /// by the stages during this reset.
    fn fresh_caches(&mut self) -> Result<SessionCaches> {
        let widths = &self.state.man.decode_widths;
        // Generation steps decode one position at a time.
        if !widths.contains(&1) {
            bail!(
                "pipelined engine decodes with width-1 windows, but the \
                 manifest only lists decode widths {widths:?}"
            );
        }
        self.reset()?;
        self.session_generation += 1;
        Ok(SessionCaches {
            caches: Vec::new(),
            generation: self.session_generation,
        })
    }

    /// Prefill windows (`emit` false) are fire-and-forget KV fills; the
    /// stage FIFOs serialise them before the first generation step.
    /// Generation windows await the emitted token from the chain. Exit
    /// checks ride on `emit` exactly as the monolithic loop did: the
    /// back-fill design never suspends exits, so `allow_exit` (a
    /// recompute-deficit concern) is ignored.
    fn run_window(
        &mut self,
        caches: &mut SessionCaches,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        _allow_exit: bool,
        emit: bool,
    ) -> Result<WindowOutcome> {
        if caches.generation != self.session_generation {
            bail!(
                "stale decode session: a newer session has reset this \
                 pipelined engine (it supports one live session at a time)"
            );
        }
        let p = self.state.man.stages.len();
        self.to_first
            .send(Work::Window {
                width,
                pos0,
                tokens: tokens[pos0..pos0 + width].to_vec(),
                hidden: None,
                exited: !emit, // prefill wants no emission
                check_exits: emit,
            })
            .ok()
            .context("chain gone")?;
        if !emit {
            return Ok(WindowOutcome { token: -1, exit_layer: 0, stages_run: p });
        }
        match self.from_last.recv().context("token")? {
            ToLeader::Token { token, exit_layer } => {
                // KV back-fill always completes through every stage, so
                // the session never accrues a deficit.
                Ok(WindowOutcome { token, exit_layer, stages_run: p })
            }
            ToLeader::ResetDone => bail!("unexpected reset ack"),
        }
    }

    fn decode_widths(&self) -> &[usize] {
        &self.state.man.decode_widths
    }

    fn max_seq(&self) -> usize {
        self.state.man.model.max_seq
    }

    fn n_stages(&self) -> usize {
        self.state.man.stages.len()
    }

    fn exit_policy(&self) -> &ExitPolicy {
        &self.policy
    }

    fn tracks_deficit(&self) -> bool {
        false
    }

    fn max_live_sessions(&self) -> usize {
        1
    }

    /// Declined: decode state lives sharded across the stage threads
    /// (one resident KV cache per thread), not in the session — there is
    /// no per-session cache to copy out. The serving pool checks this
    /// flag and serves pipelined workers without prefix reuse.
    fn supports_cache_snapshots(&self) -> bool {
        false
    }

    fn snapshot_caches(
        &mut self,
        _caches: &SessionCaches,
        _positions: usize,
    ) -> Result<Vec<crate::runtime::tensor::HostTensor>> {
        bail!(
            "the pipelined engine keeps KV caches in its stage threads \
             and cannot snapshot them (supports_cache_snapshots is false)"
        )
    }

    fn restore_caches(
        &mut self,
        _snapshot: &[crate::runtime::tensor::HostTensor],
    ) -> Result<SessionCaches> {
        bail!(
            "the pipelined engine keeps KV caches in its stage threads \
             and cannot restore snapshots (supports_cache_snapshots is \
             false)"
        )
    }
}

impl Generator for PipelinedEngine {
    fn generate(&mut self, prompt: &str, max_new: usize) -> (String, f64) {
        match self.generate_text(prompt, max_new) {
            Ok(out) => (out.text, out.seconds),
            Err(e) => {
                eprintln!("generation error: {e:#}");
                (String::new(), 0.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;
    use std::time::Duration;

    use crate::runtime::artifacts::Manifest;

    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Regression (shutdown propagation): `shutdown` must join every
    /// stage thread even when a clone of the work sender outlives the
    /// engine — stages exit on the explicit `Shutdown` message flowing
    /// down the chain, not only on the channel-close cascade.
    #[test]
    fn shutdown_joins_with_live_sender_clone() {
        if !artifacts_root().join("ee-tiny").join("manifest.json").is_file()
        {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let man =
            Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
        let state = ModelState::init(man, 1);
        let eng =
            PipelinedEngine::new(state, ExitPolicy::confidence(1.0)).unwrap();
        let extra: Sender<Work> = eng.to_first.clone();
        let (done_tx, done_rx) = channel::<()>();
        std::thread::spawn(move || {
            eng.shutdown();
            done_tx.send(()).ok();
        });
        assert!(
            done_rx.recv_timeout(Duration::from_secs(60)).is_ok(),
            "shutdown hung with a live Sender clone"
        );
        drop(extra);
    }
}
