//! Shared inference machinery: model state (params from checkpoint or
//! seed), exit metadata, width selection, statistics. The exit rule
//! itself lives in [`super::policy`] ([`ExitPolicy`]); engines hand each
//! exit head's logits summary to the policy and act on its decision.
//!
//! [`ExitPolicy`]: super::policy::ExitPolicy

use std::path::Path;

use anyhow::{bail, ensure, Result};

use crate::data::tokenizer::{ByteTokenizer, BOS_ID, EOS_ID};
use crate::runtime::artifacts::Manifest;
use crate::runtime::params;
use crate::runtime::tensor::HostTensor;

/// Parameters + manifest for an inference engine (host-resident; each
/// engine converts to literals/buffers as it sees fit).
#[derive(Clone)]
pub struct ModelState {
    pub man: Manifest,
    pub stage_params: Vec<Vec<HostTensor>>,
}

impl ModelState {
    /// Random-initialised params (tests / untrained demos).
    pub fn init(man: Manifest, seed: u64) -> ModelState {
        let stage_params = (0..man.stages.len())
            .map(|s| params::init_stage(seed, &man, s))
            .collect();
        ModelState { man, stage_params }
    }

    /// Params from a trainer checkpoint.
    pub fn from_checkpoint(man: Manifest, path: &Path) -> Result<ModelState> {
        let stage_params = params::load_stage_params(path, &man)?;
        Ok(ModelState { man, stage_params })
    }

    /// Entry exits (layer > 0) of stage s, i.e. those the decode engines
    /// evaluate on the stage's input hidden state. Exits on the embedding
    /// output (layer 0) are training-time features (Figure 7's third
    /// exit); their confidence carries no signal and the paper does not
    /// use them for inference either.
    pub fn entry_exits(&self, s: usize) -> Vec<&crate::runtime::artifacts::ExitMeta> {
        self.man.stages[s]
            .exits
            .iter()
            .filter(|e| !e.is_final && e.entry && e.layer > 0)
            .collect()
    }

    pub fn final_exit(&self) -> &crate::runtime::artifacts::ExitMeta {
        self.man.stages.last().unwrap().exits.last().unwrap()
    }
}

/// Smallest available decode width >= `need` that fits before `pos + 1`
/// (windows end at the current position and extend left over healed
/// territory). None if no width fits.
pub fn pick_width(widths: &[usize], need: usize, pos: usize) -> Option<usize> {
    widths
        .iter()
        .copied()
        .filter(|&w| w >= need && w <= pos + 1)
        .min()
}

/// BOS-prefixed token buffer for a generation request, with room reserved
/// for `reserve_new` generated tokens.
pub fn prompt_tokens(prompt: &[i32], reserve_new: usize) -> Vec<i32> {
    let mut tokens = Vec::with_capacity(prompt.len() + reserve_new + 1);
    tokens.push(BOS_ID);
    tokens.extend_from_slice(prompt);
    tokens
}

/// Clamp `max_new` to the KV-cache capacity remaining after the prompt.
///
/// The generation loops already stop gracefully when the cache fills; a
/// prompt that fits must therefore generate as many tokens as the cache
/// allows rather than erroring up front. Errors only when the prompt
/// itself (BOS included) does not fit.
pub fn clamp_max_new(
    prompt_len: usize,
    max_new: usize,
    max_seq: usize,
) -> Result<usize> {
    if prompt_len > max_seq {
        bail!(
            "prompt of {prompt_len} tokens (incl. BOS) exceeds KV-cache \
             capacity {max_seq}"
        );
    }
    Ok(max_new.min(max_seq - prompt_len))
}

/// Plan the prefill of positions [0, l-1) as (pos0, width) windows over
/// the *available* decode widths, greedily widest-first.
///
/// When the tail is shorter than every available width (e.g. the manifest
/// lacks a width-1 executable), the smallest window slides left over
/// already-processed positions instead — recomputation is idempotent, so
/// overlap only costs compute. Every returned window stays inside the
/// token buffer (`pos0 + width <= l`). Errors when no window can fit at
/// all.
pub fn prefill_chunks(
    widths: &[usize],
    l: usize,
) -> Result<Vec<(usize, usize)>> {
    prefill_chunks_from(widths, 0, l)
}

/// [`prefill_chunks`] for a *suffix*: plan windows covering positions
/// [start, l-1) of the token buffer — the cached-prefix case, where
/// positions below `start` were restored from a snapshot and only the
/// remainder needs computing.
///
/// Windows may slide left of `start` over restored/healed territory
/// (recomputation is idempotent), so the only hard requirement is that
/// the smallest width fits the buffer at all.
pub fn prefill_chunks_from(
    widths: &[usize],
    start: usize,
    l: usize,
) -> Result<Vec<(usize, usize)>> {
    let mut chunks = Vec::new();
    if l < 2 || start + 1 >= l {
        return Ok(chunks);
    }
    let wmin = match widths.iter().copied().min() {
        Some(w) => w,
        None => bail!("no decode widths available in manifest"),
    };
    if wmin > l {
        bail!(
            "no decode width fits: smallest available width {wmin} exceeds \
             token buffer of {l} (widths {widths:?})"
        );
    }
    let mut pos = start;
    while pos + 1 < l {
        let remaining = l - 1 - pos;
        match widths.iter().copied().filter(|&w| w <= remaining).max() {
            Some(w) => {
                chunks.push((pos, w));
                pos += w;
            }
            None => {
                // Tail shorter than every width: cover it with the
                // smallest window, slid left over healed territory (it
                // may also cover position l-1, which is harmless).
                chunks.push((l - wmin, wmin));
                pos = l - 1;
            }
        }
    }
    Ok(chunks)
}

/// Per-exit usage statistics of one generation run.
#[derive(Debug, Clone, Default)]
pub struct ExitStats {
    /// (exit layer, tokens emitted there). The final exit uses layer ==
    /// n_layers.
    pub counts: Vec<(usize, usize)>,
    /// Full-model passes forced by the deficit cap (sequential engine).
    pub forced_full: usize,
}

impl ExitStats {
    pub fn record(&mut self, layer: usize) {
        for c in &mut self.counts {
            if c.0 == layer {
                c.1 += 1;
                return;
            }
        }
        self.counts.push((layer, 1));
        self.counts.sort();
    }

    /// Accumulate another run's counts into this one (the serving layer
    /// aggregates per-exit usage across requests).
    pub fn merge(&mut self, other: &ExitStats) {
        for &(layer, n) in &other.counts {
            match self.counts.iter_mut().find(|c| c.0 == layer) {
                Some(c) => c.1 += n,
                None => {
                    self.counts.push((layer, n));
                    self.counts.sort();
                }
            }
        }
        self.forced_full += other.forced_full;
    }

    pub fn total(&self) -> usize {
        self.counts.iter().map(|c| c.1).sum()
    }

    /// Fraction of tokens emitted at early exits.
    pub fn early_fraction(&self, n_layers: usize) -> f64 {
        let total = self.total().max(1);
        let early: usize = self
            .counts
            .iter()
            .filter(|c| c.0 < n_layers)
            .map(|c| c.1)
            .sum();
        early as f64 / total as f64
    }
}

/// One generation result.
#[derive(Debug, Clone)]
pub struct GenOutput {
    pub tokens: Vec<i32>,
    pub text: String,
    pub seconds: f64,
    pub stats: ExitStats,
}

/// Shared stopping rule: stop on EOS/BOS or after max_new tokens.
pub fn is_stop_token(t: i32) -> bool {
    t == EOS_ID || t == BOS_ID
}

pub fn detokenize(tokens: &[i32]) -> String {
    ByteTokenizer.decode(tokens)
}

/// Slice a full-capacity per-stage KV cache `[layers, 2, S, heads, dim]`
/// down to its first `positions` entries along the position axis — the
/// bytes-accurate snapshot format every snapshot-capable backend shares
/// (`DecodeBackend::snapshot_caches`). Entries past `positions` are
/// zeros-by-construction (prefill never wrote them), so nothing is lost.
pub fn slice_cache_positions(
    cache: &HostTensor,
    shape: &[usize],
    positions: usize,
) -> Result<HostTensor> {
    ensure!(
        cache.shape.as_slice() == shape
            && shape.len() == 5
            && shape[1] == 2,
        "cache shape {:?} does not match stage cache shape {:?}",
        cache.shape,
        shape
    );
    let held = positions.min(shape[2]);
    let row = shape[3] * shape[4];
    let src_block = shape[2] * row;
    let dst_block = held * row;
    let mut data = vec![0f32; shape[0] * 2 * dst_block];
    for blk in 0..shape[0] * 2 {
        data[blk * dst_block..][..dst_block]
            .copy_from_slice(&cache.data[blk * src_block..][..dst_block]);
    }
    Ok(HostTensor::new(vec![shape[0], 2, held, shape[3], shape[4]], data))
}

/// Zero-pad a position-sliced snapshot back to the full cache capacity
/// `shape` (the inverse of [`slice_cache_positions`]); full-capacity
/// snapshots pass through unchanged. Every non-position dimension is
/// validated, so a snapshot from a differently shaped model is rejected
/// instead of silently misread.
pub fn pad_cache_to_capacity(
    snap: &HostTensor,
    shape: &[usize],
) -> Result<HostTensor> {
    if snap.shape.as_slice() == shape {
        return Ok(snap.clone());
    }
    ensure!(
        snap.shape.len() == 5
            && shape.len() == 5
            && snap.shape[0] == shape[0]
            && snap.shape[1] == 2
            && shape[1] == 2
            && snap.shape[2] <= shape[2]
            && snap.shape[3] == shape[3]
            && snap.shape[4] == shape[4],
        "cache snapshot shape {:?} does not fit capacity {:?}",
        snap.shape,
        shape
    );
    let held = snap.shape[2];
    let row = shape[3] * shape[4];
    let src_block = held * row;
    let dst_block = shape[2] * row;
    let mut full = HostTensor::zeros(shape);
    for blk in 0..shape[0] * 2 {
        full.data[blk * dst_block..][..src_block]
            .copy_from_slice(&snap.data[blk * src_block..][..src_block]);
    }
    Ok(full)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_width_policies() {
        let widths = [1usize, 2, 4, 8];
        assert_eq!(pick_width(&widths, 1, 0), Some(1));
        assert_eq!(pick_width(&widths, 2, 5), Some(2));
        assert_eq!(pick_width(&widths, 3, 5), Some(4));
        // Window of 4 does not fit before position 2.
        assert_eq!(pick_width(&widths, 3, 2), None);
        assert_eq!(pick_width(&widths, 9, 100), None);
    }

    #[test]
    fn clamp_max_new_clamps_and_rejects() {
        // Regression (over-strict capacity check): a prompt that fits is
        // clamped to the remaining cache capacity, never an error.
        assert_eq!(clamp_max_new(10, 5, 32).unwrap(), 5);
        assert_eq!(clamp_max_new(30, 5, 32).unwrap(), 2);
        assert_eq!(clamp_max_new(32, 5, 32).unwrap(), 0);
        assert!(clamp_max_new(33, 0, 32).is_err());
    }

    #[test]
    fn prompt_tokens_prepends_bos() {
        let t = prompt_tokens(&[10, 20], 4);
        assert_eq!(t, vec![crate::data::tokenizer::BOS_ID, 10, 20]);
        assert!(t.capacity() >= 7);
    }

    #[test]
    fn prefill_chunks_cover_prompt_greedily() {
        // widths [1,2,4,8], l=12: positions [0,11) as 8 + 2 + 1.
        let c = prefill_chunks(&[1, 2, 4, 8], 12).unwrap();
        assert_eq!(c, vec![(0, 8), (8, 2), (10, 1)]);
        // Single-token buffer: nothing to prefill.
        assert!(prefill_chunks(&[1, 2], 1).unwrap().is_empty());
    }

    #[test]
    fn prefill_chunks_without_width_one() {
        // Regression (prefill width fallback): widths lacking 1 must not
        // fall back to a nonexistent width-1 executable; the tail slides
        // the smallest available window left over healed positions.
        let c = prefill_chunks(&[4, 8], 12).unwrap();
        assert_eq!(c, vec![(0, 8), (8, 4)]);
        for &(pos, w) in &c {
            assert!(pos + w <= 12, "window {pos}+{w} out of bounds");
        }
        // Tail shorter than every width mid-prompt.
        let c = prefill_chunks(&[4], 6).unwrap();
        assert_eq!(c, vec![(0, 4), (2, 4)]);
        // Prompt shorter than the smallest width: a clear error, not a
        // confusing "exec not found" at runtime.
        let err = prefill_chunks(&[4, 8], 3).unwrap_err().to_string();
        assert!(err.contains("width"), "{err}");
        assert!(prefill_chunks(&[], 5).is_err());
    }

    /// Property: for arbitrary width sets and buffer lengths, every
    /// prefill plan either errors (only legal when even the smallest
    /// width cannot fit) or covers every position in [0, l-1) with
    /// windows that stay inside the token buffer.
    #[test]
    fn prefill_chunks_cover_every_position_for_arbitrary_widths() {
        use crate::util::proptest;

        proptest::check("prefill_chunks coverage", 256, |rng| {
            let n_widths = rng.range(1, 5);
            let mut widths: Vec<usize> =
                (0..n_widths).map(|_| rng.range(1, 17)).collect();
            widths.sort();
            widths.dedup();
            let l = rng.range(0, 40);
            let chunks = match prefill_chunks(&widths, l) {
                Err(_) => {
                    let wmin = *widths.iter().min().unwrap();
                    if l >= 2 && wmin <= l {
                        return Err(format!(
                            "error despite a fitting width: widths \
                             {widths:?} l {l}"
                        ));
                    }
                    return Ok(());
                }
                Ok(c) => c,
            };
            let mut covered = vec![false; l.max(1)];
            for &(pos, w) in &chunks {
                if pos + w > l {
                    return Err(format!(
                        "window {pos}+{w} out of bounds (l {l}, widths \
                         {widths:?})"
                    ));
                }
                for c in covered.iter_mut().skip(pos).take(w) {
                    *c = true;
                }
            }
            if let Some(i) = covered
                .iter()
                .take(l.saturating_sub(1))
                .position(|&c| !c)
            {
                return Err(format!(
                    "position {i} uncovered (l {l}, widths {widths:?}, \
                     chunks {chunks:?})"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn prefill_chunks_from_covers_only_the_suffix() {
        // widths [1,2,4,8], start 8, l 12: positions [8,11) as 2 + 1.
        let c = prefill_chunks_from(&[1, 2, 4, 8], 8, 12).unwrap();
        assert_eq!(c, vec![(8, 2), (10, 1)]);
        // Nothing left to prefill (cached prefix covers the buffer).
        assert!(prefill_chunks_from(&[1, 2], 11, 12).unwrap().is_empty());
        assert!(prefill_chunks_from(&[1, 2], 20, 12).unwrap().is_empty());
        // Suffix shorter than every width: the smallest window slides
        // left over restored territory (idempotent recomputation).
        let c = prefill_chunks_from(&[4], 9, 12).unwrap();
        assert_eq!(c, vec![(8, 4)]);
    }

    /// Property: for arbitrary width sets, buffer lengths, and resume
    /// points, every suffix plan either errors (only legal when even the
    /// smallest width exceeds the buffer) or covers every position in
    /// [start, l-1) with in-bounds windows.
    #[test]
    fn prefill_chunks_from_cover_suffix_for_arbitrary_widths() {
        use crate::util::proptest;

        proptest::check("prefill_chunks_from coverage", 256, |rng| {
            let n_widths = rng.range(1, 5);
            let mut widths: Vec<usize> =
                (0..n_widths).map(|_| rng.range(1, 17)).collect();
            widths.sort();
            widths.dedup();
            let l = rng.range(0, 40);
            let start = rng.range(0, 40);
            let chunks = match prefill_chunks_from(&widths, start, l) {
                Err(_) => {
                    let wmin = *widths.iter().min().unwrap();
                    if l >= 2 && start + 1 < l && wmin <= l {
                        return Err(format!(
                            "error despite a fitting width: widths \
                             {widths:?} start {start} l {l}"
                        ));
                    }
                    return Ok(());
                }
                Ok(c) => c,
            };
            let mut covered = vec![false; l.max(1)];
            for &(pos, w) in &chunks {
                if pos + w > l {
                    return Err(format!(
                        "window {pos}+{w} out of bounds (l {l}, widths \
                         {widths:?})"
                    ));
                }
                for c in covered.iter_mut().skip(pos).take(w) {
                    *c = true;
                }
            }
            for (i, c) in covered
                .iter()
                .enumerate()
                .take(l.saturating_sub(1))
                .skip(start.min(l))
            {
                if !*c {
                    return Err(format!(
                        "position {i} uncovered (start {start}, l {l}, \
                         widths {widths:?}, chunks {chunks:?})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn exit_stats_merge_accumulates() {
        let mut a = ExitStats::default();
        a.record(2);
        a.record(4);
        let mut b = ExitStats::default();
        b.record(2);
        b.record(6);
        b.forced_full = 3;
        a.merge(&b);
        assert_eq!(a.counts, vec![(2, 2), (4, 1), (6, 1)]);
        assert_eq!(a.forced_full, 3);
        assert_eq!(a.total(), 4);
    }

    #[test]
    fn exit_stats_accumulate() {
        let mut s = ExitStats::default();
        s.record(2);
        s.record(4);
        s.record(2);
        assert_eq!(s.counts, vec![(2, 2), (4, 1)]);
        assert_eq!(s.total(), 3);
        assert!((s.early_fraction(4) - 2.0 / 3.0).abs() < 1e-12);
    }
}
