//! Shared inference machinery: model state (params from checkpoint or
//! seed), exit metadata, confidence rule, width selection, statistics.

use std::path::Path;

use anyhow::Result;

use crate::data::tokenizer::{ByteTokenizer, BOS_ID, EOS_ID};
use crate::runtime::artifacts::Manifest;
use crate::runtime::params;
use crate::runtime::tensor::{argmax_prob, softmax, HostTensor};

/// Parameters + manifest for an inference engine (host-resident; each
/// engine converts to literals/buffers as it sees fit).
#[derive(Clone)]
pub struct ModelState {
    pub man: Manifest,
    pub stage_params: Vec<Vec<HostTensor>>,
}

impl ModelState {
    /// Random-initialised params (tests / untrained demos).
    pub fn init(man: Manifest, seed: u64) -> ModelState {
        let stage_params = (0..man.stages.len())
            .map(|s| params::init_stage(seed, &man, s))
            .collect();
        ModelState { man, stage_params }
    }

    /// Params from a trainer checkpoint.
    pub fn from_checkpoint(man: Manifest, path: &Path) -> Result<ModelState> {
        let stage_params = params::load_stage_params(path, &man)?;
        Ok(ModelState { man, stage_params })
    }

    /// Entry exits (layer > 0) of stage s, i.e. those the decode engines
    /// evaluate on the stage's input hidden state. Exits on the embedding
    /// output (layer 0) are training-time features (Figure 7's third
    /// exit); their confidence carries no signal and the paper does not
    /// use them for inference either.
    pub fn entry_exits(&self, s: usize) -> Vec<&crate::runtime::artifacts::ExitMeta> {
        self.man.stages[s]
            .exits
            .iter()
            .filter(|e| !e.is_final && e.entry && e.layer > 0)
            .collect()
    }

    pub fn final_exit(&self) -> &crate::runtime::artifacts::ExitMeta {
        self.man.stages.last().unwrap().exits.last().unwrap()
    }
}

/// The paper's exit rule: exit iff max softmax probability >= threshold.
/// Returns (token, confidence).
pub fn confidence_decision(logits: &[f32]) -> (i32, f32) {
    let probs = softmax(logits);
    let (idx, p) = argmax_prob(&probs);
    (idx as i32, p)
}

/// Smallest available decode width >= `need` that fits before `pos + 1`
/// (windows end at the current position and extend left over healed
/// territory). None if no width fits.
pub fn pick_width(widths: &[usize], need: usize, pos: usize) -> Option<usize> {
    widths
        .iter()
        .copied()
        .filter(|&w| w >= need && w <= pos + 1)
        .min()
}

/// Per-exit usage statistics of one generation run.
#[derive(Debug, Clone, Default)]
pub struct ExitStats {
    /// (exit layer, tokens emitted there). The final exit uses layer ==
    /// n_layers.
    pub counts: Vec<(usize, usize)>,
    /// Full-model passes forced by the deficit cap (sequential engine).
    pub forced_full: usize,
}

impl ExitStats {
    pub fn record(&mut self, layer: usize) {
        for c in &mut self.counts {
            if c.0 == layer {
                c.1 += 1;
                return;
            }
        }
        self.counts.push((layer, 1));
        self.counts.sort();
    }

    pub fn total(&self) -> usize {
        self.counts.iter().map(|c| c.1).sum()
    }

    /// Fraction of tokens emitted at early exits.
    pub fn early_fraction(&self, n_layers: usize) -> f64 {
        let total = self.total().max(1);
        let early: usize = self
            .counts
            .iter()
            .filter(|c| c.0 < n_layers)
            .map(|c| c.1)
            .sum();
        early as f64 / total as f64
    }
}

/// One generation result.
#[derive(Debug, Clone)]
pub struct GenOutput {
    pub tokens: Vec<i32>,
    pub text: String,
    pub seconds: f64,
    pub stats: ExitStats,
}

/// Shared stopping rule: stop on EOS/BOS or after max_new tokens.
pub fn is_stop_token(t: i32) -> bool {
    t == EOS_ID || t == BOS_ID
}

pub fn detokenize(tokens: &[i32]) -> String {
    ByteTokenizer.decode(tokens)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confidence_decision_peaks() {
        let mut logits = vec![0.0f32; 10];
        logits[3] = 8.0;
        let (tok, conf) = confidence_decision(&logits);
        assert_eq!(tok, 3);
        assert!(conf > 0.99);
        let flat = vec![0.0f32; 10];
        let (_, conf) = confidence_decision(&flat);
        assert!((conf - 0.1).abs() < 1e-5);
    }

    #[test]
    fn pick_width_policies() {
        let widths = [1usize, 2, 4, 8];
        assert_eq!(pick_width(&widths, 1, 0), Some(1));
        assert_eq!(pick_width(&widths, 2, 5), Some(2));
        assert_eq!(pick_width(&widths, 3, 5), Some(4));
        // Window of 4 does not fit before position 2.
        assert_eq!(pick_width(&widths, 3, 2), None);
        assert_eq!(pick_width(&widths, 9, 100), None);
    }

    #[test]
    fn exit_stats_accumulate() {
        let mut s = ExitStats::default();
        s.record(2);
        s.record(4);
        s.record(2);
        assert_eq!(s.counts, vec![(2, 2), (4, 1)]);
        assert_eq!(s.total(), 3);
        assert!((s.early_fraction(4) - 2.0 / 3.0).abs() < 1e-12);
    }
}
