//! Resumable per-token decode sessions — the step-based core of both
//! inference engines and the serving layer.
//!
//! A [`DecodeSession`] owns everything that used to live on the stack of a
//! monolithic `generate_tokens` loop: the token buffer, per-session KV
//! caches, the recomputation deficit, per-exit statistics, and the
//! stop/budget/capacity checks. It advances one token per [`step`] call,
//! so a caller can interleave many sessions over one engine (continuous
//! batching), stream tokens as they are emitted, or simply [`drain`] to
//! reproduce the old blocking behaviour.
//!
//! The engine side of the split is [`DecodeBackend`]: the minimal surface
//! a session needs — fresh caches, one window pass, and static model
//! facts. `SequentialEngine` implements it with host-side per-session
//! caches (KV recomputation, Section 4 / Appendix D.3); `PipelinedEngine`
//! keeps per-session KV slots inside its stage threads and interleaves
//! many sessions' windows down the one chain
//! ([`DecodeBackend::interleaves_windows`] /
//! [`DecodeSession::step_interleaved`]). Either way, arbitrarily many
//! sessions can be live at once, and both engines snapshot and restore
//! per-session caches for the shared-prefix KV cache.
//!
//! [`step`]: DecodeSession::step
//! [`drain`]: DecodeSession::drain

use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::tensor::HostTensor;

use super::common::{
    clamp_max_new, detokenize, is_stop_token, pick_width,
    prefill_chunks_from, prompt_tokens, ExitStats, GenOutput,
};
use super::policy::ExitPolicy;
use super::prefix_cache::{CacheSnapshot, PinnedSnapshot, SnapshotSource};

/// Per-session decode state handed out by a backend.
pub struct SessionCaches {
    /// Host-side per-session KV caches (the sequential engine: one
    /// literal per stage). Backends whose decode state lives elsewhere
    /// (the pipelined engine's stage threads) leave this empty.
    pub caches: Vec<xla::Literal>,
    /// Backend-assigned session id for engines with engine-resident
    /// decode state: the pipelined engine keys every stage's KV-cache
    /// slot (and every in-flight chain message) by this id, and the
    /// sequential engine keys device-resident fused lane groups (and
    /// the parked caches of dissolved ones) by it — so the `caches`
    /// vector above may be stale while the session rides a resident
    /// group, until the engine lazily syncs it on the next touch.
    /// Ids are never reused.
    pub generation: u64,
}

/// One lane of a fused batched decode pass ([`DecodeBackend::run_lanes`]):
/// a session's current width-1 window, by reference into its state.
///
/// Lanes are independent — each carries its own KV caches and position —
/// so sessions at different sequence lengths share one fused call. The
/// engine gathers `caches` into the lane-stacked layout, runs one batched
/// executable per stage, applies exit heads to per-lane hidden slices,
/// and scatters the updated caches back.
pub struct LaneSlot<'a> {
    /// The session's per-stage KV caches (gathered, then scattered back).
    pub caches: &'a mut SessionCaches,
    /// The lane's current token (the one whose successor is decoded).
    pub token: i32,
    /// The token's position in the lane's buffer.
    pub pos: usize,
    /// Early-exit checks enabled for this lane (false under the forced
    /// full-model pass bookkeeping, exactly as in the solo path).
    pub allow_exit: bool,
}

/// Host⇄device KV-cache traffic attributable to fused lane decode,
/// accumulated by the backend across its lifetime (monotonic; sample
/// before/after a window of work and diff with [`LaneTraffic::since`]).
///
/// Gathers/scatters are counted in **lane × stage** units: one gather is
/// one lane's cache for one stage crossing host→device into a
/// lane-stacked literal, one scatter is the reverse. A device-resident
/// backend reports traffic only at group formation (gathers) and lane
/// departure / snapshot / preemption (scatters); a round-trip backend
/// reports `lanes × stages` of each per fused step. `warm_hits` /
/// `cold_forms` count fused passes served by an already-resident group
/// vs. passes that had to (re)gather one.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneTraffic {
    /// Lane×stage cache copies host→device (group formation).
    pub cache_gathers: u64,
    /// Lane×stage cache copies device→host (departure/snapshot/regroup).
    pub cache_scatters: u64,
    /// Bytes moved by gathers.
    pub gather_bytes: u64,
    /// Bytes moved by scatters.
    pub scatter_bytes: u64,
    /// Fused passes stepped against an already-resident lane group.
    pub warm_hits: u64,
    /// Fused passes that had to gather (form) their lane group.
    pub cold_forms: u64,
}

impl LaneTraffic {
    /// Delta of this (later) sample over an earlier one.
    pub fn since(&self, base: &LaneTraffic) -> LaneTraffic {
        LaneTraffic {
            cache_gathers: self.cache_gathers - base.cache_gathers,
            cache_scatters: self.cache_scatters - base.cache_scatters,
            gather_bytes: self.gather_bytes - base.gather_bytes,
            scatter_bytes: self.scatter_bytes - base.scatter_bytes,
            warm_hits: self.warm_hits - base.warm_hits,
            cold_forms: self.cold_forms - base.cold_forms,
        }
    }
}

/// Result of one fused [`DecodeSession::step_fused`] round.
#[derive(Debug)]
pub struct FusedStep {
    /// Per-lane step events, in lane order.
    pub events: Vec<StepEvent>,
    /// Stages the fused pass skipped because *every* lane had already
    /// taken an early exit (un-fired lanes never cause a skip).
    pub stages_skipped: usize,
}

/// Result of one decode window pass.
#[derive(Debug, Clone, Copy)]
pub struct WindowOutcome {
    /// Emitted token (-1 for pure prefill passes).
    pub token: i32,
    /// Exit layer the token came from (final layer when no early exit).
    pub exit_layer: usize,
    /// Stages the pass ran; a pass covering all stages clears the
    /// recomputation deficit.
    pub stages_run: usize,
}

/// The engine surface a [`DecodeSession`] drives. Both engines implement
/// this, which keeps every caller — `generate_tokens`, the serving pool,
/// the eval harness — on the one audited decode path.
pub trait DecodeBackend {
    /// Fresh per-session caches; called once when a session is created.
    /// Backends with engine-resident state use this to reset it.
    fn fresh_caches(&mut self) -> Result<SessionCaches>;

    /// Run one decode window over `tokens[pos0..pos0 + width]`.
    ///
    /// `allow_exit` gates early-exit checks (false during prefill and
    /// forced full-model passes); `emit` is false for pure prefill
    /// passes, which run all stages and emit no token.
    #[allow(clippy::too_many_arguments)]
    fn run_window(
        &mut self,
        caches: &mut SessionCaches,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        allow_exit: bool,
        emit: bool,
    ) -> Result<WindowOutcome>;

    /// Whether this backend can interleave emitting windows from many
    /// live sessions ([`submit_window`] / [`collect_window`]): submit
    /// every session's window first, then collect their tokens, so one
    /// session's deep-stage KV back-fill overlaps another session's
    /// shallow-stage forward — the serving-side pipeline-bubble filling
    /// of the paper's Section 4. Default false: callers fall back to
    /// solo [`run_window`] steps.
    ///
    /// [`submit_window`]: DecodeBackend::submit_window
    /// [`collect_window`]: DecodeBackend::collect_window
    /// [`run_window`]: DecodeBackend::run_window
    fn interleaves_windows(&self) -> bool {
        false
    }

    /// Split-phase emitting window pass, submit half: queue one decode
    /// window without waiting for its token. Only meaningful on backends
    /// whose [`interleaves_windows`] is true (the default errors).
    ///
    /// [`interleaves_windows`]: DecodeBackend::interleaves_windows
    fn submit_window(
        &mut self,
        caches: &mut SessionCaches,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        allow_exit: bool,
    ) -> Result<()> {
        let _ = (caches, tokens, pos0, width, allow_exit);
        bail!("this backend does not interleave windows")
    }

    /// Split-phase emitting window pass, collect half: await the token
    /// of this session's outstanding [`submit_window`].
    ///
    /// [`submit_window`]: DecodeBackend::submit_window
    fn collect_window(
        &mut self,
        caches: &mut SessionCaches,
    ) -> Result<WindowOutcome> {
        let _ = caches;
        bail!("this backend does not interleave windows")
    }

    /// Decode window widths available in the manifest.
    fn decode_widths(&self) -> &[usize];

    /// Fused-lane batch sizes this backend can decode in one call
    /// (sorted ascending; empty when lane fusion is unavailable —
    /// default). A non-empty ladder promises [`run_lanes`] works for
    /// exactly these group sizes.
    ///
    /// [`run_lanes`]: DecodeBackend::run_lanes
    fn decode_lanes(&self) -> &[usize] {
        &[]
    }

    /// Advance every lane by one width-1 decode window in a single
    /// batched pass per stage, with per-lane exit decisions: a fired
    /// lane's token is taken at its exit layer, and deeper stages are
    /// skipped only once every lane has fired. Returns one
    /// [`WindowOutcome`] per lane, in lane order, with solo-equivalent
    /// `stages_run` (so the caller's deficit accounting matches the
    /// unfused path exactly).
    ///
    /// Errors on backends whose [`decode_lanes`] is empty, and when
    /// `lanes.len()` is not one of the advertised sizes.
    ///
    /// [`decode_lanes`]: DecodeBackend::decode_lanes
    fn run_lanes(
        &mut self,
        lanes: &mut [LaneSlot<'_>],
    ) -> Result<Vec<WindowOutcome>> {
        let _ = lanes;
        bail!("this backend does not support fused lane decode")
    }

    /// Monotonic host⇄device KV-cache traffic counters for fused lane
    /// decode ([`LaneTraffic`]). Backends without lane fusion report
    /// zeros (the default).
    fn lane_traffic(&self) -> LaneTraffic {
        LaneTraffic::default()
    }

    /// KV-cache capacity in positions.
    fn max_seq(&self) -> usize;

    /// Number of pipeline stages.
    fn n_stages(&self) -> usize;

    /// The resident exit policy ([`ExitPolicy`]) early-exit checks run
    /// under. Sessions consult [`ExitPolicy::may_exit`] for the forced
    /// full-pass bookkeeping; the per-head decisions happen inside the
    /// engine's window pass.
    fn exit_policy(&self) -> &ExitPolicy;

    /// Whether early-exited tokens leave deep-layer KV entries missing
    /// that the session must track and heal (KV recomputation). Backends
    /// that back-fill in band (the pipelined engine) return false and
    /// always decode width-1 windows.
    fn tracks_deficit(&self) -> bool;

    /// How many sessions may be live on this backend at once.
    fn max_live_sessions(&self) -> usize;

    /// Capability flag for the prefix KV cache
    /// ([`crate::inference::prefix_cache`]): whether this backend's
    /// per-session KV state can be copied to host snapshots and rebuilt
    /// from them. Both engines support it — the sequential engine's
    /// sessions own their caches outright, and the pipelined engine
    /// reads its per-stage session slots over the chain's
    /// quiesce/snapshot protocol and rebuilds them on open.
    fn supports_cache_snapshots(&self) -> bool;

    /// Copy a session's KV caches to host tensors, one per stage,
    /// sliced along the position axis to the first `positions` entries
    /// (bytes-accurate snapshots: a short prompt's snapshot is small,
    /// whatever the cache capacity). Errors on backends where
    /// [`supports_cache_snapshots`] is false.
    ///
    /// [`supports_cache_snapshots`]: DecodeBackend::supports_cache_snapshots
    fn snapshot_caches(
        &mut self,
        caches: &SessionCaches,
        positions: usize,
    ) -> Result<Vec<HostTensor>>;

    /// Rebuild per-session caches from a host snapshot taken by
    /// [`snapshot_caches`] on a same-shaped engine, zero-padding
    /// position-sliced snapshots back to the cache capacity. Errors on
    /// backends where [`supports_cache_snapshots`] is false.
    ///
    /// [`snapshot_caches`]: DecodeBackend::snapshot_caches
    /// [`supports_cache_snapshots`]: DecodeBackend::supports_cache_snapshots
    fn restore_caches(
        &mut self,
        snapshot: &[HostTensor],
    ) -> Result<SessionCaches>;

    /// Release a session's backend-side decode state. Backends with
    /// engine-resident state (the pipelined engine's per-stage session
    /// slots) free it here; for backends whose state lives in the
    /// `caches` handle itself, dropping the handle is enough and this is
    /// a no-op (the default).
    fn release_caches(&mut self, caches: &SessionCaches) -> Result<()> {
        let _ = caches;
        Ok(())
    }
}

/// Why a session finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DoneReason {
    /// A stop token (EOS/BOS) was emitted.
    Stop,
    /// The `max_new` token budget is exhausted.
    Budget,
    /// The KV cache has no room for another position.
    CacheFull,
}

/// Result of one [`DecodeSession::step`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepEvent {
    /// One token was emitted at `exit_layer`; `done` is set when this
    /// token ends the session (stop token or last of the budget).
    Token {
        token: i32,
        exit_layer: usize,
        done: Option<DoneReason>,
    },
    /// The session ended without emitting a token this step (budget or
    /// capacity exhausted before decoding). Also returned by every call
    /// after the session is done.
    Finished(DoneReason),
}

/// Resumable state of one generation request.
///
/// The session does not borrow its backend; every call takes it
/// explicitly, so a pool worker can hold many sessions beside one engine
/// and round-robin [`DecodeSession::step`] across them.
pub struct DecodeSession {
    tokens: Vec<i32>,
    max_new: usize,
    /// Built lazily during prefill: a prefix-cache hit *becomes* the
    /// session caches directly, so a restored admission never pays the
    /// redundant zeroed [`DecodeBackend::fresh_caches`] build. `Some`
    /// for every prefilled session that is not already done.
    caches: Option<SessionCaches>,
    /// Trailing positions healed by fewer than all stages (KV
    /// recomputation backends only).
    deficit: usize,
    stats: ExitStats,
    generated: Vec<i32>,
    done: Option<DoneReason>,
    prefilled: bool,
    /// Prefix-cache snapshot this session restored from, held pinned for
    /// the session's lifetime so the entry stays resident while in use.
    pin: Option<PinnedSnapshot>,
    started: Instant,
    seconds: f64,
}

/// Result of [`DecodeSession::prefill_with_cache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CachedPrefill {
    /// Leading token positions matched by the restored snapshot (0 on a
    /// miss or when the cache was not consulted).
    pub cached_tokens: usize,
    /// Prefill positions actually computed after the restore.
    pub prefilled_positions: usize,
    /// Prefill positions skipped thanks to the restore.
    pub saved_positions: usize,
}

impl DecodeSession {
    /// Build a session for `prompt` (token ids; BOS prepended), clamping
    /// `max_new` to the KV-cache capacity. Errors when the prompt itself
    /// does not fit.
    pub fn new(
        backend: &mut dyn DecodeBackend,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<DecodeSession> {
        let tokens = prompt_tokens(prompt, max_new);
        let max_new = clamp_max_new(tokens.len(), max_new, backend.max_seq())?;
        Ok(DecodeSession {
            tokens,
            max_new,
            // Deferred to prefill: a prefix-cache restore supplies the
            // caches itself, and building fresh ones here would waste a
            // full zeroed-cache allocation on every hit.
            caches: None,
            deficit: 0,
            stats: ExitStats::default(),
            generated: Vec::new(),
            done: if max_new == 0 { Some(DoneReason::Budget) } else { None },
            prefilled: false,
            pin: None,
            started: Instant::now(),
            seconds: 0.0,
        })
    }

    /// [`DecodeSession::new`] over byte-tokenised text.
    pub fn new_text(
        backend: &mut dyn DecodeBackend,
        prompt: &str,
        max_new: usize,
    ) -> Result<DecodeSession> {
        let ids = crate::data::tokenizer::ByteTokenizer.encode(prompt);
        DecodeSession::new(backend, &ids, max_new)
    }

    /// Prefill positions `[0, L-1)` of the prompt: shared greedy chunking
    /// over the available widths, no exit checks. Idempotent; a no-op for
    /// sessions that are already done (zero-budget prompts).
    pub fn prefill(&mut self, backend: &mut dyn DecodeBackend) -> Result<()> {
        self.prefill_inner(backend, None).map(|_| ())
    }

    /// [`DecodeSession::prefill`] through a shared-prefix KV-cache store:
    /// look up the longest cached prefix of the prompt, restore its
    /// snapshot, and prefill only the remainder. Falls back to a plain
    /// prefill (without consulting the store) on backends that do not
    /// support cache snapshots, and on a miss.
    ///
    /// The restored snapshot stays pinned in the store for this session's
    /// lifetime. Restored KV entries are trusted only up to the
    /// snapshot's healed frontier — its recompute-deficit tail (Section 4
    /// / Appendix D.3) is re-run with full-stage passes along with the
    /// suffix, so early-exit KV healing stays correct across the restore.
    pub fn prefill_with_cache(
        &mut self,
        backend: &mut dyn DecodeBackend,
        store: &dyn SnapshotSource,
    ) -> Result<CachedPrefill> {
        self.prefill_inner(backend, Some(store))
    }

    fn prefill_inner(
        &mut self,
        backend: &mut dyn DecodeBackend,
        store: Option<&dyn SnapshotSource>,
    ) -> Result<CachedPrefill> {
        let mut report = CachedPrefill::default();
        if self.prefilled || self.done.is_some() {
            self.prefilled = true;
            return Ok(report);
        }
        let l = self.tokens.len();
        let mut start = 0usize;
        let store = store.filter(|_| backend.supports_cache_snapshots());
        if let Some(store) = store {
            if let Some(hit) = store.lookup(&self.tokens) {
                let snap = hit.snapshot.snapshot();
                // Restoring is best-effort: the cache is an optimization,
                // so a failed restore degrades to a full prefill over
                // fresh caches instead of failing a request that would
                // have served fine uncached.
                match backend.restore_caches(&snap.stage_caches) {
                    Ok(caches) => {
                        // The restored caches *are* the session caches —
                        // a hit skips the zeroed fresh-cache build
                        // entirely (see the `caches` field docs).
                        self.caches = Some(caches);
                        // Trust restored positions only below the
                        // snapshot's healed frontier and the common
                        // prefix; everything from `start` on gets a
                        // full-stage pass below, which also heals any
                        // deficit tail the snapshot carried.
                        start = hit
                            .matched
                            .min(snap.healed_frontier())
                            .min(l - 1);
                        report.cached_tokens = hit.matched;
                        self.pin = Some(hit.snapshot);
                    }
                    Err(e) => eprintln!(
                        "[prefix-cache] snapshot restore failed; falling \
                         back to full prefill: {e:#}"
                    ),
                }
            }
        }
        if self.caches.is_none() {
            self.caches = Some(backend.fresh_caches()?);
        }
        let caches = self.caches.as_mut().unwrap();
        let chunks =
            prefill_chunks_from(backend.decode_widths(), start, l)?;
        for (pos, w) in chunks {
            backend.run_window(caches, &self.tokens, pos, w, false, false)?;
        }
        // Every untrusted position just ran all stages, so the session
        // starts decoding with a clean deficit regardless of what the
        // snapshot carried.
        self.deficit = 0;
        self.prefilled = true;
        report.prefilled_positions = (l - 1).saturating_sub(start);
        report.saved_positions = start;
        if let Some(store) = store {
            if report.saved_positions > 0 {
                store.record_saved(report.saved_positions as u64);
            }
        }
        Ok(report)
    }

    /// Capture the post-prefill state as an immutable snapshot for a
    /// [`PrefixCacheStore`]. Only valid between [`prefill`] and the first
    /// [`step`] — the one point where "KV entries for the whole token
    /// buffer, deficit included" is a well-defined prefix state.
    ///
    /// [`PrefixCacheStore`]: super::prefix_cache::PrefixCacheStore
    /// [`prefill`]: DecodeSession::prefill
    /// [`step`]: DecodeSession::step
    pub fn prefix_snapshot(
        &self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<CacheSnapshot> {
        ensure!(
            self.prefilled && self.done.is_none() && self.generated.is_empty(),
            "prefix snapshots are only valid after prefill and before \
             decoding"
        );
        // Prefilled and not done implies the prefill pass built (or
        // restored) the session caches.
        let caches = self.caches.as_ref().expect("prefilled session caches");
        // Prefill computed KV for positions [0, l-1); slice the host
        // copy there instead of hauling the full fixed-shape cache
        // (bytes-accurate budgeting — the store charges what is held).
        let positions = self.tokens.len().saturating_sub(1);
        Ok(CacheSnapshot {
            tokens: self.tokens.clone(),
            stage_caches: backend.snapshot_caches(caches, positions)?,
            deficit: self.deficit,
        })
    }

    /// Capture the end-of-turn state — prompt ⧺ generated, KV entries
    /// included — as an immutable snapshot keyed under the full token
    /// sequence, so a follow-up turn whose prompt extends this
    /// conversation's history restores the whole thing and prefills only
    /// its own new text. The decode-time counterpart of
    /// [`prefix_snapshot`]: only valid once the session is done but
    /// before [`close`] releases its caches.
    ///
    /// The recompute deficit is carried verbatim; a restorer re-runs the
    /// unhealed tail via the snapshot's healed frontier, exactly as for
    /// prefill-time snapshots.
    ///
    /// [`prefix_snapshot`]: DecodeSession::prefix_snapshot
    /// [`close`]: DecodeSession::close
    pub fn finish_snapshot(
        &self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<CacheSnapshot> {
        ensure!(
            self.prefilled && self.done.is_some(),
            "finish snapshots are only valid once decoding completes"
        );
        let caches = self
            .caches
            .as_ref()
            .context("finish snapshot after session caches were released")?;
        // Same slice rule as `prefix_snapshot` / `park`: KV entries
        // exist for positions [0, len-1) — the last token (often the
        // stop token) was emitted, never prefilled.
        let positions = self.tokens.len().saturating_sub(1);
        Ok(CacheSnapshot {
            tokens: self.tokens.clone(),
            stage_caches: backend.snapshot_caches(caches, positions)?,
            deficit: self.deficit,
        })
    }

    /// Capture a decode-time micro-checkpoint: a [`ParkedSession`]
    /// snapshot of this *live* session, without consuming it or
    /// touching its backend-side state — the session keeps decoding
    /// afterwards. The self-healing serving layer stores these at a
    /// fixed token cadence so a later engine fault can
    /// [`ParkedSession::resume`] the session and re-decode only the
    /// tail since the checkpoint (deterministic decoding makes the
    /// re-decoded tail token-identical, so recovery is invisible to the
    /// stream).
    ///
    /// Same validity rules as [`park`]: a prefilled, unfinished session
    /// on a backend whose [`DecodeBackend::supports_cache_snapshots`]
    /// is true. Both engines' snapshot paths are non-destructive (the
    /// pipelined chain's quiesce/snapshot protocol keeps the stage
    /// slots), which is what makes a live-session snapshot safe.
    ///
    /// [`park`]: DecodeSession::park
    pub fn checkpoint(
        &self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<ParkedSession> {
        ensure!(
            self.prefilled && self.done.is_none(),
            "checkpoints are only valid on a prefilled, unfinished \
             session"
        );
        ensure!(
            backend.supports_cache_snapshots(),
            "checkpoint on a backend without cache snapshots"
        );
        let caches = self
            .caches
            .as_ref()
            .context("checkpointing a session without caches")?;
        // Same slice rule as `park`: KV entries exist for [0, len-1).
        let positions = self.tokens.len().saturating_sub(1);
        Ok(ParkedSession {
            tokens: self.tokens.clone(),
            max_new: self.max_new,
            deficit: self.deficit,
            stats: self.stats.clone(),
            generated: self.generated.clone(),
            stage_caches: backend.snapshot_caches(caches, positions)?,
            started: self.started,
        })
    }

    /// Park a mid-decode session: copy its per-stage KV caches to host
    /// tensors, release the backend-side state, and return a plain-data
    /// [`ParkedSession`] that can cross threads and later
    /// [`ParkedSession::resume`] on either engine — the preemption
    /// primitive of the serving control plane.
    ///
    /// Consumes the session; on error the backend state has still been
    /// released (best-effort), so a failed park surfaces as a lost
    /// request, never a leaked session slot. Any prefix-cache pin is
    /// dropped — the snapshot is self-contained.
    ///
    /// Only valid on a prefilled, unfinished session of a backend whose
    /// [`DecodeBackend::supports_cache_snapshots`] is true.
    pub fn park(
        mut self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<ParkedSession> {
        ensure!(
            self.prefilled && self.done.is_none(),
            "park is only valid on a prefilled, unfinished session"
        );
        let caches = self
            .caches
            .take()
            .context("parking a session without caches")?;
        // KV entries exist for positions [0, len-1): prefill computes
        // [0, l-1) and every step writes position n = len-1 before
        // pushing its token (same slice rule as `prefix_snapshot`).
        let positions = self.tokens.len().saturating_sub(1);
        let snap = backend.snapshot_caches(&caches, positions);
        // Win or lose, free the backend-side state: a failed snapshot
        // must not leak a pipelined stage slot or a resident lane.
        let _ = backend.release_caches(&caches);
        let stage_caches = snap.context("parking session: cache snapshot")?;
        Ok(ParkedSession {
            tokens: std::mem::take(&mut self.tokens),
            max_new: self.max_new,
            deficit: self.deficit,
            stats: std::mem::take(&mut self.stats),
            generated: std::mem::take(&mut self.generated),
            stage_caches,
            started: self.started,
        })
    }

    /// Length of the prompt token buffer (BOS included).
    pub fn prompt_len(&self) -> usize {
        self.tokens.len() - self.generated.len()
    }

    /// Token key of the prefix-cache snapshot this session restored from
    /// (held pinned for the session's lifetime), if any.
    pub fn pinned_prefix(&self) -> Option<&[i32]> {
        self.pin.as_ref().map(|p| p.tokens())
    }

    /// Decode one token. Returns [`StepEvent::Finished`] (idempotently)
    /// once the session is done.
    pub fn step(
        &mut self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<StepEvent> {
        if let Some(r) = self.done {
            return Ok(StepEvent::Finished(r));
        }
        ensure!(self.prefilled, "DecodeSession::step before prefill");
        if self.generated.len() >= self.max_new {
            return Ok(StepEvent::Finished(self.finish(DoneReason::Budget)));
        }
        let n = self.tokens.len() - 1; // current position (has a token)
        if n + 1 >= backend.max_seq() {
            return Ok(StepEvent::Finished(self.finish(DoneReason::CacheFull)));
        }

        let p = backend.n_stages();
        let (width, allow_exit) = if backend.tracks_deficit() {
            let need = self.deficit + 1;
            let width = pick_width(backend.decode_widths(), need, n)
                .with_context(|| {
                    format!("no decode width fits need {need} at pos {n}")
                })?;
            // Exit eligibility: after exiting, the deficit becomes `need`,
            // so the *next* pass needs a window of need + 1 — suspend
            // early exits when that would not fit (the paper's forced
            // full-model pass). Policies that can never exit
            // ([`ExitPolicy::may_exit`] false — `Never`, `Confidence` at
            // 1.0) skip the check and the forced-full accounting, exactly
            // like the old scalar threshold at 1.0.
            let may_exit = backend.exit_policy().may_exit();
            let eligible = may_exit
                && pick_width(backend.decode_widths(), need + 1, n + 1)
                    .is_some();
            if !eligible && may_exit {
                self.stats.forced_full += 1;
            }
            (width, eligible)
        } else {
            // In-band back-fill: no deficit, one position per pass.
            (1, true)
        };
        let pos0 = n + 1 - width;
        let caches = self
            .caches
            .as_mut()
            .expect("prefilled session has caches");
        let out = backend.run_window(
            caches,
            &self.tokens,
            pos0,
            width,
            allow_exit,
            true,
        )?;
        Ok(self.absorb(out, p, backend.tracks_deficit()))
    }

    /// Fold one emitted window outcome into the session: deficit
    /// bookkeeping, stats, token buffers, and the stop/budget check —
    /// the shared tail of [`step`] and [`step_fused`].
    ///
    /// [`step`]: DecodeSession::step
    /// [`step_fused`]: DecodeSession::step_fused
    fn absorb(
        &mut self,
        out: WindowOutcome,
        n_stages: usize,
        tracks_deficit: bool,
    ) -> StepEvent {
        if tracks_deficit {
            self.deficit =
                if out.stages_run == n_stages { 0 } else { self.deficit + 1 };
        }
        self.stats.record(out.exit_layer);
        self.tokens.push(out.token);
        self.generated.push(out.token);
        let done = if is_stop_token(out.token) {
            Some(self.finish(DoneReason::Stop))
        } else if self.generated.len() >= self.max_new {
            Some(self.finish(DoneReason::Budget))
        } else {
            None
        };
        StepEvent::Token { token: out.token, exit_layer: out.exit_layer, done }
    }

    /// Whether this session may join a fused lane group right now: it
    /// must be mid-decode (prefilled, not done, budget and KV capacity
    /// left), hold its own caches, and carry **no recompute deficit** —
    /// a session whose healing window exceeds width 1 takes the solo
    /// windowed path until the deficit clears, so fused lanes are always
    /// plain width-1 windows.
    pub fn fusable(&self, backend: &dyn DecodeBackend) -> bool {
        self.prefilled
            && self.done.is_none()
            && self.deficit == 0
            && self.generated.len() < self.max_new
            && self.tokens.len() < backend.max_seq()
            && self.caches.is_some()
    }

    /// Decode one token for *every* session in a single fused pass
    /// ([`DecodeBackend::run_lanes`]) — the compute-batching hot path of
    /// the serving pool. All sessions must be [`fusable`] and share the
    /// backend's resident exit policy (the pool groups by policy), and
    /// `sessions.len()` must be one of [`DecodeBackend::decode_lanes`].
    ///
    /// Per-lane bookkeeping (exit eligibility, the forced-full pass
    /// accounting, deficit updates) mirrors [`step`] exactly, so a
    /// session stepped through fused rounds and one stepped solo produce
    /// identical streams.
    ///
    /// [`fusable`]: DecodeSession::fusable
    /// [`step`]: DecodeSession::step
    pub fn step_fused(
        backend: &mut dyn DecodeBackend,
        sessions: &mut [&mut DecodeSession],
    ) -> Result<FusedStep> {
        let p = backend.n_stages();
        let widths = backend.decode_widths().to_vec();
        let may_exit = backend.exit_policy().may_exit();
        let tracks_deficit = backend.tracks_deficit();
        for sess in sessions.iter() {
            ensure!(
                sess.fusable(&*backend),
                "step_fused over a session that is not fusable"
            );
        }
        let mut slots: Vec<LaneSlot<'_>> =
            Vec::with_capacity(sessions.len());
        let mut forced: Vec<bool> = Vec::with_capacity(sessions.len());
        for sess in sessions.iter_mut() {
            let s = &mut **sess;
            let n = s.tokens.len() - 1; // current position (has a token)
            // Exit eligibility mirrors the solo step exactly. Deficit
            // trackers at deficit 0: after exiting, the next pass needs
            // a window of width 2 — suspend early exits when that would
            // not fit (the forced full-model pass), with the same
            // accounting. In-band back-fill backends never suspend.
            let eligible = if tracks_deficit {
                may_exit && pick_width(&widths, 2, n + 1).is_some()
            } else {
                true
            };
            // Forced-full accounting lands only once the fused pass
            // succeeds: a failed pass is retried on the solo path,
            // which does its own accounting — no double count.
            forced.push(tracks_deficit && may_exit && !eligible);
            let token = s.tokens[n];
            let caches =
                s.caches.as_mut().expect("fusable session has caches");
            slots.push(LaneSlot {
                caches,
                token,
                pos: n,
                allow_exit: eligible,
            });
        }
        let outs = backend.run_lanes(&mut slots)?;
        drop(slots);
        ensure!(
            outs.len() == sessions.len(),
            "run_lanes returned {} outcomes for {} lanes",
            outs.len(),
            sessions.len()
        );
        let deepest = outs.iter().map(|o| o.stages_run).max().unwrap_or(p);
        let events = sessions
            .iter_mut()
            .zip(outs.iter().zip(&forced))
            .map(|(s, (&o, &f))| {
                if f {
                    s.stats.forced_full += 1;
                }
                s.absorb(o, p, tracks_deficit)
            })
            .collect();
        Ok(FusedStep { events, stages_skipped: p.saturating_sub(deepest) })
    }

    /// Decode one token for *every* session by interleaving their
    /// width-1 windows down the backend's stage chain
    /// ([`DecodeBackend::submit_window`] / [`collect_window`]): all
    /// windows are submitted before any token is collected, so session
    /// B's shallow-stage forward overlaps session A's deep-stage KV
    /// back-fill — the pipeline-bubble filling the pool's interleaved
    /// rounds are built on. All sessions must be [`fusable`], and the
    /// per-session bookkeeping is the shared [`step`] tail (in-band
    /// back-fill backends never suspend exits or track deficits), so an
    /// interleaved stream is identical to a solo-stepped one.
    ///
    /// Returns one [`StepEvent`] per session, in session order.
    ///
    /// [`collect_window`]: DecodeBackend::collect_window
    /// [`fusable`]: DecodeSession::fusable
    /// [`step`]: DecodeSession::step
    pub fn step_interleaved(
        backend: &mut dyn DecodeBackend,
        sessions: &mut [&mut DecodeSession],
    ) -> Result<Vec<StepEvent>> {
        ensure!(
            backend.interleaves_windows() && !backend.tracks_deficit(),
            "step_interleaved needs an in-band back-fill backend that \
             interleaves windows"
        );
        for sess in sessions.iter() {
            ensure!(
                sess.fusable(&*backend),
                "step_interleaved over a session that is not fusable"
            );
        }
        let p = backend.n_stages();
        // Submit every session's window before collecting any token:
        // the chain starts session i+1's shallow stages while session i
        // occupies the deeper ones.
        for sess in sessions.iter_mut() {
            let s = &mut **sess;
            let n = s.tokens.len() - 1; // current position (has a token)
            let caches =
                s.caches.as_mut().expect("fusable session has caches");
            backend.submit_window(caches, &s.tokens, n, 1, true)?;
        }
        // Collect in the same order, folding each token in with the
        // shared solo bookkeeping.
        let mut events = Vec::with_capacity(sessions.len());
        for sess in sessions.iter_mut() {
            let s = &mut **sess;
            let caches =
                s.caches.as_mut().expect("fusable session has caches");
            let out = backend.collect_window(caches)?;
            events.push(s.absorb(out, p, false));
        }
        Ok(events)
    }

    /// Prefill, then step to completion — the serial path
    /// `generate_tokens` collapses to.
    pub fn drain(
        &mut self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<GenOutput> {
        self.prefill(backend)?;
        while !self.is_done() {
            self.step(backend)?;
        }
        self.close(backend);
        Ok(self.output())
    }

    /// Release the session's backend-side decode state
    /// ([`DecodeBackend::release_caches`]: per-stage KV slots on the
    /// pipelined engine; a no-op for backends whose state lives in the
    /// caches handle). Idempotent, and best-effort: a close can only
    /// fail on an engine whose stage chain is already down, where there
    /// is no state left to free.
    pub fn close(&mut self, backend: &mut dyn DecodeBackend) {
        if let Some(c) = self.caches.take() {
            let _ = backend.release_caches(&c);
        }
    }

    fn finish(&mut self, reason: DoneReason) -> DoneReason {
        if self.done.is_none() {
            self.done = Some(reason);
            self.seconds = self.started.elapsed().as_secs_f64();
        }
        reason
    }

    pub fn is_done(&self) -> bool {
        self.done.is_some()
    }

    pub fn done_reason(&self) -> Option<DoneReason> {
        self.done
    }

    /// Tokens generated so far.
    pub fn generated(&self) -> &[i32] {
        &self.generated
    }

    /// Snapshot of the generation result (final once [`is_done`] is
    /// true). `seconds` is wall time since the session was created — under
    /// interleaved serving it includes time spent stepping other sessions.
    ///
    /// [`is_done`]: DecodeSession::is_done
    pub fn output(&self) -> GenOutput {
        GenOutput {
            text: detokenize(&self.generated),
            tokens: self.generated.clone(),
            seconds: if self.done.is_some() {
                self.seconds
            } else {
                self.started.elapsed().as_secs_f64()
            },
            stats: self.stats.clone(),
        }
    }
}

/// A mid-decode session parked to host memory by [`DecodeSession::park`]:
/// the token buffer, recompute deficit, per-exit stats, and a per-stage
/// host snapshot of the KV caches — plain data with no backend handles,
/// so it is `Send` (unlike a live session, whose caches hold `!Send`
/// device literals) and can sit in a shared park store until a worker
/// resumes it.
///
/// Resuming restores the caches byte-for-byte and the deficit **verbatim**
/// (no healing): healing the deficit tail with full-depth passes would
/// change subsequent exit-eligibility decisions and diverge the stream
/// from an uninterrupted run. The consequence is that a deficit-carrying
/// snapshot can only resume on a deficit-tracking backend; deficit-free
/// snapshots (including everything the pipelined engine parks) resume on
/// either engine.
///
/// `Clone` is deliberate: the self-healing layer's checkpoint store
/// hands out *copies* for recovery attempts, keeping the stored
/// snapshot intact in case the attempt itself fails.
#[derive(Clone)]
pub struct ParkedSession {
    tokens: Vec<i32>,
    max_new: usize,
    deficit: usize,
    stats: ExitStats,
    generated: Vec<i32>,
    stage_caches: Vec<HostTensor>,
    started: Instant,
}

// The whole point of parking is crossing the pool's worker threads;
// assert it at compile time so a `!Send` field can never sneak in.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<ParkedSession>();
};

impl ParkedSession {
    /// Rebuild a live [`DecodeSession`] from this snapshot on `backend`.
    ///
    /// The caller must re-apply the session's exit policy to the backend
    /// *before* resuming (mirrors admission: the pipelined engine
    /// captures the resident policy at `open_session`).
    pub fn resume(
        self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<DecodeSession> {
        ensure!(
            backend.supports_cache_snapshots(),
            "resume on a backend without cache snapshots"
        );
        ensure!(
            self.deficit == 0 || backend.tracks_deficit(),
            "a deficit-carrying parked session ({} unhealed positions) \
             can only resume on a deficit-tracking backend",
            self.deficit
        );
        let caches = backend
            .restore_caches(&self.stage_caches)
            .context("resuming parked session: cache restore")?;
        Ok(DecodeSession {
            tokens: self.tokens,
            max_new: self.max_new,
            caches: Some(caches),
            deficit: self.deficit,
            stats: self.stats,
            generated: self.generated,
            done: None,
            prefilled: true,
            pin: None,
            started: self.started,
            seconds: 0.0,
        })
    }

    /// Tokens generated before the session was parked.
    pub fn generated(&self) -> &[i32] {
        &self.generated
    }

    /// Total token-buffer length (prompt + generated).
    pub fn buffered_len(&self) -> usize {
        self.tokens.len()
    }

    /// Bytes held by the host cache snapshot.
    pub fn snapshot_bytes(&self) -> usize {
        self.stage_caches
            .iter()
            .map(|t| t.data.len() * std::mem::size_of::<f32>())
            .sum()
    }

    /// Full token sequence (prompt ⧺ generated) the snapshot covers —
    /// the position a resumed session continues from.
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Test-only stub with empty caches, for exercising park-store
    /// bookkeeping without an engine.
    #[cfg(test)]
    pub(crate) fn stub(tokens: Vec<i32>) -> ParkedSession {
        ParkedSession {
            tokens,
            max_new: 8,
            deficit: 0,
            stats: ExitStats::default(),
            generated: Vec::new(),
            stage_caches: Vec::new(),
            started: Instant::now(),
        }
    }
}
