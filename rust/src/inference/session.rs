//! Resumable per-token decode sessions — the step-based core of both
//! inference engines and the serving layer.
//!
//! A [`DecodeSession`] owns everything that used to live on the stack of a
//! monolithic `generate_tokens` loop: the token buffer, per-session KV
//! caches, the recomputation deficit, per-exit statistics, and the
//! stop/budget/capacity checks. It advances one token per [`step`] call,
//! so a caller can interleave many sessions over one engine (continuous
//! batching), stream tokens as they are emitted, or simply [`drain`] to
//! reproduce the old blocking behaviour.
//!
//! The engine side of the split is [`DecodeBackend`]: the minimal surface
//! a session needs — fresh caches, one window pass, and static model
//! facts. `SequentialEngine` implements it with host-side per-session
//! caches (KV recomputation, Section 4 / Appendix D.3), so arbitrarily
//! many of its sessions can be live at once; `PipelinedEngine` keeps
//! decode state in its stage threads and therefore reports a single live
//! session ([`DecodeBackend::max_live_sessions`]).
//!
//! [`step`]: DecodeSession::step
//! [`drain`]: DecodeSession::drain

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::common::{
    clamp_max_new, detokenize, is_stop_token, pick_width, prefill_chunks,
    prompt_tokens, ExitStats, GenOutput,
};

/// Per-session decode state handed out by a backend.
pub struct SessionCaches {
    /// Host-side per-session KV caches (the sequential engine: one
    /// literal per stage). Backends whose decode state lives elsewhere
    /// (the pipelined engine's stage threads) leave this empty.
    pub caches: Vec<xla::Literal>,
    /// Generation stamp for backends with engine-resident state: the
    /// pipelined engine bumps its counter on every
    /// [`DecodeBackend::fresh_caches`] (which resets the stage chain)
    /// and refuses window passes from a stale generation — starting a
    /// second session on such a backend invalidates the first with an
    /// error instead of silently decoding against reset caches.
    /// Backends with fully session-owned state ignore it.
    pub generation: u64,
}

/// Result of one decode window pass.
#[derive(Debug, Clone, Copy)]
pub struct WindowOutcome {
    /// Emitted token (-1 for pure prefill passes).
    pub token: i32,
    /// Exit layer the token came from (final layer when no early exit).
    pub exit_layer: usize,
    /// Stages the pass ran; a pass covering all stages clears the
    /// recomputation deficit.
    pub stages_run: usize,
}

/// The engine surface a [`DecodeSession`] drives. Both engines implement
/// this, which keeps every caller — `generate_tokens`, the serving pool,
/// the eval harness — on the one audited decode path.
pub trait DecodeBackend {
    /// Fresh per-session caches; called once when a session is created.
    /// Backends with engine-resident state use this to reset it.
    fn fresh_caches(&mut self) -> Result<SessionCaches>;

    /// Run one decode window over `tokens[pos0..pos0 + width]`.
    ///
    /// `allow_exit` gates early-exit checks (false during prefill and
    /// forced full-model passes); `emit` is false for pure prefill
    /// passes, which run all stages and emit no token.
    #[allow(clippy::too_many_arguments)]
    fn run_window(
        &mut self,
        caches: &mut SessionCaches,
        tokens: &[i32],
        pos0: usize,
        width: usize,
        allow_exit: bool,
        emit: bool,
    ) -> Result<WindowOutcome>;

    /// Decode window widths available in the manifest.
    fn decode_widths(&self) -> &[usize];

    /// KV-cache capacity in positions.
    fn max_seq(&self) -> usize;

    /// Number of pipeline stages.
    fn n_stages(&self) -> usize;

    /// Current confidence threshold for early exits.
    fn exit_threshold(&self) -> f32;

    /// Whether early-exited tokens leave deep-layer KV entries missing
    /// that the session must track and heal (KV recomputation). Backends
    /// that back-fill in band (the pipelined engine) return false and
    /// always decode width-1 windows.
    fn tracks_deficit(&self) -> bool;

    /// How many sessions may be live on this backend at once.
    fn max_live_sessions(&self) -> usize;
}

/// Why a session finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DoneReason {
    /// A stop token (EOS/BOS) was emitted.
    Stop,
    /// The `max_new` token budget is exhausted.
    Budget,
    /// The KV cache has no room for another position.
    CacheFull,
}

/// Result of one [`DecodeSession::step`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepEvent {
    /// One token was emitted at `exit_layer`; `done` is set when this
    /// token ends the session (stop token or last of the budget).
    Token {
        token: i32,
        exit_layer: usize,
        done: Option<DoneReason>,
    },
    /// The session ended without emitting a token this step (budget or
    /// capacity exhausted before decoding). Also returned by every call
    /// after the session is done.
    Finished(DoneReason),
}

/// Resumable state of one generation request.
///
/// The session does not borrow its backend; every call takes it
/// explicitly, so a pool worker can hold many sessions beside one engine
/// and round-robin [`DecodeSession::step`] across them.
pub struct DecodeSession {
    tokens: Vec<i32>,
    max_new: usize,
    caches: SessionCaches,
    /// Trailing positions healed by fewer than all stages (KV
    /// recomputation backends only).
    deficit: usize,
    stats: ExitStats,
    generated: Vec<i32>,
    done: Option<DoneReason>,
    prefilled: bool,
    started: Instant,
    seconds: f64,
}

impl DecodeSession {
    /// Build a session for `prompt` (token ids; BOS prepended), clamping
    /// `max_new` to the KV-cache capacity. Errors when the prompt itself
    /// does not fit.
    pub fn new(
        backend: &mut dyn DecodeBackend,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<DecodeSession> {
        let tokens = prompt_tokens(prompt, max_new);
        let max_new = clamp_max_new(tokens.len(), max_new, backend.max_seq())?;
        let caches = backend.fresh_caches()?;
        Ok(DecodeSession {
            tokens,
            max_new,
            caches,
            deficit: 0,
            stats: ExitStats::default(),
            generated: Vec::new(),
            done: if max_new == 0 { Some(DoneReason::Budget) } else { None },
            prefilled: false,
            started: Instant::now(),
            seconds: 0.0,
        })
    }

    /// [`DecodeSession::new`] over byte-tokenised text.
    pub fn new_text(
        backend: &mut dyn DecodeBackend,
        prompt: &str,
        max_new: usize,
    ) -> Result<DecodeSession> {
        let ids = crate::data::tokenizer::ByteTokenizer.encode(prompt);
        DecodeSession::new(backend, &ids, max_new)
    }

    /// Prefill positions `[0, L-1)` of the prompt: shared greedy chunking
    /// over the available widths, no exit checks. Idempotent; a no-op for
    /// sessions that are already done (zero-budget prompts).
    pub fn prefill(&mut self, backend: &mut dyn DecodeBackend) -> Result<()> {
        if self.prefilled || self.done.is_some() {
            self.prefilled = true;
            return Ok(());
        }
        let chunks =
            prefill_chunks(backend.decode_widths(), self.tokens.len())?;
        for (pos, w) in chunks {
            backend.run_window(
                &mut self.caches,
                &self.tokens,
                pos,
                w,
                false,
                false,
            )?;
        }
        self.prefilled = true;
        Ok(())
    }

    /// Decode one token. Returns [`StepEvent::Finished`] (idempotently)
    /// once the session is done.
    pub fn step(
        &mut self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<StepEvent> {
        if let Some(r) = self.done {
            return Ok(StepEvent::Finished(r));
        }
        ensure!(self.prefilled, "DecodeSession::step before prefill");
        if self.generated.len() >= self.max_new {
            return Ok(StepEvent::Finished(self.finish(DoneReason::Budget)));
        }
        let n = self.tokens.len() - 1; // current position (has a token)
        if n + 1 >= backend.max_seq() {
            return Ok(StepEvent::Finished(self.finish(DoneReason::CacheFull)));
        }

        let p = backend.n_stages();
        let (width, allow_exit) = if backend.tracks_deficit() {
            let need = self.deficit + 1;
            let width = pick_width(backend.decode_widths(), need, n)
                .with_context(|| {
                    format!("no decode width fits need {need} at pos {n}")
                })?;
            // Exit eligibility: after exiting, the deficit becomes `need`,
            // so the *next* pass needs a window of need + 1 — suspend
            // early exits when that would not fit (the paper's forced
            // full-model pass).
            let eligible = backend.exit_threshold() < 1.0
                && pick_width(backend.decode_widths(), need + 1, n + 1)
                    .is_some();
            if !eligible && backend.exit_threshold() < 1.0 {
                self.stats.forced_full += 1;
            }
            (width, eligible)
        } else {
            // In-band back-fill: no deficit, one position per pass.
            (1, true)
        };
        let pos0 = n + 1 - width;
        let out = backend.run_window(
            &mut self.caches,
            &self.tokens,
            pos0,
            width,
            allow_exit,
            true,
        )?;
        if backend.tracks_deficit() {
            self.deficit =
                if out.stages_run == p { 0 } else { self.deficit + 1 };
        }
        self.stats.record(out.exit_layer);
        self.tokens.push(out.token);
        self.generated.push(out.token);
        let done = if is_stop_token(out.token) {
            Some(self.finish(DoneReason::Stop))
        } else if self.generated.len() >= self.max_new {
            Some(self.finish(DoneReason::Budget))
        } else {
            None
        };
        Ok(StepEvent::Token { token: out.token, exit_layer: out.exit_layer, done })
    }

    /// Prefill, then step to completion — the serial path
    /// `generate_tokens` collapses to.
    pub fn drain(
        &mut self,
        backend: &mut dyn DecodeBackend,
    ) -> Result<GenOutput> {
        self.prefill(backend)?;
        while !self.is_done() {
            self.step(backend)?;
        }
        Ok(self.output())
    }

    fn finish(&mut self, reason: DoneReason) -> DoneReason {
        if self.done.is_none() {
            self.done = Some(reason);
            self.seconds = self.started.elapsed().as_secs_f64();
        }
        reason
    }

    pub fn is_done(&self) -> bool {
        self.done.is_some()
    }

    pub fn done_reason(&self) -> Option<DoneReason> {
        self.done
    }

    /// Tokens generated so far.
    pub fn generated(&self) -> &[i32] {
        &self.generated
    }

    /// Snapshot of the generation result (final once [`is_done`] is
    /// true). `seconds` is wall time since the session was created — under
    /// interleaved serving it includes time spent stepping other sessions.
    ///
    /// [`is_done`]: DecodeSession::is_done
    pub fn output(&self) -> GenOutput {
        GenOutput {
            text: detokenize(&self.generated),
            tokens: self.generated.clone(),
            seconds: if self.done.is_some() {
                self.seconds
            } else {
                self.started.elapsed().as_secs_f64()
            },
            stats: self.stats.clone(),
        }
    }
}
