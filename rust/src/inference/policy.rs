//! Pluggable exit-decision policies — the first-class API behind every
//! early-exit check in the system.
//!
//! The paper's Section 4 exit rule (exit iff max softmax probability >=
//! a scalar threshold) used to be a bare `f32` threaded through the
//! engines, sessions, serving pool, and eval harness. [`ExitPolicy`]
//! replaces that plumbing with a closed set of decision rules over a
//! per-exit [`LogitsSummary`]:
//!
//! - [`ExitPolicy::Confidence`] — the paper's rule, bit-for-bit: exit
//!   iff `top_prob >= threshold`. `threshold = 1.0` is *defined* as the
//!   full-model baseline (exits disabled entirely, exactly like the old
//!   scalar-1.0 path, including the sequential engine's forced-full-pass
//!   accounting).
//! - [`ExitPolicy::PerLayer`] — one confidence threshold per exit layer
//!   (EE-Tuning, Pan et al. 2024: exit decisions are worth tuning
//!   per-exit). Layers not listed never exit. Uniform thresholds are
//!   exactly [`ExitPolicy::Confidence`].
//! - [`ExitPolicy::TopTwoMargin`] — exit iff the probability gap between
//!   the top-1 and top-2 tokens is at least `delta` (Shan et al. 2024
//!   study margin-style exit signals).
//! - [`ExitPolicy::Entropy`] — exit iff the softmax entropy is at most
//!   `max_nats` (low entropy = confident distribution, not just a
//!   confident argmax).
//! - [`ExitPolicy::Never`] — full-model decoding regardless of layer or
//!   summary; the explicit baseline spelling.
//!
//! [`ExitPolicy::calibrated`] fits a [`ExitPolicy::PerLayer`] policy
//! from [`ProbeReport`] data (the Table-4 machinery): for every early
//! exit it picks the smallest confidence threshold whose accepted tokens
//! agree with the final exit's prediction at a target rate.
//!
//! The textual spec grammar (CLI `--policy`, round-tripped by
//! [`ExitPolicy::spec`]):
//!
//! ```text
//! never                      full-model baseline
//! confidence:0.8   |  0.8    the paper's rule (bare floats accepted)
//! per-layer:2=0.7,4=0.9      per-exit-layer confidence thresholds
//! margin:0.3                 top-2 probability margin
//! entropy:1.5                max softmax entropy in nats
//! ```

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::{argmax_prob, softmax};

use super::probe::ProbeReport;

/// What a policy tells the engine to do at one exit head.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitDecision {
    /// Emit the exit head's argmax token here.
    Exit,
    /// Keep running deeper layers.
    Continue,
}

impl ExitDecision {
    pub fn is_exit(self) -> bool {
        self == ExitDecision::Exit
    }
}

/// Per-exit softmax summary handed to [`ExitPolicy::decide`]: everything
/// any resident policy needs, computed once per head evaluation so the
/// decision itself is engine-agnostic (both engines, the probe, and
/// tests share [`summarize_logits`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LogitsSummary {
    /// Argmax token id — what would be emitted on exit.
    pub token: i32,
    /// Max softmax probability (the paper's confidence signal).
    pub top_prob: f32,
    /// Probability gap between the top-1 and top-2 tokens.
    pub margin: f32,
    /// Softmax entropy in nats.
    pub entropy_nats: f32,
}

/// Summarise one logits vector for exit decisions.
pub fn summarize_logits(logits: &[f32]) -> LogitsSummary {
    let probs = softmax(logits);
    let (idx, top) = argmax_prob(&probs);
    let mut second = 0.0f32;
    let mut entropy = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        if i != idx && p > second {
            second = p;
        }
        if p > 0.0 {
            entropy -= p * p.ln();
        }
    }
    LogitsSummary {
        token: idx as i32,
        top_prob: top,
        margin: top - second,
        entropy_nats: entropy,
    }
}

/// A pluggable early-exit decision rule. See the module docs for the
/// variants' semantics and the textual spec grammar.
#[derive(Debug, Clone, PartialEq)]
pub enum ExitPolicy {
    /// The paper's rule: exit iff `top_prob >= threshold`. `1.0` is the
    /// full-model baseline (exits disabled, [`ExitPolicy::may_exit`] is
    /// false — identical to the pre-policy scalar-threshold semantics).
    Confidence { threshold: f32 },
    /// Per-exit-layer confidence thresholds `(layer, threshold)`.
    /// Layers not listed never exit; uniform thresholds are exactly
    /// [`ExitPolicy::Confidence`].
    PerLayer { thresholds: Vec<(usize, f32)> },
    /// Exit iff `top_prob - second_prob >= delta`.
    TopTwoMargin { delta: f32 },
    /// Exit iff the softmax entropy is at most `max_nats`.
    Entropy { max_nats: f32 },
    /// Never exit early — the explicit full-model spelling.
    Never,
}

impl ExitPolicy {
    /// The paper's confidence rule — the spelling every pre-policy
    /// `threshold: f32` call site migrates to.
    pub fn confidence(threshold: f32) -> ExitPolicy {
        ExitPolicy::Confidence { threshold }
    }

    /// Decide whether to exit at `layer` given the head's summary.
    pub fn decide(&self, layer: usize, s: &LogitsSummary) -> ExitDecision {
        let exit = match self {
            ExitPolicy::Confidence { threshold } => s.top_prob >= *threshold,
            ExitPolicy::PerLayer { thresholds } => thresholds
                .iter()
                .find(|(l, _)| *l == layer)
                .is_some_and(|(_, t)| s.top_prob >= *t),
            ExitPolicy::TopTwoMargin { delta } => s.margin >= *delta,
            ExitPolicy::Entropy { max_nats } => s.entropy_nats <= *max_nats,
            ExitPolicy::Never => false,
        };
        if exit {
            ExitDecision::Exit
        } else {
            ExitDecision::Continue
        }
    }

    /// Whether this policy can ever exit early. False means full-model
    /// decoding: engines may skip exit-head evaluation and the
    /// sequential session suspends its forced-full-pass bookkeeping —
    /// exactly the old `threshold >= 1.0` behaviour.
    ///
    /// `Confidence`/`PerLayer` thresholds at `1.0` count as "never": the
    /// scalar-threshold API defined `1.0` as the full-model baseline and
    /// the policy API preserves that bit-for-bit. Margin and entropy
    /// rules are only "never" when their bound is unsatisfiable.
    pub fn may_exit(&self) -> bool {
        match self {
            ExitPolicy::Confidence { threshold } => *threshold < 1.0,
            ExitPolicy::PerLayer { thresholds } => {
                thresholds.iter().any(|(_, t)| *t < 1.0)
            }
            ExitPolicy::TopTwoMargin { delta } => *delta <= 1.0,
            ExitPolicy::Entropy { max_nats } => *max_nats >= 0.0,
            ExitPolicy::Never => false,
        }
    }

    /// [`ExitPolicy::may_exit`] restricted to one exit layer: false when
    /// this policy can never fire *there* (unlisted `PerLayer` layers,
    /// or a per-layer threshold at 1.0). Engines use this to skip the
    /// layer's head computation outright — the decision could only be
    /// `Continue`.
    pub fn may_exit_at(&self, layer: usize) -> bool {
        match self {
            ExitPolicy::PerLayer { thresholds } => thresholds
                .iter()
                .any(|(l, t)| *l == layer && *t < 1.0),
            _ => self.may_exit(),
        }
    }

    /// Fit a [`ExitPolicy::PerLayer`] policy from Table-4 probe data:
    /// for each early exit, the smallest confidence threshold such that
    /// tokens accepted at it agree with the final exit's prediction at a
    /// rate of at least `target_agreement`. Exits that cannot reach the
    /// target at any observed confidence get threshold `1.0` (disabled).
    /// A probe with no early exits at all yields [`ExitPolicy::Never`]
    /// (an empty `PerLayer` would not round-trip through the spec
    /// grammar).
    pub fn calibrated(
        report: &ProbeReport,
        target_agreement: f64,
    ) -> ExitPolicy {
        // The deepest probed layer is the final exit — it is the
        // agreement reference, not a calibration target.
        let final_layer = report.exit_layers.iter().copied().max();
        let early: Vec<usize> = report
            .exit_layers
            .iter()
            .copied()
            .filter(|&l| Some(l) != final_layer)
            .collect();
        let mut thresholds = Vec::with_capacity(early.len());
        for layer in early {
            // (confidence, agrees-with-final) per generated token.
            let mut obs: Vec<(f32, bool)> = report
                .probes
                .iter()
                .filter_map(|p| {
                    let fin = p.exits.last()?;
                    let e = p.exits.iter().find(|e| e.0 == layer)?;
                    Some((e.2, e.1 == fin.1))
                })
                .collect();
            // Highest confidence first; accepting threshold t means
            // accepting every observation with conf >= t, so scan the
            // prefixes and keep the smallest t whose prefix still meets
            // the agreement target. Ties in confidence are admitted
            // together (>= is inclusive).
            obs.sort_by(|a, b| b.0.total_cmp(&a.0));
            let mut best = 1.0f32;
            let mut agree = 0usize;
            let mut i = 0usize;
            while i < obs.len() {
                let conf = obs[i].0;
                while i < obs.len() && obs[i].0 == conf {
                    agree += usize::from(obs[i].1);
                    i += 1;
                }
                if agree as f64 / i as f64 >= target_agreement {
                    best = conf;
                }
            }
            thresholds.push((layer, best));
        }
        if thresholds.is_empty() {
            return ExitPolicy::Never;
        }
        ExitPolicy::PerLayer { thresholds }
    }

    /// The one CLI resolution rule, shared by every surface that takes
    /// an exit policy: `--policy SPEC` wins; otherwise `--threshold F`
    /// is sugar for the confidence rule; otherwise
    /// `Confidence{default_threshold}`.
    pub fn from_args(
        args: &crate::util::cli::Args,
        default_threshold: f32,
    ) -> Result<ExitPolicy> {
        match args.get("policy") {
            Some(spec) => ExitPolicy::parse(spec),
            None => Ok(ExitPolicy::confidence(
                args.f64_or("threshold", default_threshold as f64) as f32,
            )),
        }
    }

    /// Parse the textual spec grammar (see module docs). A bare float is
    /// shorthand for `confidence:<float>`.
    pub fn parse(spec: &str) -> Result<ExitPolicy> {
        let spec = spec.trim();
        if spec == "never" {
            return Ok(ExitPolicy::Never);
        }
        if let Ok(t) = spec.parse::<f32>() {
            if !t.is_finite() {
                bail!("bad confidence threshold {spec:?}: must be finite");
            }
            return Ok(ExitPolicy::Confidence { threshold: t });
        }
        let (kind, body) = spec.split_once(':').with_context(|| {
            format!(
                "bad exit-policy spec {spec:?} (expected never | \
                 confidence:T | per-layer:L=T,... | margin:D | entropy:N)"
            )
        })?;
        match kind {
            "confidence" | "conf" => Ok(ExitPolicy::Confidence {
                threshold: parse_f32(body, "confidence threshold")?,
            }),
            "margin" | "top2-margin" => Ok(ExitPolicy::TopTwoMargin {
                delta: parse_f32(body, "margin delta")?,
            }),
            "entropy" => Ok(ExitPolicy::Entropy {
                max_nats: parse_f32(body, "entropy bound")?,
            }),
            "per-layer" | "per_layer" => {
                let mut thresholds = Vec::new();
                for part in body.split(',').filter(|p| !p.is_empty()) {
                    let (l, t) = part.split_once('=').with_context(|| {
                        format!(
                            "bad per-layer entry {part:?} (want LAYER=T)"
                        )
                    })?;
                    let layer: usize = l.trim().parse().with_context(|| {
                        format!("bad per-layer exit layer {l:?}")
                    })?;
                    let t = parse_f32(t, "per-layer threshold")?;
                    if thresholds.iter().any(|(x, _)| *x == layer) {
                        bail!("duplicate per-layer exit layer {layer}");
                    }
                    thresholds.push((layer, t));
                }
                if thresholds.is_empty() {
                    bail!("per-layer policy needs at least one LAYER=T");
                }
                thresholds.sort_by_key(|(l, _)| *l);
                Ok(ExitPolicy::PerLayer { thresholds })
            }
            other => bail!(
                "unknown exit-policy kind {other:?} (never | confidence | \
                 per-layer | margin | entropy)"
            ),
        }
    }

    /// Canonical spec string: `ExitPolicy::parse(p.spec())` reproduces
    /// `p` (modulo `PerLayer` entry order, which `parse` sorts).
    pub fn spec(&self) -> String {
        match self {
            ExitPolicy::Confidence { threshold } => {
                format!("confidence:{threshold}")
            }
            ExitPolicy::PerLayer { thresholds } => {
                let parts: Vec<String> = thresholds
                    .iter()
                    .map(|(l, t)| format!("{l}={t}"))
                    .collect();
                format!("per-layer:{}", parts.join(","))
            }
            ExitPolicy::TopTwoMargin { delta } => format!("margin:{delta}"),
            ExitPolicy::Entropy { max_nats } => format!("entropy:{max_nats}"),
            ExitPolicy::Never => "never".to_string(),
        }
    }
}

impl std::fmt::Display for ExitPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.spec())
    }
}

fn parse_f32(s: &str, what: &str) -> Result<f32> {
    let v: f32 = s
        .trim()
        .parse()
        .with_context(|| format!("bad {what} {s:?}"))?;
    if !v.is_finite() {
        bail!("bad {what} {s:?}: must be finite");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::super::sequential::TokenProbe;
    use super::*;
    use crate::util::proptest;

    fn summary(top: f32, second: f32) -> LogitsSummary {
        LogitsSummary {
            token: 0,
            top_prob: top,
            margin: top - second,
            entropy_nats: 0.5,
        }
    }

    #[test]
    fn summarize_logits_matches_softmax_facts() {
        let mut logits = vec![0.0f32; 10];
        logits[3] = 8.0;
        let s = summarize_logits(&logits);
        assert_eq!(s.token, 3);
        assert!(s.top_prob > 0.99);
        assert!(s.margin > 0.99);
        assert!(s.entropy_nats < 0.05, "{s:?}");
        // Flat logits: uniform distribution, max entropy ln(10).
        let s = summarize_logits(&vec![0.0f32; 10]);
        assert!((s.top_prob - 0.1).abs() < 1e-5);
        assert!(s.margin.abs() < 1e-6);
        assert!((s.entropy_nats - 10f32.ln()).abs() < 1e-4);
    }

    #[test]
    fn confidence_decides_on_top_prob_inclusive() {
        let p = ExitPolicy::confidence(0.8);
        assert!(p.decide(2, &summary(0.8, 0.1)).is_exit(), "boundary is >=");
        assert!(p.decide(2, &summary(0.91, 0.1)).is_exit());
        assert!(!p.decide(2, &summary(0.79, 0.1)).is_exit());
        assert!(p.may_exit());
        // 1.0 is the full-model baseline: exits disabled entirely.
        assert!(!ExitPolicy::confidence(1.0).may_exit());
        assert!(!ExitPolicy::confidence(1.5).may_exit());
    }

    #[test]
    fn per_layer_uses_each_layers_threshold_and_skips_unlisted() {
        let p = ExitPolicy::PerLayer {
            thresholds: vec![(2, 0.9), (4, 0.5)],
        };
        let s = summary(0.7, 0.1);
        assert!(!p.decide(2, &s).is_exit());
        assert!(p.decide(4, &s).is_exit());
        assert!(!p.decide(6, &s).is_exit(), "unlisted layer never exits");
        assert!(p.may_exit());
        // Per-layer reachability: engines skip heads where the policy
        // can never fire.
        assert!(p.may_exit_at(2) && p.may_exit_at(4));
        assert!(!p.may_exit_at(6), "unlisted layer is unreachable");
        let disabled = ExitPolicy::PerLayer {
            thresholds: vec![(2, 1.0), (4, 1.0)],
        };
        assert!(!disabled.may_exit());
        assert!(!disabled.may_exit_at(2));
        assert!(ExitPolicy::confidence(0.5).may_exit_at(7));
        assert!(!ExitPolicy::Never.may_exit_at(2));
    }

    #[test]
    fn margin_entropy_and_never_semantics() {
        let m = ExitPolicy::TopTwoMargin { delta: 0.3 };
        assert!(m.decide(2, &summary(0.6, 0.3)).is_exit());
        assert!(!m.decide(2, &summary(0.6, 0.4)).is_exit());
        assert!(m.may_exit());
        assert!(!ExitPolicy::TopTwoMargin { delta: 1.5 }.may_exit());

        let e = ExitPolicy::Entropy { max_nats: 0.5 };
        assert!(e.decide(2, &summary(0.9, 0.05)).is_exit());
        let mut hot = summary(0.4, 0.3);
        hot.entropy_nats = 1.2;
        assert!(!e.decide(2, &hot).is_exit());
        assert!(e.may_exit());
        assert!(!ExitPolicy::Entropy { max_nats: -1.0 }.may_exit());

        assert!(!ExitPolicy::Never.decide(0, &summary(1.0, 0.0)).is_exit());
        assert!(!ExitPolicy::Never.may_exit());
    }

    /// Property: `PerLayer` with one uniform threshold on every probed
    /// layer decides identically to `Confidence` with that threshold,
    /// for arbitrary summaries and layers.
    #[test]
    fn uniform_per_layer_equals_confidence() {
        proptest::check("uniform per-layer == confidence", 256, |rng| {
            let t = rng.below(101) as f32 / 100.0;
            let layers = [2usize, 4, 6, 8];
            let per = ExitPolicy::PerLayer {
                thresholds: layers.iter().map(|&l| (l, t)).collect(),
            };
            let conf = ExitPolicy::confidence(t);
            if per.may_exit() != conf.may_exit() {
                return Err(format!("may_exit diverges at t={t}"));
            }
            for &layer in &layers {
                let top = rng.below(101) as f32 / 100.0;
                let s = summary(top, (top / 2.0).min(1.0 - top));
                if per.decide(layer, &s) != conf.decide(layer, &s) {
                    return Err(format!(
                        "decision diverges: layer {layer} t {t} top {top}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn spec_round_trips() {
        let policies = [
            ExitPolicy::confidence(0.8),
            ExitPolicy::confidence(1.0),
            ExitPolicy::PerLayer { thresholds: vec![(2, 0.7), (4, 0.9)] },
            ExitPolicy::TopTwoMargin { delta: 0.25 },
            ExitPolicy::Entropy { max_nats: 1.5 },
            ExitPolicy::Never,
        ];
        for p in policies {
            let parsed = ExitPolicy::parse(&p.spec()).unwrap();
            assert_eq!(parsed, p, "spec {:?} did not round-trip", p.spec());
        }
        // Sugar forms.
        assert_eq!(
            ExitPolicy::parse("0.6").unwrap(),
            ExitPolicy::confidence(0.6)
        );
        assert_eq!(
            ExitPolicy::parse("conf:0.6").unwrap(),
            ExitPolicy::confidence(0.6)
        );
        // Rejections.
        assert!(ExitPolicy::parse("fifo").is_err());
        assert!(ExitPolicy::parse("per-layer:").is_err());
        assert!(ExitPolicy::parse("per-layer:2=0.5,2=0.6").is_err());
        assert!(ExitPolicy::parse("entropy:abc").is_err());
        // Non-finite numbers would make a policy unequal to itself
        // (NaN != NaN breaks the pool's policy change-detection).
        assert!(ExitPolicy::parse("nan").is_err());
        assert!(ExitPolicy::parse("inf").is_err());
        assert!(ExitPolicy::parse("confidence:nan").is_err());
        assert!(ExitPolicy::parse("entropy:inf").is_err());
    }

    fn probe(position: usize, exits: Vec<(usize, i32, f32)>) -> TokenProbe {
        TokenProbe { position, exits }
    }

    #[test]
    fn calibration_picks_smallest_threshold_meeting_target() {
        // Layer 2 observations (final layer 4 always predicts token 7):
        // conf 0.9 agrees, 0.7 agrees, 0.5 disagrees, 0.3 agrees.
        let report = ProbeReport {
            probes: vec![
                probe(0, vec![(2, 7, 0.9), (4, 7, 0.99)]),
                probe(1, vec![(2, 7, 0.7), (4, 7, 0.99)]),
                probe(2, vec![(2, 9, 0.5), (4, 7, 0.99)]),
                probe(3, vec![(2, 7, 0.3), (4, 7, 0.99)]),
            ],
            generated: String::new(),
            exit_layers: vec![2, 4],
        };
        // Target 1.0: only the {0.9, 0.7} prefix is all-agreeing.
        let p = ExitPolicy::calibrated(&report, 1.0);
        assert_eq!(
            p,
            ExitPolicy::PerLayer { thresholds: vec![(2, 0.7)] }
        );
        // Target 0.75: the {0.9, 0.7, 0.5, 0.3} prefix agrees at 3/4.
        let p = ExitPolicy::calibrated(&report, 0.75);
        assert_eq!(
            p,
            ExitPolicy::PerLayer { thresholds: vec![(2, 0.3)] }
        );
        // Unreachable target on an always-disagreeing exit: disabled.
        let bad = ProbeReport {
            probes: vec![probe(0, vec![(2, 1, 0.9), (4, 7, 0.99)])],
            generated: String::new(),
            exit_layers: vec![2, 4],
        };
        let p = ExitPolicy::calibrated(&bad, 0.9);
        assert_eq!(
            p,
            ExitPolicy::PerLayer { thresholds: vec![(2, 1.0)] }
        );
        assert!(!p.may_exit());
        // No early exits at all: Never, not an unparseable empty
        // PerLayer — the printed spec must round-trip.
        let none = ProbeReport {
            probes: vec![probe(0, vec![(4, 7, 0.99)])],
            generated: String::new(),
            exit_layers: vec![4],
        };
        let p = ExitPolicy::calibrated(&none, 0.9);
        assert_eq!(p, ExitPolicy::Never);
        assert_eq!(ExitPolicy::parse(&p.spec()).unwrap(), p);
    }
}
