//! Tiered snapshot store: a small pinned device-resident tier over the
//! host-copy [`PrefixCacheStore`] tier, so hot shared prefixes (system
//! prompts, active conversations) skip the host round-trip.
//!
//! The host tier owns every snapshot — the trie, the LRU, the position
//! budget — exactly as before. The device tier is a *residency overlay*:
//! it holds [`PinnedSnapshot`] guards on the hottest entries, which (a)
//! marks them device-resident for restore-path accounting and (b) pins
//! them in the host tier, so budget pressure there can never evict a
//! device-resident entry out from under its residency. Consequently the
//! device tier is always a subset of the host tier.
//!
//! Tier movement is frequency-driven and deterministic:
//!
//! - **Promotion** — an entry is promoted once it has been hit
//!   [`PROMOTE_AFTER`] times and fits the device position budget.
//! - **Demotion** — promotion under pressure demotes resident entries
//!   that are strictly *colder* (fewer recorded hits; ties broken by
//!   smaller token key) than the candidate, dropping their pins back to
//!   plain host residency. A candidate never displaces an equally-hot
//!   or hotter entry, and a promotion that cannot free enough room from
//!   strictly-colder entries is skipped outright — no partial demotion.
//! - A **device budget of 0** disables the overlay entirely: lookups,
//!   inserts, and eviction behave byte-for-byte like the bare host
//!   store (the tiered-vs-host-only parity configuration).
//!
//! Per-tier activity (device hits, host hits, misses, promotions,
//! demotions) is counted in [`TierStats`], the tier analogue of
//! [`PrefixCacheStats`]; host-tier counters remain on the wrapped
//! store. Budget and subset invariants are enforced by the pinned-seed
//! property tests at the bottom of this file.
//!
//! [`PrefixCacheStats`]: super::prefix_cache::PrefixCacheStats

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::prefix_cache::{
    CacheSnapshot, PinnedSnapshot, PrefixCacheStore, PrefixCacheStats,
    PrefixHit, SnapshotSource,
};

/// Hits an entry needs before it is promoted to the device tier.
const PROMOTE_AFTER: u32 = 2;

/// Cap on tracked per-key hit counts; once exceeded, cold non-resident
/// keys are pruned so conversational churn cannot grow the map without
/// bound.
const MAX_TRACKED: usize = 1024;

/// Activity counters of the device tier (monotonic; diff two readings
/// with [`TierStats::since`] to attribute one batch).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Lookups served by a device-resident entry.
    pub device_hits: u64,
    /// Lookups served by the host tier only.
    pub host_hits: u64,
    /// Lookups with no usable shared prefix in either tier.
    pub misses: u64,
    /// Entries promoted host → device.
    pub promotions: u64,
    /// Entries demoted device → host (displaced by a hotter candidate).
    pub demotions: u64,
}

impl TierStats {
    /// Total lookups.
    pub fn lookups(&self) -> u64 {
        self.device_hits + self.host_hits + self.misses
    }

    /// Fraction of *hits* served from the device tier (0 when nothing
    /// hit).
    pub fn device_hit_rate(&self) -> f64 {
        let hits = self.device_hits + self.host_hits;
        self.device_hits as f64 / hits.max(1) as f64
    }

    /// Accumulate another reading into this one.
    pub fn merge(&mut self, other: &TierStats) {
        self.device_hits += other.device_hits;
        self.host_hits += other.host_hits;
        self.misses += other.misses;
        self.promotions += other.promotions;
        self.demotions += other.demotions;
    }

    /// Counter delta `self - baseline` (saturating): activity since an
    /// earlier reading of the same store.
    pub fn since(&self, baseline: &TierStats) -> TierStats {
        TierStats {
            device_hits: self
                .device_hits
                .saturating_sub(baseline.device_hits),
            host_hits: self.host_hits.saturating_sub(baseline.host_hits),
            misses: self.misses.saturating_sub(baseline.misses),
            promotions: self.promotions.saturating_sub(baseline.promotions),
            demotions: self.demotions.saturating_sub(baseline.demotions),
        }
    }
}

struct TierInner {
    /// Device-resident entries: key → the pin that keeps the host entry
    /// alive (and marks residency).
    resident: BTreeMap<Vec<i32>, PinnedSnapshot>,
    /// Positions held by `resident` (each entry's snapshot weight).
    resident_positions: usize,
    /// Per-key hit counts driving promotion/demotion order.
    hits: BTreeMap<Vec<i32>, u32>,
    stats: TierStats,
}

/// Thread-safe tiered device+host snapshot store; see the module docs.
/// Drop-in for [`PrefixCacheStore`] wherever the pool consumed one —
/// [`SnapshotSource`] covers the session prefill path, and the host
/// tier's budget/occupancy accessors are delegated.
pub struct TieredStore {
    host: PrefixCacheStore,
    device_positions: usize,
    inner: Mutex<TierInner>,
}

impl TieredStore {
    /// A store whose host tier may hold `host_positions` cached
    /// positions and whose device tier may pin `device_positions` of
    /// them resident. `device_positions == 0` disables the overlay.
    pub fn new(host_positions: usize, device_positions: usize) -> TieredStore {
        TieredStore {
            host: PrefixCacheStore::new(host_positions),
            device_positions,
            inner: Mutex::new(TierInner {
                resident: BTreeMap::new(),
                resident_positions: 0,
                hits: BTreeMap::new(),
                stats: TierStats::default(),
            }),
        }
    }

    /// Longest-common-prefix lookup through both tiers. The host trie is
    /// the single source of truth for *what* matches; this layer only
    /// classifies the hit by residency, updates hit frequencies, and
    /// promotes once an entry crosses the threshold.
    pub fn lookup(&self, query: &[i32]) -> Option<PrefixHit> {
        let hit = match self.host.lookup(query) {
            Some(h) => h,
            None => {
                self.inner.lock().unwrap().stats.misses += 1;
                return None;
            }
        };
        let key = hit.snapshot.tokens().to_vec();
        let need = hit.snapshot.snapshot().positions();
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let count = {
            let c = inner.hits.entry(key.clone()).or_insert(0);
            *c = c.saturating_add(1);
            *c
        };
        if inner.resident.contains_key(&key) {
            inner.stats.device_hits += 1;
        } else {
            inner.stats.host_hits += 1;
            if count >= PROMOTE_AFTER && need <= self.device_positions {
                self.promote_locked(inner, &key, need, count, &hit);
            }
        }
        if inner.hits.len() > MAX_TRACKED {
            let resident = &inner.resident;
            inner
                .hits
                .retain(|k, c| resident.contains_key(k) || *c >= PROMOTE_AFTER);
        }
        Some(hit)
    }

    /// Promote `key` into the device tier, demoting strictly-colder
    /// residents (coldest first) as needed. Skips — and demotes nothing —
    /// when colder residents cannot free enough room: a candidate never
    /// displaces an equally-hot or hotter entry, and never partially.
    fn promote_locked(
        &self,
        inner: &mut TierInner,
        key: &[i32],
        need: usize,
        count: u32,
        hit: &PrefixHit,
    ) {
        let mut free =
            self.device_positions.saturating_sub(inner.resident_positions);
        let mut planned: Vec<Vec<i32>> = Vec::new();
        if free < need {
            let mut order: Vec<(u32, Vec<i32>, usize)> = inner
                .resident
                .iter()
                .map(|(k, pin)| {
                    (
                        inner.hits.get(k).copied().unwrap_or(0),
                        k.clone(),
                        pin.snapshot().positions(),
                    )
                })
                .collect();
            order.sort();
            for (c, k, weight) in order {
                if free >= need {
                    break;
                }
                if c >= count {
                    break;
                }
                free += weight;
                planned.push(k);
            }
            if free < need {
                return;
            }
        }
        for k in planned {
            let pin = inner.resident.remove(&k).expect("planned resident");
            inner.resident_positions -= pin.snapshot().positions();
            inner.stats.demotions += 1;
        }
        inner.resident.insert(key.to_vec(), hit.snapshot.clone());
        inner.resident_positions += need;
        inner.stats.promotions += 1;
    }

    /// Store a snapshot in the host tier (promotion happens on later
    /// hits, never at insert — a snapshot nobody re-reads must not pin
    /// device room).
    pub fn insert(&self, snap: CacheSnapshot) -> bool {
        self.host.insert(snap)
    }

    /// Whether the host tier could currently admit a snapshot of
    /// `positions` (see [`PrefixCacheStore::would_admit`]).
    pub fn would_admit(&self, positions: usize) -> bool {
        self.host.would_admit(positions)
    }

    /// Evict the host tier's LRU unpinned entry. Device-resident entries
    /// hold a pin and are therefore never eviction victims.
    pub fn evict_one(&self) -> Option<Vec<i32>> {
        self.host.evict_one()
    }

    /// Drop the entry stored under exactly `tokens` from both tiers (TTL
    /// expiry). The device pin is released first so the host removal is
    /// not blocked by our own residency; removal still fails while any
    /// *other* pin (a decoding session) is live, leaving the entry
    /// host-resident but no longer device-resident.
    pub fn remove(&self, tokens: &[i32]) -> bool {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(pin) = inner.resident.remove(tokens) {
                inner.resident_positions -= pin.snapshot().positions();
            }
            inner.hits.remove(tokens);
        }
        self.host.remove(tokens)
    }

    /// Attribute prefill positions skipped thanks to a hit.
    pub fn record_saved(&self, positions: u64) {
        self.host.record_saved(positions)
    }

    /// Host-tier counter snapshot.
    pub fn stats(&self) -> PrefixCacheStats {
        self.host.stats()
    }

    /// Device-tier counter snapshot.
    pub fn tier_stats(&self) -> TierStats {
        self.inner.lock().unwrap().stats
    }

    /// Host-tier position budget.
    pub fn max_positions(&self) -> usize {
        self.host.max_positions()
    }

    /// Host-tier positions currently resident.
    pub fn used_positions(&self) -> usize {
        self.host.used_positions()
    }

    /// Host memory held by resident snapshots.
    pub fn used_bytes(&self) -> usize {
        self.host.used_bytes()
    }

    /// Resident host-tier snapshots.
    pub fn len(&self) -> usize {
        self.host.len()
    }

    pub fn is_empty(&self) -> bool {
        self.host.is_empty()
    }

    /// Host-tier snapshots with at least one live pin (device residency
    /// counts as a pin).
    pub fn pinned_entries(&self) -> usize {
        self.host.pinned_entries()
    }

    /// Device-tier position budget.
    pub fn device_budget(&self) -> usize {
        self.device_positions
    }

    /// Positions pinned device-resident.
    pub fn device_used_positions(&self) -> usize {
        self.inner.lock().unwrap().resident_positions
    }

    /// Device-resident entries.
    pub fn device_len(&self) -> usize {
        self.inner.lock().unwrap().resident.len()
    }

    /// Bytes held by device-resident snapshots.
    pub fn device_bytes(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .resident
            .values()
            .map(|p| p.snapshot().bytes())
            .sum()
    }

    /// Whether the entry stored under exactly `tokens` is
    /// device-resident.
    pub fn is_device_resident(&self, tokens: &[i32]) -> bool {
        self.inner.lock().unwrap().resident.contains_key(tokens)
    }
}

impl SnapshotSource for TieredStore {
    fn lookup(&self, query: &[i32]) -> Option<PrefixHit> {
        TieredStore::lookup(self, query)
    }

    fn record_saved(&self, positions: u64) {
        self.host.record_saved(positions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    /// Snapshot with no tensors — the store never inspects them, so the
    /// tier machinery can be tested without a model (weight = key len).
    fn snap(tokens: &[i32]) -> CacheSnapshot {
        CacheSnapshot {
            tokens: tokens.to_vec(),
            stage_caches: Vec::new(),
            deficit: 0,
        }
    }

    #[test]
    fn promotion_needs_repeat_hits_and_budget() {
        let s = TieredStore::new(32, 4);
        assert!(s.insert(snap(&[1, 2, 3])));
        assert!(s.insert(snap(&[7, 8, 9, 10, 11])));
        // First hit: host tier only.
        assert!(s.lookup(&[1, 2, 3]).is_some());
        assert!(!s.is_device_resident(&[1, 2, 3]));
        // Second hit crosses PROMOTE_AFTER: promoted.
        assert!(s.lookup(&[1, 2, 3]).is_some());
        assert!(s.is_device_resident(&[1, 2, 3]));
        assert_eq!(s.device_used_positions(), 3);
        // Third hit is a device hit.
        assert!(s.lookup(&[1, 2, 3]).is_some());
        // The 5-position entry can never fit the 4-position device
        // budget, however hot.
        for _ in 0..4 {
            assert!(s.lookup(&[7, 8, 9, 10, 11]).is_some());
        }
        assert!(!s.is_device_resident(&[7, 8, 9, 10, 11]));
        let t = s.tier_stats();
        assert_eq!(t.device_hits, 1);
        assert_eq!(t.host_hits, 6);
        assert_eq!(t.promotions, 1);
        assert_eq!(t.demotions, 0);
        assert!((t.device_hit_rate() - 1.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn hotter_candidate_demotes_coldest_resident_only() {
        let s = TieredStore::new(64, 5);
        assert!(s.insert(snap(&[1, 2])));
        assert!(s.insert(snap(&[3, 4, 5])));
        assert!(s.insert(snap(&[6, 7])));
        // Promote [1,2] (2 hits) and [3,4,5] (2 hits): device full (5/5).
        for _ in 0..2 {
            assert!(s.lookup(&[1, 2]).is_some());
            assert!(s.lookup(&[3, 4, 5]).is_some());
        }
        assert_eq!(s.device_used_positions(), 5);
        // [6,7] at 2 hits is not strictly hotter than either resident
        // (both sit at 2 device-era hits... [1,2] and [3,4,5] have 2
        // recorded hits each): promotion is skipped, nothing demoted.
        assert!(s.lookup(&[6, 7]).is_some());
        assert!(s.lookup(&[6, 7]).is_some());
        assert!(!s.is_device_resident(&[6, 7]));
        assert_eq!(s.tier_stats().demotions, 0);
        // A third hit makes [6,7] strictly hotter (3 > 2): the coldest
        // resident by (count, key) — [1,2] — is demoted to make room.
        assert!(s.lookup(&[6, 7]).is_some());
        assert!(s.is_device_resident(&[6, 7]));
        assert!(!s.is_device_resident(&[1, 2]));
        assert!(s.is_device_resident(&[3, 4, 5]));
        assert_eq!(s.device_used_positions(), 5);
        let t = s.tier_stats();
        assert_eq!(t.promotions, 3);
        assert_eq!(t.demotions, 1);
    }

    #[test]
    fn device_residents_survive_host_pressure() {
        // Host budget 8, device 4: promote [1,2,3,4], then pour in
        // enough inserts to thrash the host LRU — the resident entry is
        // pinned and must never be the victim.
        let s = TieredStore::new(8, 4);
        assert!(s.insert(snap(&[1, 2, 3, 4])));
        assert!(s.lookup(&[1, 2, 3, 4]).is_some());
        assert!(s.lookup(&[1, 2, 3, 4]).is_some());
        assert!(s.is_device_resident(&[1, 2, 3, 4]));
        for i in 0..6i32 {
            s.insert(snap(&[10 + i, 20 + i, 30 + i]));
        }
        let hit = s.lookup(&[1, 2, 3, 4, 9]).expect("still resident");
        assert_eq!(hit.snapshot.tokens(), &[1, 2, 3, 4]);
        assert_eq!(hit.matched, 4);
        assert!(s.used_positions() <= s.max_positions());
        // Eviction can also never pick it.
        while s.evict_one().is_some() {}
        assert_eq!(s.len(), 1);
        assert!(s.is_device_resident(&[1, 2, 3, 4]));
    }

    #[test]
    fn remove_drops_both_tiers() {
        let s = TieredStore::new(32, 8);
        assert!(s.insert(snap(&[1, 2, 3])));
        assert!(s.lookup(&[1, 2, 3]).is_some());
        assert!(s.lookup(&[1, 2, 3]).is_some());
        assert!(s.is_device_resident(&[1, 2, 3]));
        assert!(s.remove(&[1, 2, 3]));
        assert!(!s.is_device_resident(&[1, 2, 3]));
        assert_eq!(s.device_used_positions(), 0);
        assert!(s.is_empty());
        // A live outside pin blocks the host removal but not the
        // residency drop.
        assert!(s.insert(snap(&[4, 5, 6])));
        let pin = s.lookup(&[4, 5, 6]).expect("hit");
        assert!(s.lookup(&[4, 5, 6]).is_some());
        assert!(s.is_device_resident(&[4, 5, 6]));
        assert!(!s.remove(&[4, 5, 6]), "session pin still live");
        assert!(!s.is_device_resident(&[4, 5, 6]));
        assert_eq!(s.len(), 1);
        drop(pin);
        assert!(s.remove(&[4, 5, 6]));
        assert!(s.is_empty());
    }

    #[test]
    fn zero_device_budget_is_host_only() {
        let s = TieredStore::new(16, 0);
        assert!(s.insert(snap(&[1, 2, 3])));
        for _ in 0..5 {
            assert!(s.lookup(&[1, 2, 3]).is_some());
        }
        assert!(!s.is_device_resident(&[1, 2, 3]));
        assert_eq!(s.device_len(), 0);
        assert_eq!(s.device_used_positions(), 0);
        let t = s.tier_stats();
        assert_eq!(t.promotions, 0);
        assert_eq!(t.host_hits, 5);
    }

    /// ISSUE satellite: longest-prefix lookup stays maximal when
    /// snapshots share mid-branch prefixes — system prompt ⊂ turn-1 ⊂
    /// turn-2, the exact nesting conversational finish-snapshots create.
    #[test]
    fn conversational_nested_keys_lookup_stays_maximal() {
        proptest::check("tiered nested-key lookup", 64, |rng| {
            let s = TieredStore::new(4096, rng.range(0, 32));
            // A chain of nested keys: each extends the previous.
            let mut chain: Vec<Vec<i32>> = Vec::new();
            let mut key: Vec<i32> =
                (0..rng.range(2, 6)).map(|_| rng.below(4) as i32).collect();
            for _ in 0..rng.range(2, 5) {
                chain.push(key.clone());
                for _ in 0..rng.range(1, 5) {
                    key.push(rng.below(4) as i32);
                }
            }
            chain.push(key);
            // Insert in random order; every nested key must coexist.
            let mut order: Vec<usize> = (0..chain.len()).collect();
            for i in (1..order.len()).rev() {
                order.swap(i, rng.below(i + 1));
            }
            for &i in &order {
                if !s.insert(snap(&chain[i])) {
                    return Err(format!("insert rejected {:?}", chain[i]));
                }
            }
            // Random queries, some extending chain members: matched must
            // equal the best lcp over all keys, and repeat lookups (which
            // promote) must never change the answer.
            for _ in 0..20 {
                let base = &chain[rng.below(chain.len())];
                let mut q = base.clone();
                for _ in 0..rng.range(0, 4) {
                    q.push(rng.below(4) as i32);
                }
                let want = chain
                    .iter()
                    .map(|k| {
                        k.iter().zip(&q).take_while(|(a, b)| a == b).count()
                    })
                    .max()
                    .unwrap();
                match s.lookup(&q) {
                    Some(h) if want >= 2 => {
                        if h.matched != want {
                            return Err(format!(
                                "query {q:?}: matched {} != best lcp {want}",
                                h.matched
                            ));
                        }
                    }
                    None if want < 2 => {}
                    got => {
                        return Err(format!(
                            "query {q:?}: hit {} vs lcp {want}",
                            got.is_some()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE satellite: eviction never orphans a pinned descendant —
    /// with turn-2 device-resident (pinned), evicting its ancestors must
    /// leave the descendant reachable through the trie at full depth.
    #[test]
    fn eviction_never_orphans_pinned_descendant() {
        proptest::check("tiered pinned descendant", 64, |rng| {
            let system: Vec<i32> =
                (0..rng.range(2, 5)).map(|_| rng.below(3) as i32).collect();
            let mut turn1 = system.clone();
            turn1.extend((0..rng.range(1, 4)).map(|_| rng.below(3) as i32));
            let mut turn2 = turn1.clone();
            turn2.extend((0..rng.range(1, 4)).map(|_| rng.below(3) as i32));
            let s = TieredStore::new(256, turn2.len());
            for k in [&system, &turn1, &turn2] {
                if !s.insert(snap(k)) {
                    return Err(format!("insert rejected {k:?}"));
                }
            }
            // Pin turn-2 into the device tier.
            for _ in 0..PROMOTE_AFTER {
                s.lookup(&turn2).ok_or("turn2 lookup missed")?;
            }
            if !s.is_device_resident(&turn2) {
                return Err("turn2 was not promoted".into());
            }
            // Flush everything evictable (the ancestors).
            while s.evict_one().is_some() {}
            if s.len() != 1 {
                return Err(format!(
                    "expected only the pinned descendant, got {}",
                    s.len()
                ));
            }
            // The descendant is still reachable at full depth, through
            // trie nodes its evicted ancestors once shared.
            let mut q = turn2.clone();
            q.push(99);
            let hit = s.lookup(&q).ok_or("pinned descendant orphaned")?;
            if hit.matched != turn2.len()
                || hit.snapshot.tokens() != turn2.as_slice()
            {
                return Err(format!(
                    "descendant mis-resolved: matched {} of {:?}",
                    hit.matched,
                    hit.snapshot.tokens()
                ));
            }
            Ok(())
        });
    }

    /// ISSUE satellite: tier promotion/demotion preserves the
    /// position/byte budget invariants under random op sequences —
    /// device usage within budget, device ⊆ host, bytes consistent with
    /// residents, host budget untouched by the overlay.
    #[test]
    fn tier_churn_preserves_budget_invariants() {
        proptest::check("tiered budget invariants", 96, |rng| {
            let host_budget = rng.range(8, 40);
            let device_budget = rng.range(0, 12);
            let s = TieredStore::new(host_budget, device_budget);
            let mut keys: Vec<Vec<i32>> = Vec::new();
            for _ in 0..rng.range(30, 100) {
                match rng.below(5) {
                    0 | 1 => {
                        let key: Vec<i32> = (0..rng.range(2, 7))
                            .map(|_| rng.below(4) as i32)
                            .collect();
                        if s.insert(snap(&key)) {
                            keys.push(key);
                        }
                    }
                    2 | 3 => {
                        if let Some(k) =
                            keys.get(rng.below(keys.len().max(1)))
                        {
                            s.lookup(k);
                        }
                    }
                    _ => {
                        if rng.below(2) == 0 {
                            s.evict_one();
                        } else if let Some(k) =
                            keys.get(rng.below(keys.len().max(1)))
                        {
                            s.remove(k);
                        }
                    }
                }
                if s.device_used_positions() > device_budget {
                    return Err(format!(
                        "device budget exceeded: {} > {device_budget}",
                        s.device_used_positions()
                    ));
                }
                if s.used_positions() > host_budget {
                    return Err(format!(
                        "host budget exceeded: {} > {host_budget}",
                        s.used_positions()
                    ));
                }
                if s.device_len() > s.len() {
                    return Err(format!(
                        "device tier ({}) outgrew host tier ({})",
                        s.device_len(),
                        s.len()
                    ));
                }
                if s.device_len() > 0 && s.pinned_entries() < s.device_len()
                {
                    return Err(
                        "resident entries missing their pins".to_string()
                    );
                }
            }
            // Every device-resident key must still resolve exactly in
            // the host tier (subset invariant).
            for k in &keys {
                if s.is_device_resident(k) {
                    let hit =
                        s.lookup(k).ok_or("resident key missing from host")?;
                    if hit.snapshot.tokens() != k.as_slice() {
                        return Err(format!(
                            "resident {k:?} resolved to {:?}",
                            hit.snapshot.tokens()
                        ));
                    }
                }
            }
            // Tensor-less snapshots hold no bytes; the gauge must agree.
            if s.device_bytes() != 0 {
                return Err("phantom device bytes".to_string());
            }
            Ok(())
        });
    }
}
