//! # EE-LLM (reproduction)
//!
//! Large-scale training and inference of early-exit LLMs with pipeline
//! parallelism — a full-system reproduction of Chen et al., ICML 2024,
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the coordinator: pipeline-parallel 1F1B training
//!   with the paper's auxiliary-loss backpropagation (Eq. 2), two
//!   KV-cache-compatible early-exit inference engines (KV recomputation and
//!   pipeline-based), a multi-request serving layer (engine pool +
//!   scheduler), a discrete-event pipeline-schedule simulator, and all
//!   supporting substrates (tokenizer, data pipeline, eval harness,
//!   metrics, CLI).
//! - **L2 (python/compile)** — the early-exit GPT model in JAX, AOT-lowered
//!   per pipeline stage to HLO text (`make artifacts`).
//! - **L1 (python/compile/kernels)** — Pallas kernels for the hot spots
//!   (fused exit-loss, flash attention), lowered inside the L2 functions.
//!
//! Python never runs at request time: the runtime loads `artifacts/` and is
//! otherwise self-contained.

pub mod config;
pub mod data;
pub mod eval;
pub mod inference;
pub mod metrics;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod training;
pub mod util;
