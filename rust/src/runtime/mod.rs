//! L3 runtime: loading AOT artifacts and executing them on PJRT.
//!
//! - [`tensor`] — host tensors (plain `Vec<f32>` / `Vec<i32>` + shape) and
//!   conversion to/from `xla::Literal`. These are what flows across the
//!   pipeline's P2P channels.
//! - [`artifacts`] — the `manifest.json` schema emitted by
//!   `python/compile/aot.py`.
//! - [`client`] — PJRT CPU client wrapper + compiled-executable registry.
//!   `xla` types are `Rc`-based (!Send), so each pipeline-stage worker
//!   thread constructs its own [`client::StageRuntime`]; only host tensors
//!   cross threads.
//! - [`params`] — deterministic parameter initialisation from manifest
//!   specs, plus binary checkpoint save/load.

pub mod artifacts;
pub mod client;
pub mod params;
pub mod tensor;

pub use artifacts::{ExitMeta, Manifest, ParamSpec, StageMeta};
pub use client::{Executable, StageRuntime};
pub use tensor::{HostTensor, IntTensor};
