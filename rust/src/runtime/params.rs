//! Parameter initialisation and checkpointing.
//!
//! Parameters are initialised in Rust from the manifest's init specs (the
//! L2 python code never holds weights). Initialisation is deterministic in
//! (seed, parameter name): each tensor gets an RNG stream forked from a
//! hash of its fully-qualified name, so the same seed yields identical
//! weights regardless of stage layout — this is what lets the integration
//! tests compare pipeline-parallel execution against the monolithic
//! reference executable parameter-for-parameter. Tied parameters (same
//! `tie_group`, e.g. the shared unembedding of the paper's Section 2
//! option) receive identical replicas by construction because they are
//! seeded by group name.
//!
//! Checkpoint format (`.eckpt`): magic, then per tensor
//! `name_len u32 | name | rank u32 | dims u64... | f32 data`.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifacts::{Init, Manifest, ParamSpec};
use super::tensor::HostTensor;
use crate::util::rng::Rng;

const MAGIC: &[u8; 8] = b"EELLMCK1";

fn name_tag(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Initialise one tensor. `scope` disambiguates stages ("s0", "s1", ...).
/// Tied parameters are seeded by their group name so replicas agree.
pub fn init_param(seed: u64, scope: &str, spec: &ParamSpec) -> HostTensor {
    let key = match &spec.tie_group {
        Some(g) => format!("tie.{g}"),
        None => format!("{scope}.{}", spec.name),
    };
    let n = spec.numel();
    let data = match spec.init {
        Init::Zeros => vec![0.0; n],
        Init::Ones => vec![1.0; n],
        Init::Normal { std } => {
            let mut rng = Rng::new(seed).fork(name_tag(&key));
            rng.normal_vec(n, std)
        }
    };
    HostTensor::new(spec.shape.clone(), data)
}

/// Initialise all parameters of one stage.
pub fn init_stage(seed: u64, man: &Manifest, stage: usize) -> Vec<HostTensor> {
    man.stages[stage]
        .params
        .iter()
        .map(|sp| init_param(seed, &format!("s{stage}"), sp))
        .collect()
}

/// Initialise the full (stage-concatenated) parameter list — the ordering
/// the monolithic reference executable expects.
pub fn init_full(seed: u64, man: &Manifest) -> Vec<HostTensor> {
    (0..man.stages.len())
        .flat_map(|s| init_stage(seed, man, s))
        .collect()
}

// ---------------------------------------------------------------------------
// Checkpoints
// ---------------------------------------------------------------------------

pub fn save_checkpoint(
    path: &Path,
    named: &[(String, &HostTensor)],
) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&(named.len() as u32).to_le_bytes())?;
    for (name, t) in named {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        for &v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load_checkpoint(path: &Path) -> Result<Vec<(String, HostTensor)>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an EE-LLM checkpoint", path.display());
    }
    let mut u32b = [0u8; 4];
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let nlen = u32::from_le_bytes(u32b) as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).context("checkpoint name utf8")?;
        f.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            f.read_exact(&mut u64b)?;
            shape.push(u64::from_le_bytes(u64b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        out.push((name, HostTensor::new(shape, data)));
    }
    Ok(out)
}

/// Save per-stage params under `s{stage}.{param_name}` keys.
pub fn save_stage_params(
    path: &Path,
    man: &Manifest,
    stage_params: &[Vec<HostTensor>],
) -> Result<()> {
    let mut named = Vec::new();
    for (s, params) in stage_params.iter().enumerate() {
        for (sp, t) in man.stages[s].params.iter().zip(params) {
            named.push((format!("s{s}.{}", sp.name), t));
        }
    }
    save_checkpoint(path, &named)
}

/// Load per-stage params saved by [`save_stage_params`].
pub fn load_stage_params(
    path: &Path,
    man: &Manifest,
) -> Result<Vec<Vec<HostTensor>>> {
    let flat = load_checkpoint(path)?;
    let map: std::collections::BTreeMap<String, HostTensor> =
        flat.into_iter().collect();
    let mut out = Vec::new();
    for (s, st) in man.stages.iter().enumerate() {
        let mut params = Vec::with_capacity(st.params.len());
        for sp in &st.params {
            let key = format!("s{s}.{}", sp.name);
            let t = map
                .get(&key)
                .with_context(|| format!("checkpoint missing {key}"))?;
            if t.shape != sp.shape {
                bail!("checkpoint {key}: shape {:?} != {:?}", t.shape, sp.shape);
            }
            params.push(t.clone());
        }
        out.push(params);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Init;

    fn spec(name: &str, shape: &[usize], init: Init) -> ParamSpec {
        ParamSpec {
            name: name.into(),
            shape: shape.to_vec(),
            init,
            tie_group: None,
        }
    }

    #[test]
    fn init_is_deterministic_and_name_dependent() {
        let a = init_param(1, "s0", &spec("w", &[8, 8], Init::Normal { std: 0.02 }));
        let b = init_param(1, "s0", &spec("w", &[8, 8], Init::Normal { std: 0.02 }));
        let c = init_param(1, "s0", &spec("w2", &[8, 8], Init::Normal { std: 0.02 }));
        assert_eq!(a, b);
        assert_ne!(a, c);
        let d = init_param(2, "s0", &spec("w", &[8, 8], Init::Normal { std: 0.02 }));
        assert_ne!(a, d);
    }

    #[test]
    fn tied_params_get_identical_replicas() {
        let mut sp1 = spec("exit0.wout", &[16, 4], Init::Normal { std: 0.02 });
        sp1.tie_group = Some("unembed".into());
        let mut sp2 = spec("exit4.wout", &[16, 4], Init::Normal { std: 0.02 });
        sp2.tie_group = Some("unembed".into());
        let a = init_param(7, "s0", &sp1);
        let b = init_param(7, "s3", &sp2);
        assert_eq!(a, b);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("eellm_test_ckpt");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("rt.eckpt");
        let t1 = HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t2 = HostTensor::scalar(7.5);
        save_checkpoint(&path, &[("a".into(), &t1), ("b.x".into(), &t2)])
            .unwrap();
        let back = load_checkpoint(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "a");
        assert_eq!(back[0].1, t1);
        assert_eq!(back[1].1, t2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("eellm_test_ckpt");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("bad.eckpt");
        std::fs::write(&path, b"NOTMAGIC____").unwrap();
        assert!(load_checkpoint(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
