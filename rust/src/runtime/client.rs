//! PJRT client wrapper and the per-stage executable registry.
//!
//! The `xla` crate's types are `Rc`-based and thus `!Send`: every pipeline
//! stage worker thread builds its own [`StageRuntime`] (own PJRT CPU
//! client, own compiled executables) — which also mirrors the paper's
//! topology of one device per pipeline stage. Only [`HostTensor`]s cross
//! thread boundaries.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::artifacts::{Manifest, StageMeta};
use super::tensor::HostTensor;

/// A compiled HLO module plus basic invocation metrics.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub calls: RefCell<u64>,
    pub total_ms: RefCell<f64>,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let bufs = self
            .exe
            .execute::<L>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let out = lit.to_tuple().context("decomposing output tuple")?;
        *self.calls.borrow_mut() += 1;
        *self.total_ms.borrow_mut() += t0.elapsed().as_secs_f64() * 1e3;
        Ok(out)
    }

    /// Execute and convert every output to a host tensor.
    pub fn run_host<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<HostTensor>> {
        self.run(args)?
            .iter()
            .map(HostTensor::from_literal)
            .collect()
    }
}

/// One thread's view of the runtime: a PJRT client plus the compiled
/// executables of a single pipeline stage (or of the monolithic reference).
pub struct StageRuntime {
    pub client: xla::PjRtClient,
    execs: BTreeMap<String, Executable>,
}

impl StageRuntime {
    pub fn cpu() -> Result<StageRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT client")?;
        Ok(StageRuntime { client, execs: BTreeMap::new() })
    }

    /// Compile one HLO text file under a logical name.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let _ = t0;
        self.execs.insert(
            name.to_string(),
            Executable {
                name: name.to_string(),
                exe,
                calls: RefCell::new(0),
                total_ms: RefCell::new(0.0),
            },
        );
        Ok(())
    }

    /// Compile every executable a training worker for `stage` needs.
    pub fn load_stage_training(
        &mut self,
        man: &Manifest,
        stage: &StageMeta,
    ) -> Result<()> {
        for key in ["fwd", "bwd", "eval", "adam", "sqsum"] {
            self.load(key, &man.exec_path(stage.exec(key)?))?;
        }
        Ok(())
    }

    /// Compile every executable an inference worker for `stage` needs.
    pub fn load_stage_inference(
        &mut self,
        man: &Manifest,
        stage: &StageMeta,
    ) -> Result<()> {
        for w in &man.decode_widths {
            let key = format!("decode_w{w}");
            self.load(&key, &man.exec_path(stage.exec(&key)?))?;
        }
        for e in &stage.exits {
            let key = format!("head{}", e.layer);
            self.load(&key, &man.exec_path(stage.exec(&key)?))?;
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.execs
            .get(name)
            .with_context(|| format!("executable {name:?} not loaded"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    /// (name, calls, total_ms) for every loaded executable — profile data.
    pub fn profile(&self) -> Vec<(String, u64, f64)> {
        self.execs
            .values()
            .map(|e| (e.name.clone(), *e.calls.borrow(), *e.total_ms.borrow()))
            .collect()
    }
}
