//! Artifact manifest schema (`artifacts/<config>/manifest.json`).
//!
//! The manifest is the L2 -> L3 contract: parameter names/shapes/inits per
//! stage, exit metadata (layer, head kind, default loss weight, tie group),
//! executable filenames, and KV-cache shapes. It is produced by
//! `python/compile/aot.py` and parsed here with the in-repo JSON parser.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum Init {
    Normal { std: f32 },
    Zeros,
    Ones,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
    pub tie_group: Option<String>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> Result<ParamSpec> {
        let name = v.field("name")?.as_str().context("param name")?.into();
        let shape = v.field("shape")?.usize_arr()?;
        let init = match v.field("init")?.as_str().context("init kind")? {
            "normal" => Init::Normal {
                std: v.field("std")?.as_f64().context("std")? as f32,
            },
            "zeros" => Init::Zeros,
            "ones" => Init::Ones,
            other => bail!("unknown init {other:?}"),
        };
        let tie_group =
            v.get("tie_group").and_then(|t| t.as_str()).map(String::from);
        Ok(ParamSpec { name, shape, init, tie_group })
    }
}

#[derive(Debug, Clone)]
pub struct ExitMeta {
    /// Backbone layer the exit is attached after (n_layers = final exit).
    pub layer: usize,
    pub head: String,
    /// Default training loss weight (runtime-overridable).
    pub weight: f32,
    pub is_final: bool,
    /// True iff the exit reads the stage's input hidden state
    /// (Optimization-2 placement; required by the decode engines).
    pub entry: bool,
    /// Indices into the stage param list that feed this exit's head.
    pub head_param_idx: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct StageMeta {
    pub index: usize,
    pub n_params: usize,
    pub params: Vec<ParamSpec>,
    pub exits: Vec<ExitMeta>,
    /// (layers_per_stage, 2, max_seq, n_heads, head_dim)
    pub cache_shape: Vec<usize>,
    pub executables: BTreeMap<String, String>,
}

impl StageMeta {
    pub fn exec(&self, name: &str) -> Result<&str> {
        self.executables
            .get(name)
            .map(|s| s.as_str())
            .with_context(|| format!("stage {} lacks executable {name:?}", self.index))
    }

    pub fn param_bytes(&self) -> usize {
        self.params.iter().map(|p| p.numel() * 4).sum()
    }
}

#[derive(Debug, Clone)]
pub struct ReferenceMeta {
    pub loss_grads: String,
    pub eval: String,
    pub n_params: usize,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub hidden: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub seq: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub microbatch: usize,
    pub pipeline_stages: usize,
    pub tie_embeddings: bool,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub approx_param_count: usize,
    pub decode_widths: Vec<usize>,
    /// Lane-fused batched decode ladder: each entry B names a per-stage
    /// `decode_b{B}_w1` executable stepping B independent width-1
    /// windows (lane-stacked KV caches, per-lane positions) in one XLA
    /// call. Empty on manifests predating lane fusion.
    pub decode_lanes: Vec<usize>,
    pub prefill_width: usize,
    pub stages: Vec<StageMeta>,
    pub reference: Option<ReferenceMeta>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&j, dir)
    }

    /// Load a named config from an artifacts root directory.
    pub fn load_config(artifacts_root: &Path, name: &str) -> Result<Manifest> {
        Manifest::load(&artifacts_root.join(name))
    }

    fn from_json(j: &Json, dir: &Path) -> Result<Manifest> {
        let m = j.field("model")?;
        let model = ModelMeta {
            hidden: m.field("hidden")?.as_usize().context("hidden")?,
            n_layers: m.field("n_layers")?.as_usize().context("n_layers")?,
            n_heads: m.field("n_heads")?.as_usize().context("n_heads")?,
            head_dim: m.field("head_dim")?.as_usize().context("head_dim")?,
            seq: m.field("seq")?.as_usize().context("seq")?,
            max_seq: m.field("max_seq")?.as_usize().context("max_seq")?,
            vocab: m.field("vocab")?.as_usize().context("vocab")?,
            microbatch: m
                .field("microbatch")?
                .as_usize()
                .context("microbatch")?,
            pipeline_stages: m
                .field("pipeline_stages")?
                .as_usize()
                .context("pipeline_stages")?,
            tie_embeddings: m
                .field("tie_embeddings")?
                .as_bool()
                .context("tie_embeddings")?,
        };

        let mut stages = Vec::new();
        for sj in j.field("stages")?.as_arr().context("stages")? {
            let params = sj
                .field("params")?
                .as_arr()
                .context("params")?
                .iter()
                .map(ParamSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            let exits = sj
                .field("exits")?
                .as_arr()
                .context("exits")?
                .iter()
                .map(|e| {
                    Ok(ExitMeta {
                        layer: e.field("layer")?.as_usize().context("layer")?,
                        head: e
                            .field("head")?
                            .as_str()
                            .context("head")?
                            .into(),
                        weight: e.field("weight")?.as_f64().context("weight")?
                            as f32,
                        is_final: e
                            .field("final")?
                            .as_bool()
                            .context("final")?,
                        entry: e.field("entry")?.as_bool().context("entry")?,
                        head_param_idx: e
                            .field("head_param_idx")?
                            .usize_arr()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let executables = sj
                .field("executables")?
                .as_obj()
                .context("executables")?
                .iter()
                .map(|(k, v)| {
                    Ok((k.clone(), v.as_str().context("exec path")?.to_string()))
                })
                .collect::<Result<BTreeMap<_, _>>>()?;
            stages.push(StageMeta {
                index: sj.field("index")?.as_usize().context("index")?,
                n_params: sj
                    .field("n_params")?
                    .as_usize()
                    .context("n_params")?,
                params,
                exits,
                cache_shape: sj.field("cache_shape")?.usize_arr()?,
                executables,
            });
        }

        let reference = match j.field("reference")? {
            Json::Null => None,
            r => Some(ReferenceMeta {
                loss_grads: r
                    .field("loss_grads")?
                    .as_str()
                    .context("loss_grads")?
                    .into(),
                eval: r.field("eval")?.as_str().context("eval")?.into(),
                n_params: r
                    .field("n_params")?
                    .as_usize()
                    .context("ref n_params")?,
            }),
        };

        let man = Manifest {
            name: j.field("name")?.as_str().context("name")?.into(),
            dir: dir.to_path_buf(),
            model,
            approx_param_count: j
                .field("approx_param_count")?
                .as_usize()
                .context("approx_param_count")?,
            decode_widths: j.field("decode_widths")?.usize_arr()?,
            // Optional: manifests built before lane fusion lack the key
            // (and decode fine, solo-only).
            decode_lanes: match j.get("decode_lanes") {
                Some(v) => v.usize_arr()?,
                None => Vec::new(),
            },
            prefill_width: j
                .field("prefill_width")?
                .as_usize()
                .context("prefill_width")?,
            stages,
            reference,
        };
        man.validate()?;
        Ok(man)
    }

    fn validate(&self) -> Result<()> {
        if self.stages.len() != self.model.pipeline_stages {
            bail!("manifest stage count mismatch");
        }
        for (i, st) in self.stages.iter().enumerate() {
            if st.index != i {
                bail!("stage index mismatch at {i}");
            }
            if st.params.len() != st.n_params {
                bail!("stage {i}: n_params mismatch");
            }
            for e in &st.exits {
                for &pi in &e.head_param_idx {
                    if pi >= st.params.len() {
                        bail!("stage {i}: head param idx out of range");
                    }
                }
            }
        }
        // The final exit must be the last exit of the last stage.
        let last = self.stages.last().unwrap();
        match last.exits.last() {
            Some(e) if e.is_final => {}
            _ => bail!("last stage lacks final exit"),
        }
        // Any non-empty width set is servable: the sequential engine's
        // prefill/decode pick from the available widths (the pipelined
        // engine additionally checks for width 1 at generation time).
        if self.decode_widths.is_empty() {
            bail!("manifest lists no decode widths");
        }
        // Lane fusion is optional, but a listed lane must fuse something.
        for &b in &self.decode_lanes {
            if b < 2 {
                bail!("decode lane size {b} fuses nothing (need >= 2)");
            }
        }
        // Lane-batched exit heads (`head{L}_b{B}`) are optional per lane
        // size, but any that exist must ride a declared lane size — a
        // stray B would never be dispatched and points at a manifest bug.
        for st in &self.stages {
            for e in &st.exits {
                let prefix = format!("head{}_b", e.layer);
                for key in st.executables.keys() {
                    if let Some(b) = key.strip_prefix(&prefix) {
                        let b: usize = b.parse().with_context(|| {
                            format!("stage {}: bad lane suffix {key:?}",
                                    st.index)
                        })?;
                        if !self.decode_lanes.contains(&b) {
                            bail!(
                                "stage {}: batched head {key:?} has no \
                                 matching decode lane size",
                                st.index
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Lane sizes (a subset of `decode_lanes`) for which **every** stage
    /// ships a lane-batched exit-head executable (`head{L}_b{B}`) for
    /// **every** one of its exits — the sizes at which a fused lane
    /// group's exit decisions collapse to one dispatch per exit. Engines
    /// fall back to per-lane solo head calls for sizes missing here
    /// (manifests predating batched heads return empty).
    pub fn head_lanes(&self) -> Vec<usize> {
        self.decode_lanes
            .iter()
            .copied()
            .filter(|b| {
                self.stages.iter().all(|st| {
                    st.exits.iter().all(|e| {
                        st.executables
                            .contains_key(&format!("head{}_b{b}", e.layer))
                    })
                })
            })
            .collect()
    }

    pub fn exec_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// All exits in stage-major order, as (stage, layer, default_weight).
    pub fn exit_order(&self) -> Vec<(usize, usize, f32)> {
        let mut out = Vec::new();
        for st in &self.stages {
            for e in &st.exits {
                out.push((st.index, e.layer, e.weight));
            }
        }
        out
    }

    pub fn total_params(&self) -> usize {
        self.stages
            .iter()
            .flat_map(|s| s.params.iter())
            .map(|p| p.numel())
            .sum()
    }

    /// Map tie-group name -> [(stage, param index)] of its members.
    pub fn tie_groups(&self) -> BTreeMap<String, Vec<(usize, usize)>> {
        let mut out: BTreeMap<String, Vec<(usize, usize)>> = BTreeMap::new();
        for st in &self.stages {
            for (pi, p) in st.params.iter().enumerate() {
                if let Some(g) = &p.tie_group {
                    out.entry(g.clone()).or_default().push((st.index, pi));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_ee_tiny_manifest() {
        let root = artifacts_root();
        if !root.join("ee-tiny").is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let man = Manifest::load_config(&root, "ee-tiny").unwrap();
        assert_eq!(man.model.pipeline_stages, 2);
        assert_eq!(man.stages.len(), 2);
        assert_eq!(man.total_params(), man.approx_param_count);
        assert!(man.reference.is_some());
        // ee-tiny: one early exit (layer 2) + final exit (layer 4).
        assert_eq!(man.exit_order().len(), 2);
        assert!(man.stages[1].exits.last().unwrap().is_final);
        // Freshly built artifacts ship a lane-batched exit head for
        // every exit at every declared lane size.
        assert_eq!(man.head_lanes(), man.decode_lanes);
    }

    #[test]
    fn tie_groups_cover_tied_config() {
        let root = artifacts_root();
        if !root.join("ee-tiny-tied").is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let man = Manifest::load_config(&root, "ee-tiny-tied").unwrap();
        let groups = man.tie_groups();
        let g = groups.get("unembed").expect("unembed group");
        // embed.tok + one head per exit (2 early + final) = 4 members.
        assert_eq!(g.len(), 4);
        // All members share a shape.
        let shapes: Vec<_> = g
            .iter()
            .map(|&(s, p)| man.stages[s].params[p].shape.clone())
            .collect();
        assert!(shapes.windows(2).all(|w| w[0] == w[1]));
    }
}
