//! Host tensors: the data representation that crosses pipeline P2P channels
//! and converts to/from `xla::Literal` at stage boundaries.

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        HostTensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn full(shape: &[usize], v: f32) -> HostTensor {
        HostTensor { shape: shape.to_vec(), data: vec![v; numel(shape)] }
    }

    pub fn scalar(v: f32) -> HostTensor {
        HostTensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        // Single-copy path (perf pass §L3-1): building via
        // vec1().reshape() copies twice and ran at ~1.2 GiB/s; the
        // shape+raw-bytes constructor copies once.
        let bytes = unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * 4,
            )
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.shape,
            bytes,
        )?)
    }

    /// Build an f32 literal straight from a borrowed slice — the
    /// zero-extra-copy twin of [`HostTensor::to_literal`] for data that
    /// lives inside a larger host buffer (one lane's rows of a
    /// lane-stacked KV cache), so scattering a lane out of a fused group
    /// skips the intermediate owned `Vec`.
    pub fn literal_from_slice(
        shape: &[usize],
        data: &[f32],
    ) -> Result<xla::Literal> {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        let bytes = unsafe {
            std::slice::from_raw_parts(
                data.as_ptr() as *const u8,
                data.len() * 4,
            )
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            shape,
            bytes,
        )?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().context("literal to f32 vec")?;
        if data.len() != numel(&dims) {
            bail!("literal size {} != shape {:?}", data.len(), dims);
        }
        Ok(HostTensor { shape: dims, data })
    }

    pub fn sq_sum(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// In-place axpy: self += alpha * other (for gradient accumulation and
    /// tied-parameter all-reduce).
    pub fn axpy(&mut self, alpha: f32, other: &HostTensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> IntTensor {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        IntTensor { shape, data }
    }

    pub fn scalar(v: i32) -> IntTensor {
        IntTensor { shape: vec![], data: vec![v] }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let bytes = unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * 4,
            )
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &self.shape,
            bytes,
        )?)
    }
}

/// Softmax over a logits slice (sampling happens host-side, in Rust).
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / z).collect()
}

/// (argmax index, max probability) of a probability vector.
pub fn argmax_prob(probs: &[f32]) -> (usize, f32) {
    let mut best = 0;
    let mut bp = f32::NEG_INFINITY;
    for (i, &p) in probs.iter().enumerate() {
        if p > bp {
            bp = p;
            best = i;
        }
    }
    (best, bp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let a = softmax(&[1.0, 2.0, 3.0]);
        let b = softmax(&[1001.0, 1002.0, 1003.0]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn argmax_picks_peak() {
        let (i, p) = argmax_prob(&[0.1, 0.7, 0.2]);
        assert_eq!(i, 1);
        assert!((p - 0.7).abs() < 1e-6);
    }

    #[test]
    fn axpy_accumulates() {
        let mut a = HostTensor::new(vec![2], vec![1.0, 2.0]);
        let b = HostTensor::new(vec![2], vec![10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![6.0, 12.0]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn rejects_bad_shape() {
        HostTensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn literal_from_slice_round_trips() {
        let buf: Vec<f32> = (0..8).map(|i| i as f32).collect();
        // A slice out of the middle of a larger buffer, no owned copy.
        let lit = HostTensor::literal_from_slice(&[2, 2], &buf[2..6]).unwrap();
        let t = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.data, &buf[2..6]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn literal_from_slice_rejects_bad_shape() {
        let _ = HostTensor::literal_from_slice(&[3], &[0.0; 2]);
    }
}
