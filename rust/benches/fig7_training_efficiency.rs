//! Figure 7 reproduction: training time per iteration and peak GPU memory
//! vs the number of added early exits (0-3), across model sizes and
//! (TP, PP) layouts — via the calibrated discrete-event schedule simulator.
//!
//! Exits are added in the paper's order: (1) 1/4 depth, (2) 1/2 depth,
//! (3) on the embedding output (always stage 0). The expected shape:
//! with PP enabled, time grows by ~k*(f_EE+b_EE) (slow) and memory is flat
//! until exit 3 lands on stage 0; without PP, both grow with every exit.

#[path = "bench_util.rs"]
mod bench_util;

use eellm::schedule::costs::{CostModel, PAPER_MODELS};
use eellm::schedule::plan::{EeOptions, Plan};
use eellm::schedule::sim::Simulator;
use eellm::util::table::Table;

/// Stage layout of the first k paper exits for a P-stage pipeline.
fn exits_for(k: usize, pp: usize) -> Vec<usize> {
    let mut e = vec![0usize; pp];
    // 1/4 depth -> beginning of stage P/4; 1/2 depth -> stage P/2
    // (Optimization 2 placement); third exit -> embedding output, stage 0.
    let places = [pp / 4, pp / 2, 0];
    for &p in places.iter().take(k) {
        e[p.min(pp - 1)] += 1;
    }
    e
}

fn main() {
    let layouts: &[(&str, usize, usize)] = &[
        ("1.3B", 1, 4),
        ("1.3B", 4, 1), // no pipeline parallelism
        ("7B", 1, 4),
        ("7B", 2, 4),
        ("13B", 4, 4),
        ("30B", 8, 4),
        ("30B", 4, 8),
    ];
    let mut table = Table::new(
        "Figure 7: time/iteration and peak memory vs #early exits",
        &[
            "model", "tp", "pp", "exits", "time/iter", "d_time", "peak mem GiB",
            "d_mem",
        ],
    );
    for &(name, tp, pp, ) in layouts {
        let dims = PAPER_MODELS.iter().find(|d| d.name == name).unwrap();
        let cm = CostModel::a100(dims, pp, tp);
        let m = 2 * pp.max(2);
        let sim = Simulator::new(&cm);
        let mut base: Option<(f64, f64)> = None;
        for k in 0..=3usize {
            let exits = exits_for(k, pp);
            let plan = Plan::one_f_one_b(
                pp,
                m,
                EeOptions::with_exits(exits.clone(), true),
            );
            let r = sim.run(&plan);
            let t = r.iteration_time;
            let mem = r.peak_memory_overall(cm.alpha);
            let (t0, m0) = *base.get_or_insert((t, mem));
            table.row(vec![
                name.into(),
                tp.to_string(),
                pp.to_string(),
                k.to_string(),
                format!("{:.0}ms", t * 1e3),
                format!("{:+.1}%", 100.0 * (t / t0 - 1.0)),
                bench_util::gib(mem),
                format!("{:+.1}%", 100.0 * (mem / m0 - 1.0)),
            ]);
        }
    }
    table.emit("fig7");

    // Shape assertions (the paper's qualitative claims).
    let dims = &PAPER_MODELS[1]; // 7B
    let cm = CostModel::a100(dims, 4, 1);
    let sim = Simulator::new(&cm);
    let t = |k: usize| {
        sim.run(&Plan::one_f_one_b(
            4,
            8,
            EeOptions::with_exits(exits_for(k, 4), true),
        ))
    };
    let r0 = t(0);
    let r2 = t(2);
    let r3 = t(3);
    // With PP: adding 2 middle exits costs exactly 2*(f_EE+b_EE)...
    let want = 2.0 * (cm.f_ee + cm.b_ee);
    assert!(
        ((r2.iteration_time - r0.iteration_time) - want).abs() / want < 0.05,
        "middle-exit overhead mismatch"
    );
    // ...and leaves peak memory unchanged; exit 3 (stage 0) raises it.
    assert_eq!(
        r0.peak_memory_overall(cm.alpha),
        r2.peak_memory_overall(cm.alpha)
    );
    assert!(
        r3.peak_memory_overall(cm.alpha) > r2.peak_memory_overall(cm.alpha)
    );
    // Without PP, memory grows with every exit.
    let cm1 = CostModel::a100(dims, 1, 4);
    let sim1 = Simulator::new(&cm1);
    let m1 = |k: usize| {
        sim1.run(&Plan::one_f_one_b(
            1,
            2,
            EeOptions::with_exits(vec![k], true),
        ))
        .peak_memory_overall(cm1.alpha)
    };
    assert!(m1(1) > m1(0) && m1(2) > m1(1));
    println!("fig7 shape checks OK");
}
