//! Shared helpers for the offline bench harness (criterion is unavailable
//! offline; each bench is a `harness = false` binary that prints the
//! paper-table analogue via `util::table` and exits non-zero on failure).

#![allow(dead_code)]

use std::path::PathBuf;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{Corpus, CorpusSpec};
use eellm::inference::ModelState;
use eellm::runtime::artifacts::Manifest;
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

pub fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn manifest(name: &str) -> Option<Manifest> {
    let root = artifacts_root();
    if !root.join(name).join("manifest.json").is_file() {
        eprintln!("SKIP: artifacts for {name} missing (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load_config(&root, name).expect("manifest"))
}

/// Benches that need a trained model share one cached checkpoint per
/// config; train it on first use (deterministic).
pub fn trained_state(config: &str, steps: usize) -> Option<ModelState> {
    let man = manifest(config)?;
    let dir = artifacts_root().join("runs");
    let _ = std::fs::create_dir_all(&dir);
    let ckpt = dir.join(format!("{config}-bench-{steps}.eckpt"));
    if ckpt.is_file() {
        if let Ok(s) = ModelState::from_checkpoint(man.clone(), &ckpt) {
            eprintln!("[bench] reusing checkpoint {}", ckpt.display());
            return Some(s);
        }
    }
    eprintln!("[bench] training {config} for {steps} steps (cached after)...");
    let corpus = corpus();
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, steps / 10 + 1, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .expect("trainer");
    for i in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..4).map(|_| ds.next_microbatch()).collect();
        let st = trainer.train_step(&batches, &[]).expect("step");
        if i % 25 == 0 {
            eprintln!(
                "[bench]   step {i}: final loss {:.3}",
                st.losses.last().unwrap()
            );
        }
    }
    trainer.save_checkpoint(&ckpt).expect("save");
    let params = trainer.params().expect("params");
    trainer.shutdown();
    Some(ModelState { man, stage_params: params })
}

/// The corpus every model-based bench trains/evaluates on.
pub fn corpus() -> Corpus {
    Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 12,
        target_bytes: 300_000,
    })
}

/// Reduced iteration counts when BENCH_FAST is set (CI smoke).
pub fn fast() -> bool {
    std::env::var("BENCH_FAST").is_ok()
}

pub fn gib(bytes: f64) -> String {
    format!("{:.2}", bytes / (1u64 << 30) as f64)
}
