//! Appendix C.2 reproduction: filling explicit pipeline bubbles with
//! partial microbatches.
//!
//! Three views:
//!  1. schedule: the simulator packs the planned fills into the 1F1B
//!     bubbles with zero iteration-time overhead and higher utilisation;
//!  2. statistics: Proposition C.2's variance reduction, Monte-Carlo vs
//!     closed form, across correlation regimes;
//!  3. system: the real pipeline trainer with Part-2 fills enabled makes
//!     gradient contributions from the extra microbatches without
//!     corrupting the loss trajectory.

#[path = "bench_util.rs"]
mod bench_util;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::schedule::costs::{CostModel, PAPER_MODELS};
use eellm::schedule::fill::{
    monte_carlo_variance_reduction, prop_c2_variance_reduction, FillPlan,
};
use eellm::schedule::plan::{EeOptions, Plan};
use eellm::schedule::sim::Simulator;
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};
use eellm::util::rng::Rng;
use eellm::util::table::Table;

fn main() {
    // --- 1. schedule-level packing.
    let mut table = Table::new(
        "Figure 4 / Appendix C.2: bubble filling in the 1F1B schedule",
        &["model", "pp", "fills", "iter time", "utilisation", "fill ops run"],
    );
    for &(name, pp) in &[("7B", 4usize), ("7B", 8), ("30B", 8)] {
        let dims = PAPER_MODELS.iter().find(|d| d.name == name).unwrap();
        let cm = CostModel::a100(dims, pp, 1);
        let sim = Simulator::new(&cm);
        let m = 2 * pp;
        for fills in [0usize, Plan::max_fill(pp, 2.0)] {
            let mut plan = Plan::one_f_one_b(pp, m, EeOptions::none(pp));
            if fills > 0 {
                plan.add_bubble_fill(fills, fills, 2.0);
            }
            let r = sim.run(&plan);
            let ran: usize = r
                .timelines
                .iter()
                .flat_map(|t| t.ops.iter())
                .filter(|p| {
                    matches!(
                        p.op.kind,
                        eellm::schedule::plan::OpKind::FillFwd(_)
                            | eellm::schedule::plan::OpKind::FillBwd(_)
                    )
                })
                .count();
            table.row(vec![
                name.into(),
                pp.to_string(),
                fills.to_string(),
                format!("{:.0}ms", r.iteration_time * 1e3),
                format!("{:.1}%", 100.0 * (1.0 - r.bubble_fraction())),
                ran.to_string(),
            ]);
        }
        // No-overhead assertion.
        let base = sim
            .run(&Plan::one_f_one_b(pp, m, EeOptions::none(pp)))
            .iteration_time;
        let mut plan = Plan::one_f_one_b(pp, m, EeOptions::none(pp));
        plan.add_bubble_fill(
            Plan::max_fill(pp, 2.0),
            Plan::max_fill(pp, 2.0),
            2.0,
        );
        let filled = sim.run(&plan).iteration_time;
        assert!(filled <= base * (1.0 + 1e-9), "fill overhead {filled} vs {base}");
    }
    table.emit("figc_schedule");

    // --- 2. Prop C.2 variance reduction.
    let mut vt = Table::new(
        "Proposition C.2: gradient-variance reduction (N=8 microbatches)",
        &["corr(a,b)", "MC var(e)", "MC var(e+)", "MC delta", "closed form"],
    );
    let mut rng = Rng::new(77);
    let trials = if bench_util::fast() { 20_000 } else { 200_000 };
    for rho in [0.8f64, 0.4, 0.0, -0.4, -0.8] {
        let (v, vp) = monte_carlo_variance_reduction(&mut rng, 8, rho, trials);
        let want = prop_c2_variance_reduction(1.0, rho, 8);
        vt.row(vec![
            format!("{rho}"),
            format!("{v:.4}"),
            format!("{vp:.4}"),
            format!("{:+.4}", v - vp),
            format!("{want:+.4}"),
        ]);
    }
    vt.emit("figc_variance");

    // --- 3. real trainer with fills.
    let Some(man) = bench_util::manifest("ee-small") else { return };
    let corpus = bench_util::corpus();
    let steps = if bench_util::fast() { 5 } else { 15 };
    let mut rt = Table::new(
        "Real pipeline trainer: Part-2 bubble fills (ee-small, P=4)",
        &["fills/iter", "final loss", "mean s/iter", "fill contributions"],
    );
    for fills in [0usize, 2] {
        let mut ds = Dataset::from_corpus(
            &corpus,
            man.model.seq,
            man.model.microbatch,
            3,
        );
        let mut trainer = PipelineTrainer::new(
            man.clone(),
            TrainerOptions {
                seed: 42,
                lr: LrSchedule::cosine(1e-3, 2, steps),
                grad_clip: 1.0,
                loss_weights: LossWeightSchedule::Constant,
                total_steps: steps,
                bubble_fill: fills,
                bf_ratio: 2.0,
            },
        )
        .expect("trainer");
        let mut last = 0.0;
        let mut secs = 0.0;
        let mut contrib = 0;
        for _ in 0..steps {
            let batches: Vec<TrainBatch> =
                (0..4).map(|_| ds.next_microbatch()).collect();
            let fb: Vec<TrainBatch> =
                (0..fills).map(|_| ds.next_microbatch()).collect();
            let st = trainer.train_step(&batches, &fb).expect("step");
            last = *st.losses.last().unwrap();
            secs += st.wall_seconds;
            contrib = st.fill_contributions;
        }
        trainer.shutdown();
        rt.row(vec![
            fills.to_string(),
            format!("{last:.4}"),
            format!("{:.2}", secs / steps as f64),
            contrib.to_string(),
        ]);
        if fills > 0 {
            assert!(contrib > 0, "fills were planned but contributed nothing");
        }
        assert!(last.is_finite() && last < 6.0, "loss diverged: {last}");
    }
    rt.emit("figc_trainer");
    let plan = FillPlan::plan(4, 2.0, 2);
    println!(
        "fill plan for P=4, b/f=2: k1={} k2={} depths {:?}",
        plan.k1,
        plan.k2,
        (0..plan.k2).map(|j| plan.part2_bwd_depth(4, j)).collect::<Vec<_>>()
    );
    println!("figc shape checks OK");
}
