//! Figure 9 reproduction: per-stage forward/backward time and peak memory
//! for the 7B model (P=4), standard vs early-exit with one minimalistic
//! exit per middle stage and all optimisations applied.
//!
//! Expected shape: the standard model's last stage is the compute
//! straggler (implicit bubble) and the first stage the memory bottleneck;
//! the early-exit variant balances middle-stage compute up to the last
//! stage while leaving per-stage peak memory unchanged.

#[path = "bench_util.rs"]
mod bench_util;

use eellm::schedule::analytic;
use eellm::schedule::costs::{CostModel, PAPER_MODELS};
use eellm::schedule::plan::{EeOptions, Plan};
use eellm::schedule::sim::Simulator;
use eellm::util::table::Table;

fn main() {
    let dims = PAPER_MODELS.iter().find(|d| d.name == "7B").unwrap();
    let pp = 4;
    let cm = CostModel::a100(dims, pp, 1);
    let sim = Simulator::new(&cm);

    let standard = vec![0usize; pp];
    let ee = vec![0usize, 1, 1, 0];

    let mut table = Table::new(
        "Figure 9: per-stage forward/backward time and peak memory (7B, P=4)",
        &["variant", "stage", "fwd ms", "bwd ms", "peak mem GiB"],
    );
    for (variant, exits) in [("standard", &standard), ("early-exit", &ee)] {
        let plan = Plan::one_f_one_b(
            pp,
            2 * pp,
            EeOptions::with_exits(exits.clone(), true),
        );
        let r = sim.run(&plan);
        for s in 0..pp {
            // Deferred exit forward runs inside the backward step, matching
            // the paper's Figure 9 annotation.
            let fwd = cm.stage_fwd(s, 0);
            let bwd = cm.stage_bwd(s, exits[s], exits[s]);
            table.row(vec![
                variant.into(),
                s.to_string(),
                format!("{:.1}", fwd * 1e3),
                format!("{:.1}", bwd * 1e3),
                bench_util::gib(r.peak_memory(cm.alpha, s)),
            ]);
        }
    }
    table.emit("fig9");

    // Shape checks.
    // Standard: last stage strictly slower than middle stages.
    assert!(cm.stage_fwd(1, 0) < cm.stage_fwd(pp - 1, 0));
    // EE: middle-stage fwd+bwd (with one exit) ~ last stage's.
    let mid = cm.stage_fwd(1, 0) + cm.stage_bwd(1, 1, 1);
    let last = cm.stage_fwd(pp - 1, 0) + cm.stage_bwd(pp - 1, 0, 0);
    assert!((mid - last).abs() / last < 0.02, "mid {mid} vs last {last}");
    // Memory: stage 0 is the bottleneck in both variants, unchanged by EE.
    let m_std: Vec<f64> =
        (0..pp).map(|s| analytic::stage_memory(&cm, &standard, s)).collect();
    let m_ee: Vec<f64> =
        (0..pp).map(|s| analytic::stage_memory(&cm, &ee, s)).collect();
    assert_eq!(m_std[0], m_ee[0]);
    assert!(m_ee.iter().all(|&m| m <= m_ee[0]));
    println!("fig9 shape checks OK");
}
