//! Figure 8 reproduction: evaluation score and relative speedup vs the
//! confidence threshold, on the six-task HELM-analogue suite, using a
//! trained early-exit model and the KV-recomputation engine.
//!
//! Speedup is measured against the same engine at threshold 1.0 (the
//! full-model baseline, the paper's denominator). Expected shape: speedup
//! grows as the threshold decreases, with scores comparable to the
//! baseline at moderate thresholds and degrading at aggressive ones.

#[path = "bench_util.rs"]
mod bench_util;

use eellm::data::tasks;
use eellm::eval::harness::evaluate_task;
use eellm::inference::{ExitPolicy, SequentialEngine};
use eellm::util::table::Table;

fn main() {
    let steps = if bench_util::fast() { 60 } else { 400 };
    let Some(state) = bench_util::trained_state("ee-tiny", steps) else {
        return;
    };
    let n_layers = state.man.model.n_layers;
    let corpus = bench_util::corpus();
    let n_per = if bench_util::fast() { 4 } else { 10 };
    let mut suite = tasks::all_tasks(&corpus, n_per, 5);
    // Keep only examples that fit the KV-cache capacity (byte tokenizer:
    // prompt bytes + BOS + generation budget).
    let cap = state.man.model.max_seq;
    for t in &mut suite {
        let budget = t.max_new_tokens;
        t.examples.retain(|e| e.prompt.len() + budget + 4 < cap);
        assert!(!t.examples.is_empty(), "no {} examples fit cap {cap}", t.name);
    }

    let thresholds = [1.0f32, 0.8, 0.6, 0.4, 0.2];
    let mut table = Table::new(
        "Figure 8: score and relative speedup vs confidence threshold",
        &["task", "metric", "threshold", "score", "speedup", "work-speedup", "early%"],
    );

    let mut mean_speedup_at = vec![0f64; thresholds.len()];
    for task in &suite {
        let mut base_time = 0.0f64;
        for (ti, &tau) in thresholds.iter().enumerate() {
            let mut eng =
                SequentialEngine::new(state.clone(), ExitPolicy::confidence(tau)).expect("engine");
            let mut early = 0.0f64;
            let mut toks = 0usize;
            let mut stages_run = 0usize;
            let score = {
                // Wrap to also collect exit stats.
                let mut gen = |prompt: &str, max: usize| {
                    let out = eng.generate_text(prompt, max).expect("gen");
                    early += out
                        .stats
                        .counts
                        .iter()
                        .filter(|c| c.0 < n_layers)
                        .map(|c| c.1)
                        .sum::<usize>() as f64;
                    toks += out.stats.total();
                    // Stages executed per emitted token (work proxy that
                    // transfers to multi-device hardware, where the
                    // paper's >=2x wall-clock speedups live).
                    let p = state.man.model.pipeline_stages;
                    let lps = n_layers / p;
                    for (l, c) in &out.stats.counts {
                        let s = if *l >= n_layers { p } else { l / lps };
                        stages_run += s.max(1) * c;
                    }
                    (out.text, out.seconds)
                };
                evaluate_task(task, &mut gen)
            };
            if tau >= 1.0 {
                base_time = score.total_seconds;
            }
            let speedup = base_time / score.total_seconds.max(1e-9);
            mean_speedup_at[ti] += speedup / suite.len() as f64;
            let p = state.man.model.pipeline_stages;
            let work_speedup =
                (toks * p) as f64 / (stages_run.max(1)) as f64;
            table.row(vec![
                task.name.into(),
                format!("{:?}", task.metric),
                format!("{tau}"),
                format!("{:.3}", score.score),
                format!("{speedup:.2}x"),
                format!("{work_speedup:.2}x"),
                format!("{:.0}%", 100.0 * early / toks.max(1) as f64),
            ]);
        }
    }
    table.emit("fig8");

    println!(
        "mean speedup by threshold {:?}: {:?}",
        thresholds,
        mean_speedup_at
            .iter()
            .map(|s| format!("{s:.2}x"))
            .collect::<Vec<_>>()
    );
    // Shape: speedup is (weakly) increasing as the threshold decreases,
    // and the most aggressive threshold is strictly faster than baseline.
    assert!(
        mean_speedup_at.last().unwrap() > &1.05,
        "no speedup at the lowest threshold: {mean_speedup_at:?}"
    );
    println!("fig8 shape checks OK");
}
