//! Runtime microbenchmarks: the L3 hot-path costs that the perf pass
//! optimises — literal construction, executable invocation overhead, stage
//! forward/decode throughput, and channel round-trips.

#[path = "bench_util.rs"]
mod bench_util;

use eellm::metrics::bench_loop;
use eellm::runtime::client::StageRuntime;
use eellm::runtime::params;
use eellm::runtime::tensor::{HostTensor, IntTensor};
use eellm::training::channel::{tagged_channel, Tag};
use eellm::util::table::Table;

fn main() {
    let Some(man) = bench_util::manifest("ee-tiny") else { return };
    let m = &man.model;
    let iters = if bench_util::fast() { 20 } else { 200 };

    let mut table = Table::new(
        "Runtime microbenchmarks (ee-tiny)",
        &["op", "mean", "p-ish max", "per-unit"],
    );

    // Literal conversion bandwidth.
    let big = HostTensor::zeros(&[1024, 1024]);
    let s = bench_loop(3, iters, || {
        let _ = big.to_literal().unwrap();
    });
    table.row(vec![
        "HostTensor->Literal 4MiB".into(),
        format!("{:.3}ms", s.mean() * 1e3),
        format!("{:.3}ms", s.max * 1e3),
        format!("{:.2} GiB/s", 4.0 / 1024.0 / s.mean()),
    ]);

    // Stage-0 training forward.
    let st = &man.stages[0];
    let mut rt = StageRuntime::cpu().unwrap();
    rt.load_stage_training(&man, st).unwrap();
    rt.load_stage_inference(&man, st).unwrap();
    let ps = params::init_stage(1, &man, 0);
    let plits: Vec<xla::Literal> =
        ps.iter().map(|p| p.to_literal().unwrap()).collect();
    let tokens = IntTensor::new(
        vec![m.microbatch, m.seq],
        vec![65; m.microbatch * m.seq],
    );

    let s = bench_loop(3, iters, || {
        let t = tokens.to_literal().unwrap();
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&t);
        let _ = rt.get("fwd").unwrap().run(&args).unwrap();
    });
    let toks = (m.microbatch * m.seq) as f64;
    table.row(vec![
        "stage0 fwd (train)".into(),
        format!("{:.3}ms", s.mean() * 1e3),
        format!("{:.3}ms", s.max * 1e3),
        format!("{:.0} tok/s", toks / s.mean()),
    ]);

    // Width-1 decode step.
    let cache = HostTensor::zeros(&st.cache_shape);
    let s = bench_loop(3, iters, || {
        let tok = IntTensor::new(vec![1], vec![66]).to_literal().unwrap();
        let c = cache.to_literal().unwrap();
        let pos = IntTensor::scalar(0).to_literal().unwrap();
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&tok);
        args.push(&c);
        args.push(&pos);
        let _ = rt.get("decode_w1").unwrap().run(&args).unwrap();
    });
    table.row(vec![
        "stage0 decode_w1 (incl cache copy)".into(),
        format!("{:.3}ms", s.mean() * 1e3),
        format!("{:.3}ms", s.max * 1e3),
        format!("{:.0} steps/s", 1.0 / s.mean()),
    ]);

    // Decode without re-converting the cache each call (device-resident
    // pattern candidate for the perf pass).
    let c_lit = cache.to_literal().unwrap();
    let s = bench_loop(3, iters, || {
        let tok = IntTensor::new(vec![1], vec![66]).to_literal().unwrap();
        let pos = IntTensor::scalar(0).to_literal().unwrap();
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&tok);
        args.push(&c_lit);
        args.push(&pos);
        let _ = rt.get("decode_w1").unwrap().run(&args).unwrap();
    });
    table.row(vec![
        "stage0 decode_w1 (cached cache literal)".into(),
        format!("{:.3}ms", s.mean() * 1e3),
        format!("{:.3}ms", s.max * 1e3),
        format!("{:.0} steps/s", 1.0 / s.mean()),
    ]);

    // Channel round-trip with a seq-size hidden tensor.
    let (tx, mut rx) = tagged_channel();
    let hidden = HostTensor::zeros(&[m.microbatch, m.seq, m.hidden]);
    let s = bench_loop(10, iters * 10, || {
        tx.send(Tag::Fwd(0), hidden.clone());
        let _ = rx.recv(Tag::Fwd(0));
    });
    table.row(vec![
        "P2P channel round-trip (hidden tensor)".into(),
        format!("{:.1}us", s.mean() * 1e6),
        format!("{:.1}us", s.max * 1e6),
        format!(
            "{:.2} GiB/s",
            hidden.bytes() as f64 / (1u64 << 30) as f64 / s.mean()
        ),
    ]);

    table.emit("runtime_micro");
}
