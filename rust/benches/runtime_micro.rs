//! Runtime microbenchmarks: the L3 hot-path costs that the perf pass
//! optimises — literal construction, executable invocation overhead, stage
//! forward/decode throughput, and channel round-trips.

#[path = "bench_util.rs"]
mod bench_util;

use eellm::metrics::bench_loop;
use eellm::runtime::client::StageRuntime;
use eellm::runtime::params;
use eellm::runtime::tensor::{HostTensor, IntTensor};
use eellm::training::channel::{tagged_channel, Tag};
use eellm::util::table::Table;

fn main() {
    let Some(man) = bench_util::manifest("ee-tiny") else { return };
    let m = &man.model;
    let iters = if bench_util::fast() { 20 } else { 200 };

    let mut table = Table::new(
        "Runtime microbenchmarks (ee-tiny)",
        &["op", "mean", "p-ish max", "per-unit"],
    );

    // Literal conversion bandwidth.
    let big = HostTensor::zeros(&[1024, 1024]);
    let s = bench_loop(3, iters, || {
        let _ = big.to_literal().unwrap();
    });
    table.row(vec![
        "HostTensor->Literal 4MiB".into(),
        format!("{:.3}ms", s.mean() * 1e3),
        format!("{:.3}ms", s.max * 1e3),
        format!("{:.2} GiB/s", 4.0 / 1024.0 / s.mean()),
    ]);

    // Stage-0 training forward.
    let st = &man.stages[0];
    let mut rt = StageRuntime::cpu().unwrap();
    rt.load_stage_training(&man, st).unwrap();
    rt.load_stage_inference(&man, st).unwrap();
    let ps = params::init_stage(1, &man, 0);
    let plits: Vec<xla::Literal> =
        ps.iter().map(|p| p.to_literal().unwrap()).collect();
    let tokens = IntTensor::new(
        vec![m.microbatch, m.seq],
        vec![65; m.microbatch * m.seq],
    );

    let s = bench_loop(3, iters, || {
        let t = tokens.to_literal().unwrap();
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&t);
        let _ = rt.get("fwd").unwrap().run(&args).unwrap();
    });
    let toks = (m.microbatch * m.seq) as f64;
    table.row(vec![
        "stage0 fwd (train)".into(),
        format!("{:.3}ms", s.mean() * 1e3),
        format!("{:.3}ms", s.max * 1e3),
        format!("{:.0} tok/s", toks / s.mean()),
    ]);

    // Width-1 decode step.
    let cache = HostTensor::zeros(&st.cache_shape);
    let s = bench_loop(3, iters, || {
        let tok = IntTensor::new(vec![1], vec![66]).to_literal().unwrap();
        let c = cache.to_literal().unwrap();
        let pos = IntTensor::scalar(0).to_literal().unwrap();
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&tok);
        args.push(&c);
        args.push(&pos);
        let _ = rt.get("decode_w1").unwrap().run(&args).unwrap();
    });
    table.row(vec![
        "stage0 decode_w1 (incl cache copy)".into(),
        format!("{:.3}ms", s.mean() * 1e3),
        format!("{:.3}ms", s.max * 1e3),
        format!("{:.0} steps/s", 1.0 / s.mean()),
    ]);

    // Decode without re-converting the cache each call (device-resident
    // pattern candidate for the perf pass).
    let c_lit = cache.to_literal().unwrap();
    let s = bench_loop(3, iters, || {
        let tok = IntTensor::new(vec![1], vec![66]).to_literal().unwrap();
        let pos = IntTensor::scalar(0).to_literal().unwrap();
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&tok);
        args.push(&c_lit);
        args.push(&pos);
        let _ = rt.get("decode_w1").unwrap().run(&args).unwrap();
    });
    table.row(vec![
        "stage0 decode_w1 (cached cache literal)".into(),
        format!("{:.3}ms", s.mean() * 1e3),
        format!("{:.3}ms", s.max * 1e3),
        format!("{:.0} steps/s", 1.0 / s.mean()),
    ]);

    // Fused lane decode, resident vs round-trip: the same
    // `decode_b{B}_w1` executable stepped against a device-resident
    // lane-stacked cache literal (the resident lane-group steady state)
    // vs re-gathering the B per-lane host caches into a fresh stacked
    // literal and scattering the updated caches back out every step
    // (what each fused step paid before residency). The executable work
    // is identical; the delta is pure host<->device cache traffic.
    if let Some(&b) = man.decode_lanes.iter().max() {
        let key = format!("decode_b{b}_w1");
        if let Ok(file) = st.exec(&key) {
            rt.load(&key, &man.exec_path(file)).unwrap();
            let lane_cache = HostTensor::zeros(&st.cache_shape);
            let mut stacked_shape = vec![b];
            stacked_shape.extend_from_slice(&st.cache_shape);
            let elems: usize = st.cache_shape.iter().product();
            let stacked =
                HostTensor::zeros(&stacked_shape).to_literal().unwrap();
            let s_res = bench_loop(3, iters, || {
                let tok = IntTensor::new(vec![b], vec![66; b])
                    .to_literal()
                    .unwrap();
                let pos = IntTensor::new(vec![b], vec![0; b])
                    .to_literal()
                    .unwrap();
                let mut args: Vec<&xla::Literal> = plits.iter().collect();
                args.push(&tok);
                args.push(&stacked);
                args.push(&pos);
                let _ = rt.get(&key).unwrap().run(&args).unwrap();
            });
            table.row(vec![
                format!("stage0 decode_b{b}_w1 (resident stacked cache)"),
                format!("{:.3}ms", s_res.mean() * 1e3),
                format!("{:.3}ms", s_res.max * 1e3),
                format!("{:.0} steps/s", 1.0 / s_res.mean()),
            ]);
            let s_rt = bench_loop(3, iters, || {
                // Gather: B per-lane host caches -> one stacked literal.
                let mut data = Vec::with_capacity(b * elems);
                for _ in 0..b {
                    data.extend_from_slice(&lane_cache.data);
                }
                let gathered = HostTensor::new(stacked_shape.clone(), data)
                    .to_literal()
                    .unwrap();
                let tok = IntTensor::new(vec![b], vec![66; b])
                    .to_literal()
                    .unwrap();
                let pos = IntTensor::new(vec![b], vec![0; b])
                    .to_literal()
                    .unwrap();
                let mut args: Vec<&xla::Literal> = plits.iter().collect();
                args.push(&tok);
                args.push(&gathered);
                args.push(&pos);
                let out = rt.get(&key).unwrap().run(&args).unwrap();
                // Scatter: updated stacked cache -> B per-lane literals.
                let t = HostTensor::from_literal(&out[1]).unwrap();
                for i in 0..b {
                    let _ = HostTensor::literal_from_slice(
                        &st.cache_shape,
                        &t.data[i * elems..(i + 1) * elems],
                    )
                    .unwrap();
                }
            });
            table.row(vec![
                format!("stage0 decode_b{b}_w1 (gather+scatter round-trip)"),
                format!("{:.3}ms", s_rt.mean() * 1e3),
                format!("{:.3}ms", s_rt.max * 1e3),
                format!("{:.0} steps/s", 1.0 / s_rt.mean()),
            ]);
            println!(
                "resident fused step costs {:.2}x the round-trip step \
                 (want < 1.0x; delta is host cache traffic)",
                s_res.mean() / s_rt.mean().max(1e-12)
            );
        }
    }

    // Channel round-trip with a seq-size hidden tensor.
    let (tx, mut rx) = tagged_channel();
    let hidden = HostTensor::zeros(&[m.microbatch, m.seq, m.hidden]);
    let s = bench_loop(10, iters * 10, || {
        tx.send(Tag::Fwd(0), hidden.clone());
        let _ = rx.recv(Tag::Fwd(0));
    });
    table.row(vec![
        "P2P channel round-trip (hidden tensor)".into(),
        format!("{:.1}us", s.mean() * 1e6),
        format!("{:.1}us", s.max * 1e6),
        format!(
            "{:.2} GiB/s",
            hidden.bytes() as f64 / (1u64 << 30) as f64 / s.mean()
        ),
    ]);

    table.emit("runtime_micro");
}
