//! Table 1 reproduction: the performance-optimisation ablation.
//!
//! Rows (paper Table 1): Standard; Early-exit (no optimisations: exits at
//! the *end* of stages 1 and 2, eager exit forward); Early-exit (1)
//! (deferred exit forward); Early-exit (2) (exits moved to the beginning
//! of the next stage); Early-exit (1&2). Columns: time per iteration and
//! peak memory, for the 1.3B and 7B cost models at P=4.
//!
//! Expected shape: each optimisation strictly helps; with both, time is
//! within k*(f_EE+b_EE) of Standard and peak memory matches it exactly.

#[path = "bench_util.rs"]
mod bench_util;

use eellm::schedule::costs::{CostModel, PAPER_MODELS};
use eellm::schedule::plan::{EeOptions, Plan};
use eellm::schedule::sim::{SimResult, Simulator};
use eellm::util::table::Table;

struct Row {
    name: &'static str,
    exits: Vec<usize>,
    defer: bool,
}

fn variants() -> Vec<Row> {
    // Exits at 1/4 and 1/2 depth with P=4. Without Optimization 2 they sit
    // at the END of stages 0 and 1; with it, at the beginning of stages 1
    // and 2.
    vec![
        Row { name: "Standard", exits: vec![0, 0, 0, 0], defer: true },
        Row { name: "Early-exit", exits: vec![1, 1, 0, 0], defer: false },
        Row { name: "Early-exit (1)", exits: vec![1, 1, 0, 0], defer: true },
        Row { name: "Early-exit (2)", exits: vec![0, 1, 1, 0], defer: false },
        Row { name: "Early-exit (1&2)", exits: vec![0, 1, 1, 0], defer: true },
    ]
}

fn run(cm: &CostModel, row: &Row, m: usize) -> SimResult {
    let plan = Plan::one_f_one_b(
        cm.stages,
        m,
        EeOptions::with_exits(row.exits.clone(), row.defer),
    );
    Simulator::new(cm).run(&plan)
}

fn main() {
    let mut table = Table::new(
        "Table 1: impact of the performance optimisations (P=4, M=64)",
        &[
            "setup",
            "1.3B time/iter",
            "1.3B peak GiB",
            "7B time/iter",
            "7B peak GiB",
        ],
    );
    let models: Vec<&str> = vec!["1.3B", "7B"];
    let cms: Vec<CostModel> = models
        .iter()
        .map(|n| {
            let d = PAPER_MODELS.iter().find(|d| d.name == *n).unwrap();
            CostModel::a100(d, 4, 1)
        })
        .collect();
    let m = 64; // the paper's global batch 128 / microbatch 2
    for row in variants() {
        let mut cells = vec![row.name.to_string()];
        for cm in &cms {
            let r = run(cm, &row, m);
            cells.push(format!("{:.2}s", r.iteration_time));
            cells.push(bench_util::gib(r.peak_memory_overall(cm.alpha)));
        }
        table.row(cells);
    }
    table.emit("table1");

    // Shape checks on the 7B column (matching the paper's ordering).
    let cm = &cms[1];
    let v = variants();
    let std = run(cm, &v[0], m);
    let ee = run(cm, &v[1], m);
    let ee1 = run(cm, &v[2], m);
    let ee2 = run(cm, &v[3], m);
    let ee12 = run(cm, &v[4], m);
    let a = cm.alpha;
    // Unoptimised early exits cost the most memory; each optimisation
    // monotonically reduces it; with both, it matches Standard exactly.
    assert!(ee.peak_memory_overall(a) > ee1.peak_memory_overall(a));
    assert!(ee1.peak_memory_overall(a) >= ee12.peak_memory_overall(a));
    assert!(ee2.peak_memory_overall(a) >= ee12.peak_memory_overall(a));
    assert_eq!(ee12.peak_memory_overall(a), std.peak_memory_overall(a));
    // Time: optimisations never hurt, and the final overhead vs Standard is
    // at most 2*(f_EE+b_EE) (k = 2 exits).
    assert!(ee12.iteration_time <= ee.iteration_time + 1e-9);
    let overhead = ee12.iteration_time - std.iteration_time;
    assert!(
        overhead <= 2.0 * (cm.f_ee + cm.b_ee) + 1e-9,
        "overhead {overhead}"
    );
    println!("table1 shape checks OK");
}
